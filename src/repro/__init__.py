"""repro — container-scale reproduction of the TPU v4 paper.

One package from the OCS fabric to workloads:

  * `repro.cluster`   — `Supercomputer`/`Slice` session API (start here);
                        `cluster.tenancy` co-schedules elastic training
                        against serving on one machine
  * `repro.fleet`     — SLO-aware multi-slice serving: traffic, routing,
                        autoscaling, failure-driven re-routing
  * `repro.core`      — OCS fabric, slice scheduler, topologies, cost
                        models, goodput, autotopo search, SparseCore timing
  * `repro.models`    — model zoo behind one family-dispatching `api`
  * `repro.kernels`   — Pallas kernels (+ XLA references and dispatchers)
  * `repro.embeddings`— SparseCore embedding executor, cache, placement
  * `repro.parallel`  — sharding specs, contexts, overlap, pipeline
  * `repro.serve`     — continuous-batching `ServeEngine` + `SliceSpec`
  * `repro.train`     — preemptible `Trainer` + slice-shape-elastic
                        checkpoint
  * `repro.launch`    — meshes, dry-run lowering, rooflines, HLO costs
  * `repro.data`      — deterministic synthetic datasets
  * `repro.optim`     — Adam + schedules + grad-norm utilities

Subpackages import lazily (module ``__getattr__``) so `import repro` stays
cheap — ``repro.cluster`` etc. resolve on first attribute access.
"""
import importlib

__all__ = [
    "cluster", "configs", "core", "data", "embeddings", "fleet", "kernels",
    "launch", "models", "optim", "parallel", "serve", "train",
]

__version__ = "0.4.0"


def __getattr__(name):
    if name in __all__:
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
