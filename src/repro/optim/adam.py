"""Optimizers (Adam/AdamW, Adafactor, SGD) as pure pytree transforms.

Optimizer state mirrors the parameter pytree, so ZeRO-style sharding comes
for free: state leaves inherit the parameter PartitionSpecs (fully sharded
when FSDP is on).  ``state_dtype='bfloat16'`` halves optimizer memory for the
1T-parameter config.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


class OptState(NamedTuple):
    step: jax.Array
    mu: Any                     # first moment (adam) / row factors (adafactor)
    nu: Any                     # second moment


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def lr_schedule(cfg: OptimizerConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def init(cfg: OptimizerConfig, params) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    if cfg.name == "sgd":
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(zeros, params), None)
    if cfg.name == "adafactor":
        def facts(p):
            if p.ndim < 2:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return OptState(jnp.zeros((), jnp.int32), None,
                        jax.tree.map(facts, params))
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(zeros, params),
                    jax.tree.map(zeros, params))


def apply(cfg: OptimizerConfig, params, grads, state: OptState
          ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    sd = jnp.dtype(cfg.state_dtype)

    if cfg.name == "sgd":
        def upd(p, g, m):
            m2 = (0.9 * m.astype(jnp.float32) + g)
            p2 = p.astype(jnp.float32) - lr * m2
            return p2.astype(p.dtype), m2.astype(sd)
        out = jax.tree.map(upd, params, grads, state.mu)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, OptState(step, new_m, None), {"grad_norm": gnorm,
                                                    "lr": lr}

    if cfg.name == "adafactor":
        def upd(p, g, f):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + 1e-30
            if p.ndim < 2:
                v = cfg.b2 * f["v"] + (1 - cfg.b2) * g2
                upd_ = g * jax.lax.rsqrt(v + cfg.eps)
                newf = {"v": v}
            else:
                vr = cfg.b2 * f["vr"] + (1 - cfg.b2) * g2.mean(-1)
                vc = cfg.b2 * f["vc"] + (1 - cfg.b2) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], 1e-30))
                upd_ = g * jax.lax.rsqrt(denom + cfg.eps)
                newf = {"vr": vr, "vc": vc}
            p2 = (p.astype(jnp.float32) * (1 - cfg.weight_decay * lr)
                  - lr * upd_)
            return p2.astype(p.dtype), newf
        flat, tdef = jax.tree.flatten(params)
        gflat = tdef.flatten_up_to(grads)
        fflat = tdef.flatten_up_to(state.nu)
        res = [upd(p, g, f) for p, g, f in zip(flat, gflat, fflat)]
        new_p = tdef.unflatten([r[0] for r in res])
        new_f = tdef.unflatten([r[1] for r in res])
        return new_p, OptState(step, None, new_f), {"grad_norm": gnorm,
                                                    "lr": lr}

    # adam / adamw
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh = m2 / c1
        vh = v2 / c2
        p2 = (p.astype(jnp.float32) * (1.0 - cfg.weight_decay * lr)
              - lr * mh / (jnp.sqrt(vh) + cfg.eps))
        return p2.astype(p.dtype), m2.astype(sd), v2.astype(sd)

    flat, tdef = jax.tree.flatten(params)
    gflat = tdef.flatten_up_to(grads)
    mflat = tdef.flatten_up_to(state.mu)
    vflat = tdef.flatten_up_to(state.nu)
    res = [upd(p, g, m, v) for p, g, m, v in zip(flat, gflat, mflat, vflat)]
    new_p = tdef.unflatten([r[0] for r in res])
    new_m = tdef.unflatten([r[1] for r in res])
    new_v = tdef.unflatten([r[2] for r in res])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
