"""`repro.optim` — Adam, LR schedules, gradient-norm utilities."""
from repro.optim.adam import (OptState, apply, clip_by_global_norm,
                              global_norm, init, lr_schedule)

__all__ = ["OptState", "apply", "clip_by_global_norm", "global_norm",
           "init", "lr_schedule"]
