"""`FleetService` — many slices, one service.

The fleet event loop turns a pool of serve replicas (each a `Slice` of one
`Supercomputer` running the PR-3 `ServeEngine` fast path) into a single
SLO-tracked service in front of open-loop traffic:

    traffic.generate(spec)  ──►  Router ──► ServeReplica ──► Slice/Engine
                                   ▲            │
                         Autoscaler┘            └── Supercomputer.allocate/free

Time is *virtual*: every replica chunk costs its measured wall latency (or
a fixed ``chunk_s`` in deterministic mode) on the fleet clock, and replicas
overlap in virtual time because they are independent slices of the modeled
machine — the container serializes compute the hardware would run in
parallel.  Tokens, outputs and queue dynamics are all real.

Failure path (§2.3 at fleet level): `Supercomputer.fail_block` on a serving
slice propagates a `SliceEvent` into the replica's session; with no spare
the slice is LOST, the service (subscribed machine-wide) evacuates the
replica's in-flight requests and re-routes them to survivors, where their
already-decoded tokens are re-prefilled as context.  The service keeps
serving; only capacity shrinks.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.cluster.registry import MachineRegistry, slice_key
from repro.cluster.slices import Slice, SliceEvent
from repro.cluster.straggler import StragglerConfig, StragglerDetector
from repro.cluster.supercomputer import Supercomputer
from repro.configs.base import ModelConfig
from repro.fleet.autoscaler import (Autoscaler, AutoscalerConfig,
                                    ForecastConfig)
from repro.fleet.replica import (ACTIVE, DEAD, DRAINING, FREED,
                                 PROVISIONING, ServeReplica)
from repro.fleet.router import Router, RouterConfig
from repro.fleet.traffic import FleetRequest, FleetTrace
from repro.obs import Telemetry, VirtualClock
from repro.serve.engine import ServeEngine, SliceSpec, _pct

Geometry = Union[int, Tuple[int, int, int]]
# fail/repair target: a block id or symbolic spec ("spare"/"busiest"/
# "replica:<id>"/"last_failed"/"failed:<i>"), optionally machine-scoped as
# ("<machine-name>", block-or-"spare") on a multi-machine fleet
BlockSpec = Union[int, str, Tuple[str, Union[int, str]]]
FailPlan = Sequence[Tuple[float, BlockSpec]]         # (virtual_t, target)
Arrivals = Union[FleetTrace, Sequence[FleetRequest]]
Machines = Union[Supercomputer, MachineRegistry, Sequence[Supercomputer]]


@dataclasses.dataclass
class FleetReport:
    """What one traffic scenario did to the fleet."""
    offered: int
    completed: int
    dropped: int
    drops_by_reason: Dict[str, int]  # "wait_queue_full" / "stranded"
    migrated: int                   # requests that survived a replica death
    tokens_served: int
    tokens_offered: int
    makespan_s: float               # virtual: first arrival -> last completion
    aggregate_tokens_per_s: float   # tokens_served / makespan
    p50_ttft_s: float
    p95_ttft_s: float
    slo_attainment: float           # SLO-met completions / offered
    served_goodput: float           # tokens_served / tokens_offered
    slo_goodput: float              # tokens of SLO-met requests / offered
    scale_ups: int
    scale_downs: int
    predictive_ups: int             # scale-ups fired by the forecaster
    straggler_swaps: int            # detector-fired spare swaps
    failures: int                   # fail_block hits on fleet slices
    replicas_seen: int
    # heterogeneous-fleet economics (all zero / single-keyed on a
    # generation-less or single-machine fleet)
    energy_wh: float                # allocated-lifetime Wh across replicas
    cost_usd: float                 # allocated-lifetime $ across replicas
    perf_watt_goodput: float        # SLO-met tokens per Wh
    slo_tokens_per_usd: float       # SLO-met tokens per dollar
    replicas_by_machine: Dict[str, int]  # machine name -> replicas placed
    replica_stats: List[Dict[str, Any]]
    log: List[str]

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready summary (drops the log and per-replica stats)."""
        d = dataclasses.asdict(self)
        d.pop("log")
        d.pop("replica_stats")
        return d


class FleetService:
    """Operate a pool of serve replicas over one `Supercomputer` — or a
    `MachineRegistry` of several, spanning hardware generations — as a
    single SLO-tracked service.

    Args:
      sc: the machine, a sequence of machines, or a `MachineRegistry`
        (the service subscribes to every machine's event stream).  With
        several machines, ``placement`` decides where scale-ups land and
        each replica's chunk latency scales by its generation's fig12
        perf factor relative to the first machine's generation.
      model_cfg/params: the served model (one compile serves all replicas).
      spec: per-replica `SliceSpec` serving envelope.
      geometry: chip shape of each replica slice.
      initial_replicas: pool size at t=0 (raised to the autoscaler floor).
      router: routing policy config (`least_loaded`/`least_eta`/RR).
      autoscale: elastic controller config; None pins the pool size.
      timing: "measured" (real chunk wall latency) or a fixed virtual
        seconds-per-chunk for machine-independent control dynamics.
      max_wait_queue: backpressure bound; beyond it requests are dropped
        and reported.
      ttft_window_s: sliding window for the observed-p95-TTFT signal.
      priority: scheduling class of this service's slices.
      preempt_on_allocate: let scale-ups cooperatively evict strictly
        lower-priority tenants (the serving-burst-evicts-training story);
        pass ``"shrink"`` to prefer asking them to *shrink* (hand back
        blocks, keep training on a smaller geometry) over full eviction.
      placement: multi-machine scale-up objective — a generation score
        ("perf" / "perf_watt" / "perf_dollar": best machine first) or
        "blind" (generation-unaware round-robin; the baseline the
        het-fleet benchmark beats).  Ignored on a single machine.
    """

    def __init__(self, sc: Machines, model_cfg: ModelConfig, params,
                 spec: Optional[SliceSpec] = None, *,
                 geometry: Geometry = (4, 4, 4),
                 initial_replicas: int = 1,
                 router: Optional[RouterConfig] = None,
                 autoscale: Optional[AutoscalerConfig] = None,
                 forecast: Optional[ForecastConfig] = None,
                 timing: Union[str, float] = "measured",
                 max_wait_queue: int = 256,
                 ttft_window_s: float = 2.0,
                 priority: int = 1,
                 preempt_on_allocate: Union[bool, str] = False,
                 placement: str = "perf_watt",
                 straggler: Optional[StragglerConfig] = None,
                 obs: Optional[Telemetry] = None):
        assert model_cfg.family != "audio", \
            "fleet serving rides the fast path; the whisper enc-dec " \
            "family has no per-slot cache insert yet"
        # normalize the machine argument into a registry; ``self.sc``
        # stays the first machine so single-machine callers are untouched
        if isinstance(sc, MachineRegistry):
            self.registry = sc
        elif isinstance(sc, Supercomputer):
            self.registry = MachineRegistry([sc])
        else:
            self.registry = MachineRegistry(sc)
        assert len(self.registry) > 0, "need at least one machine"
        self.machines = self.registry.machines
        self.sc = self.machines[0]
        assert placement in ("perf", "perf_watt", "perf_dollar", "blind"), \
            placement
        self.placement = placement
        self._blind_rr = 0
        # chunk-latency reference: the FIRST machine's generation (a
        # homogeneous fleet divides by 1.0 — bitwise-unchanged timing)
        ref = self.sc.generation
        self._ref_perf = ref.perf_factor if ref else 1.0
        self.cfg = model_cfg
        self.params = params
        self.spec = spec or SliceSpec()
        self.geometry = geometry
        # telemetry: share the machine's handle by default, so machine and
        # fleet events land on one timeline; when its clock is a
        # VirtualClock, the event loop advances it in step with `self.now`
        # (fleet traces read in virtual seconds)
        self.obs = obs if obs is not None else self.sc.obs
        self._vclock = (self.obs.clock
                        if isinstance(self.obs.clock, VirtualClock) else None)
        # service-local drop breakdown (the registry counters are shared
        # across services on one Telemetry; the report stays per-service)
        self.drops_by_reason: Dict[str, int] = {}
        self.router = Router(router, obs=self.obs)
        self.autoscaler = (Autoscaler(autoscale, forecast=forecast)
                           if autoscale else None)
        self.chunk_s: Optional[float] = (
            None if timing == "measured" else float(timing))
        self.max_wait_queue = max_wait_queue
        self.ttft_window_s = ttft_window_s
        # scheduling class of this service's slices.  With
        # ``preempt_on_allocate`` a scale-up that cannot be placed asks the
        # machine to cooperatively evict strictly-lower-priority tenants
        # (an elastic training job checkpoints and frees) before giving up —
        # the serving-burst-evicts-training story of cluster/tenancy.py.
        self.priority = priority
        self.preempt_on_allocate = preempt_on_allocate
        # straggler policy: every replica gets its own detector (its slice
        # is its synchronization domain; cross-replica steps never sync)
        self.straggler_cfg = straggler
        self.deferred_scale_ups = 0     # scale-ups the machine could not place

        self.replicas: List[ServeReplica] = []
        self.retired: List[ServeReplica] = []   # freed/dead, stats only
        self.wait: deque = deque()
        self.requests: List[FleetRequest] = []
        # trace-mode accounting: when `run` serves a FleetTrace, requests
        # materialize lazily at arrival; entries dropped before ever
        # materializing are counted here instead of built just to be marked
        self._trace: Optional[FleetTrace] = None
        self._trace_stranded = 0
        # running completion counters: the measured per-replica service
        # rate (tokens/busy-second over mean tokens/request) that converts
        # an arrival-rate forecast into a replica target
        self._completed_n = 0
        self._tokens_done = 0
        self.log: List[str] = []
        self.now = 0.0
        self.failures = 0
        self.failed_blocks: List[int] = []
        # machine-scoped mirror of `failed_blocks` (job/block ids are only
        # unique per machine); repairs of "last_failed"/"failed:<i>" resolve
        # through this so they land on the machine that took the hit
        self._failed: List[Tuple[Supercomputer, int]] = []
        self._next_rep = 0
        self._by_job: Dict[Tuple[int, int], ServeReplica] = {}
        self.replicas_by_machine: Dict[str, int] = {}
        self._ttfts: deque = deque()          # (t_done, ttft) window
        self._warmed = False
        self.registry.subscribe(self._on_machine_event)
        if self.autoscaler:
            initial_replicas = max(initial_replicas,
                                   self.autoscaler.cfg.min_replicas)
        for _ in range(initial_replicas):
            self._scale_up(0.0, provision_s=0.0)

    # -- pool management ------------------------------------------------------

    def _log(self, msg: str) -> None:
        self.log.append(f"[t={self.now:8.3f}s] {msg}")

    def _drop(self, reason: str, n: int = 1, **detail) -> None:
        """Account one (or n) dropped request(s): labeled counter, a
        flight-recorder event, and a postmortem snapshot of the telemetry
        leading up to the drop (the drop-reporting trigger)."""
        self.drops_by_reason[reason] = self.drops_by_reason.get(reason, 0) + n
        self.obs.metrics.counter("fleet.drops", reason=reason).inc(n)
        self.obs.event("req.drop", cat="drop", track="router", t=self.now,
                       reason=reason, n=n, **detail)
        self.obs.postmortem("request_drop", t=self.now,
                            drop_reason=reason, n=n, **detail)

    def _machine_order(self) -> List[Supercomputer]:
        """Machines to try for the next scale-up, best first.  Generation
        placement ranks by the configured objective; ``blind`` round-robins
        registration order (the generation-unaware baseline)."""
        if self.placement == "blind":
            n = len(self.machines)
            order = [self.machines[(self._blind_rr + i) % n]
                     for i in range(n)]
            self._blind_rr += 1
            return order
        return self.registry.rank(self.placement)

    def _scale_up(self, now: float, *,
                  provision_s: Optional[float] = None
                  ) -> Optional[ServeReplica]:
        """Add capacity: reuse a draining replica when one exists (pure
        bookkeeping, no OCS programming), else allocate a fresh slice on
        the best machine under the placement objective — free capacity on
        ANY machine beats shrinking/evicting a tenant on a better one."""
        for r in self.replicas:
            if r.state == DRAINING:
                r.undrain()
                self._log(f"scale-up: undrained replica {r.rep_id}")
                self.obs.event("fleet.scale_up", cat="autoscaler",
                               track="autoscaler", t=now,
                               rep_id=r.rep_id, undrained=True)
                return r
        order = self._machine_order()
        sl = mach = None
        for m in order:
            sl = m.allocate(self.geometry, required=False,
                            priority=self.priority)
            if sl is not None:
                mach = m
                break
        if sl is None and self.preempt_on_allocate:
            for m in order:
                sl = m.allocate(self.geometry, required=False,
                                priority=self.priority,
                                preempt=self.preempt_on_allocate)
                if sl is not None:
                    mach = m
                    break
        if sl is None:
            self.deferred_scale_ups += 1
            self._log("scale-up: fleet full, allocation deferred")
            return None
        session = sl.serve(self.cfg, self.params, self.spec)
        if provision_s is None:
            provision_s = (self.autoscaler.cfg.provision_s
                           if self.autoscaler else 0.0)
        det = (StragglerDetector(self.straggler_cfg)
               if self.straggler_cfg else None)
        g = mach.generation
        chips = sl.num_chips
        rep = ServeReplica(self._next_rep, sl, session, now=now,
                           provision_s=provision_s, chunk_s=self.chunk_s,
                           straggler=det, tracer=self.obs.tracer,
                           speed=(g.perf_factor / self._ref_perf
                                  if g else 1.0),
                           watts=(g.watts_per_chip * chips if g else 0.0),
                           dollars_per_h=(g.dollars_per_chip_hour * chips
                                          if g else 0.0),
                           gen=(g.name if g else ""),
                           # blind placement stays blind end-to-end: no
                           # generation hint to the autoscaler's drain order
                           drain_rank=(g.perf_per_watt
                                       if g and self.placement != "blind"
                                       else 0.0))
        self._next_rep += 1
        self.replicas.append(rep)
        self._by_job[slice_key(sl)] = rep
        self.replicas_by_machine[mach.name] = \
            self.replicas_by_machine.get(mach.name, 0) + 1
        self._log(f"scale-up: replica {rep.rep_id} on {mach.name} "
                  f"job{sl.job_id} blocks={sl.blocks} "
                  f"(ready t+{provision_s:.2f}s)")
        self.obs.event("fleet.scale_up", cat="autoscaler", track="autoscaler",
                       t=now, rep_id=rep.rep_id, job_id=sl.job_id,
                       machine=mach.name)
        return rep

    def _scale_down(self, victim: ServeReplica) -> None:
        victim.drain()
        self._log(f"scale-down: draining replica {victim.rep_id} "
                  f"(depth={victim.depth})")
        self.obs.event("fleet.scale_down", cat="autoscaler",
                       track="autoscaler", t=self.now,
                       rep_id=victim.rep_id, depth=victim.depth)

    def _free_drained(self) -> None:
        for r in self.replicas:
            if r.drained:
                self._log(f"freed replica {r.rep_id} (drained)")
                r.free()
        # retire freed/dead replicas: a long-lived service must not keep
        # every past replica's engine (and its device KV cache) alive, nor
        # iterate them on every routing decision — retire() snapshots the
        # stats and drops the session/slice references
        gone = [r for r in self.replicas if r.state in (FREED, DEAD)]
        if gone:
            for r in gone:
                if r.t_end is None:
                    r.t_end = self.now   # stop the energy/cost meter
                self._by_job.pop(slice_key(r.slice), None)
                r.retire()
            self.retired.extend(gone)
            self.replicas = [r for r in self.replicas
                             if r.state not in (FREED, DEAD)]

    @property
    def live_replicas(self) -> List[ServeReplica]:
        """Replicas that can still do work (provisioning/active/draining)."""
        return [r for r in self.replicas
                if r.state in (PROVISIONING, ACTIVE, DRAINING)]

    def close(self) -> None:
        """Shut the service down: free every replica (each must owe no
        work — `ServeReplica.free` enforces it) and detach from the
        machine's event stream, so a long-lived `Supercomputer` hosting
        successive services does not accumulate dead subscribers."""
        for r in list(self.replicas):
            if r.state in (PROVISIONING, ACTIVE, DRAINING):
                r.free()
        self._free_drained()        # retires the freed replicas
        self.registry.unsubscribe(self._on_machine_event)

    # -- failure integration --------------------------------------------------

    def _on_machine_event(self, sl: Slice, ev: SliceEvent) -> None:
        rep = self._by_job.get(slice_key(sl))
        if rep is None:
            return
        if ev.kind == "lost":
            self.failures += 1
            orphans = rep.evacuate()
            self._log(f"replica {rep.rep_id} LOST ({ev.detail}); "
                      f"re-routing {len(orphans)} in-flight requests")
            self.obs.metrics.counter("fleet.evacuated").inc(len(orphans))
            self.obs.event("fleet.evacuate", cat="failure",
                           track=f"replica:{rep.rep_id}", t=self.now,
                           rep_id=rep.rep_id, orphans=len(orphans))
            # orphans jump the wait queue: they have already waited once
            for req in reversed(orphans):
                self.wait.appendleft(req)
            self._by_job.pop(slice_key(sl), None)
        elif ev.kind == "reconfigure":
            self.failures += 1
            self._log(f"replica {rep.rep_id} reconfigured around a failed "
                      f"block ({ev.circuits_moved} circuits, "
                      f"{ev.downtime_s * 1e3:.0f}ms stall)")

    @staticmethod
    def _machine_spare(m: Supercomputer) -> Optional[int]:
        spares = sorted(m.scheduler.free & m.scheduler.healthy)
        return spares[0] if spares else None

    def _resolve_target(self, spec: BlockSpec
                        ) -> Optional[Tuple[Supercomputer, int]]:
        """Fail-plan target resolved at fire time into (machine, block):
        a raw block id (first machine — the single-machine legacy form),
        "replica:<id>" (first block of that replica's slice, wherever it
        is), "busiest" (first block of the alive replica owing the most
        work fleet-wide), "spare" (a healthy free block — burn it to force
        the next failure into the no-spare LOST path; first machine that
        has one), or a ("<machine-name>", block-or-"spare") pair to pin
        the hit to one machine."""
        if isinstance(spec, tuple):
            name, inner = spec
            m = self.registry.get(name)
            if inner == "spare":
                b = self._machine_spare(m)
                return (m, b) if b is not None else None
            return (m, int(inner))
        if isinstance(spec, int):
            return (self.sc, spec)
        if spec == "spare":
            for m in self.machines:
                b = self._machine_spare(m)
                if b is not None:
                    return (m, b)
            return None
        if spec == "busiest":
            alive = [r for r in self.replicas
                     if r.alive and r.state != PROVISIONING]
            if not alive:
                return None
            busiest = max(alive, key=lambda r: (r.tokens_owed(), r.depth,
                                                -r.rep_id))
            return (busiest.slice._sc, busiest.slice.blocks[0])
        rep_id = int(str(spec).split(":", 1)[1])
        for r in self.replicas:
            if r.rep_id == rep_id and r.alive:
                return (r.slice._sc, r.slice.blocks[0])
        return None

    # -- dispatch -------------------------------------------------------------

    def _admit_or_wait(self, req: FleetRequest) -> None:
        if self.router.route(req, self.replicas, self.now) is not None:
            return
        if len(self.wait) < self.max_wait_queue:
            self.wait.append(req)
        else:
            req.status = "dropped"
            self._log(f"DROP req{req.fid} (wait queue full)")
            self._drop("wait_queue_full", fid=req.fid)

    def _flush_wait(self) -> None:
        while self.wait:
            if self.router.route(self.wait[0], self.replicas,
                                 self.now) is None:
                break
            self.wait.popleft()

    def _window_p95_ttft(self) -> Optional[float]:
        # keyed on COMPLETION time, evicted by filtering: completions from
        # different replicas append out of order in measured-timing mode,
        # so front-only eviction could trap stale samples behind new ones
        cutoff = self.now - self.ttft_window_s
        if self._ttfts:
            self._ttfts = deque((t, v) for t, v in self._ttfts
                                if t >= cutoff)
        if not self._ttfts:
            return None
        return _pct([v for _, v in self._ttfts], 95)

    def capacity_rps(self) -> Optional[float]:
        """Measured per-replica request service rate: decode throughput per
        busy replica-second over the observed mean tokens per completed
        request.  None until enough completions have been seen to trust
        the estimate — the forecaster abstains until then."""
        if self._completed_n < 8:
            return None
        toks = sum(r.tokens_served for r in self.replicas) \
            + sum(r.stats()["tokens_served"] for r in self.retired)
        busy = sum(r.busy_s for r in self.replicas) \
            + sum(r.stats()["busy_s"] for r in self.retired)
        if busy <= 0.0 or toks <= 0:
            return None
        mean_new = self._tokens_done / self._completed_n
        return (toks / busy) / max(1.0, mean_new)

    def _tick_autoscaler(self) -> None:
        assert self.autoscaler is not None
        action, victim = self.autoscaler.decide(
            self.now, self.replicas, len(self.wait),
            self._window_p95_ttft(), capacity_rps=self.capacity_rps())
        if action == "up":
            prev_pred = self.autoscaler.predictive_ups
            if self._scale_up(self.now) is not None:
                self.autoscaler.record("up", self.now)
                if self.autoscaler.predictive_ups > prev_pred:
                    # forecaster-fired pre-provision: mark it on the trace
                    # so a replay can tell predictive ups from reactive
                    self.obs.event("fleet.predictive_up", cat="autoscaler",
                                   track="autoscaler", t=self.now)
        elif action == "down":
            self._scale_down(victim)
            self.autoscaler.record("down", self.now)

    def warmup(self) -> None:
        """Compile the shared serving programs outside virtual time: one
        throwaway engine (no slice) runs a request end-to-end, so replica
        chunk latencies never include compile."""
        if self._warmed:
            return
        eng = ServeEngine(self.cfg, self.params, self.spec)
        eng.submit(np.arange(4, dtype=np.int32),
                   max_new_tokens=self.spec.chunk + 1)
        eng.run(max_steps=4 * self.spec.chunk)
        self._warmed = True

    # -- the event loop -------------------------------------------------------

    def run(self, requests: Arrivals, *,
            fail_plan: Optional[FailPlan] = None,
            repair_plan: Optional[FailPlan] = None,
            settle_s: float = 0.0,
            max_iters: int = 200_000,
            on_advance=None) -> FleetReport:
        """Serve one arrival trace to completion (plus ``settle_s`` virtual
        seconds of autoscaler cool-down, so drains/frees become visible).

        ``requests`` is either a `FleetTrace` (the structure-of-arrays
        form: arrivals are cursor-indexed straight off the numpy columns
        and each `FleetRequest` materializes only when its arrival time
        comes — a million-request day costs a million cheap column reads,
        not a million up-front objects) or a plain request sequence.  A
        sequence already sorted by arrival is used as-is (one O(n)
        monotonicity scan); only out-of-order input pays the sort.

        ``fail_plan``/``repair_plan`` inject `fail_block`/`repair_block`
        calls at virtual times; a repair target of ``"last_failed"``
        resolves to the most recently failed block at fire time (and
        ``"failed:<i>"`` to the i-th injected failure), so a scenario can
        kill a serving block and later hand it back for the autoscaler to
        reclaim.

        ``on_advance(now)`` is called after every virtual-clock advance —
        the co-tenancy hook: `cluster.tenancy` uses it to run training
        quanta in step with fleet time (the two tenants hold disjoint
        slices, so their compute overlaps in virtual time)."""
        if self.chunk_s is None:
            self.warmup()
        trace = requests if isinstance(requests, FleetTrace) else None
        if trace is not None:
            n_arr = len(trace)
            arr_t = trace.t_arrival
            self.requests = []          # filled as arrivals materialize
            arrivals: List[FleetRequest] = []
        else:
            arrivals = list(requests)
            key = lambda r: (r.t_arrival, r.fid)        # noqa: E731
            if any(key(arrivals[i]) > key(arrivals[i + 1])
                   for i in range(len(arrivals) - 1)):
                arrivals.sort(key=key)
            n_arr = len(arrivals)
            arr_t = None
            self.requests = list(arrivals)
        self._trace = trace
        self._trace_stranded = 0
        fails = sorted(fail_plan or [], key=lambda f: f[0])
        repairs = sorted(repair_plan or [], key=lambda f: f[0])
        ai = fi = ri = 0
        tick = self.autoscaler.cfg.tick_s if self.autoscaler else None
        next_tick = 0.0 if tick else float("inf")
        # settle is measured from the last *event* — which, for a re-entered
        # service (windowed tenancy driving), starts at the current clock,
        # so an idle follow-up run still grants the autoscaler settle_s of
        # tick time to drain surplus replicas
        last_event_t = self.now

        def next_arrival_t() -> float:
            return float(arr_t[ai]) if trace is not None \
                else arrivals[ai].t_arrival

        def work_remaining() -> bool:
            if (ai < n_arr or fi < len(fails) or ri < len(repairs)
                    or self.wait):
                return True
            return any(r.state in (PROVISIONING, ACTIVE, DRAINING)
                       and r.session.engine.depth > 0
                       for r in self.replicas)

        for _ in range(max_iters):
            # promote warmed-up replicas, release finished drains
            for r in self.replicas:
                if r.state == PROVISIONING and self.now >= r.ready_at:
                    r.state = ACTIVE
            self._free_drained()

            if not work_remaining():
                if (self.autoscaler is None
                        or self.now >= last_event_t + settle_s):
                    break
                steady = (not any(r.state == DRAINING
                                  for r in self.replicas)
                          and len(self.live_replicas)
                          <= self.autoscaler.cfg.min_replicas)
                if steady:
                    break
                self.now = max(self.now, next_tick)
                if self._vclock is not None:
                    self._vclock.advance(self.now)
                self._tick_autoscaler()
                next_tick = self.now + tick
                continue

            # -- next event time ---------------------------------------------
            cands: List[float] = []
            if ai < n_arr:
                cands.append(next_arrival_t())
            if fi < len(fails):
                cands.append(fails[fi][0])
            if ri < len(repairs):
                cands.append(repairs[ri][0])
            starts = [s for s in (r.next_start() for r in self.replicas)
                      if s is not None]
            cands.extend(starts)
            if tick:
                # ticks run whenever the loop is alive: an idle gap before
                # a distant repair must still drain surplus replicas
                cands.append(next_tick)
            # capacity can never return: no live replicas, no healthy free
            # blocks, and no repairs left to change that — fail the
            # stranded (and still-arriving) requests loudly instead of
            # spinning ticks until max_iters
            dead_end = (not self.live_replicas and ri >= len(repairs)
                        and self.registry.free_healthy_blocks() == 0)
            if dead_end and (self.wait or ai < n_arr):
                # before declaring the requests stranded, try one scale-up:
                # with `preempt_on_allocate` the machine may still carve a
                # slice out of a lower-priority tenant (e.g. an elastic
                # training job that checkpoints and frees on request)
                if self._scale_up(self.now) is not None:
                    # capacity reclaimed: hand it the stranded work so the
                    # new replica appears in the next event-time sweep
                    self._flush_wait()
                    continue
            if not cands or (dead_end and (self.wait or ai < n_arr)):
                stranded = list(self.wait)
                self.wait.clear()
                n_unmat = 0
                if trace is not None:
                    # never-materialized trace entries are counted dropped,
                    # not built just to be stamped — at fleet scale that is
                    # the difference between a counter and a million objects
                    n_unmat = n_arr - ai
                    self._trace_stranded += n_unmat
                else:
                    stranded += arrivals[ai:]
                ai = n_arr
                for req in stranded:
                    req.status = "dropped"
                self._log(f"no capacity and no path to any: dropped "
                          f"{len(stranded) + n_unmat} stranded requests")
                if stranded or n_unmat:
                    self._drop("stranded", n=len(stranded) + n_unmat)
                break
            self.now = max(self.now, min(cands))
            if self._vclock is not None:
                self._vclock.advance(self.now)
            if on_advance is not None:
                on_advance(self.now)

            # -- injected failures / repairs ---------------------------------
            while fi < len(fails) and fails[fi][0] <= self.now:
                tgt = self._resolve_target(fails[fi][1])
                if tgt is None:
                    # a scenario that declares a failure must see it land or
                    # know it didn't — silent skips make benchmarks measure
                    # something other than what they claim
                    self._log(f"SKIPPED fail_block({fails[fi][1]!r}): "
                              f"target did not resolve")
                else:
                    mach, block = tgt
                    self._log(f"injecting fail_block({block}) "
                              f"on {mach.name}")
                    self.failed_blocks.append(block)
                    self._failed.append((mach, block))
                    mach.fail_block(block)  # subscription handles rerouting
                    last_event_t = self.now
                fi += 1
            while ri < len(repairs) and repairs[ri][0] <= self.now:
                spec_b = repairs[ri][1]
                ri += 1
                if spec_b == "last_failed":
                    if not self._failed:
                        continue
                    tgt = self._failed[-1]
                elif isinstance(spec_b, str) and spec_b.startswith("failed:"):
                    # "failed:<i>": i-th injected failure of this service's
                    # lifetime — lets a plan that burns spares repair each
                    # of them individually
                    i = int(spec_b.split(":", 1)[1])
                    if i >= len(self._failed):
                        continue
                    tgt = self._failed[i]
                else:
                    tgt = self._resolve_target(spec_b)
                if tgt is not None:
                    mach, block = tgt
                    self._log(f"repair_block({block}) on {mach.name}")
                    mach.repair_block(block)
                    last_event_t = self.now
            # -- arrivals ----------------------------------------------------
            while ai < n_arr and next_arrival_t() <= self.now:
                if trace is not None:
                    req = trace.request(ai)
                    self.requests.append(req)
                else:
                    req = arrivals[ai]
                if self.autoscaler is not None:
                    self.autoscaler.observe_arrival(req.t_arrival)
                tr = self.obs.tracer
                if tr.enabled:
                    tr.event("req.arrival", cat="request", track="router",
                             t=req.t_arrival, fid=req.fid)
                self._admit_or_wait(req)
                ai += 1
            # -- autoscaler tick ---------------------------------------------
            if tick and self.now >= next_tick:
                self._tick_autoscaler()
                next_tick = self.now + tick
            # -- replica chunks ----------------------------------------------
            for r in list(self.replicas):
                if r.runnable(self.now):
                    for done in r.step(self.now):
                        self._ttfts.append((done.t_done, done.ttft_s))
                        self._completed_n += 1
                        self._tokens_done += len(done.out_tokens)
                        last_event_t = max(last_event_t, done.t_done)
            # completions freed slots; drain the wait queue into them
            self._flush_wait()
        else:
            raise RuntimeError(f"fleet loop did not converge in "
                               f"{max_iters} iterations")
        return self._report()

    # -- reporting ------------------------------------------------------------

    def report_for(self, requests: Sequence[FleetRequest]) -> FleetReport:
        """Build a `FleetReport` over an arbitrary request population —
        used by windowed drivers (`cluster.tenancy`) that feed one trace
        through several `run` calls and want one merged report at the end."""
        return self._report(requests)

    def _report(self, requests: Optional[Sequence[FleetRequest]] = None
                ) -> FleetReport:
        reqs = list(requests) if requests is not None else self.requests
        trace = self._trace if requests is None else None
        done = [r for r in reqs if r.status == "done"]
        dropped = [r for r in reqs if r.status == "dropped"]
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        tokens = sum(len(r.out_tokens) for r in done)
        offered_n = len(reqs)
        dropped_n = len(dropped)
        offered_tok = sum(r.max_new_tokens for r in reqs)
        t0 = min((r.t_arrival for r in reqs), default=0.0)
        if trace is not None and len(trace):
            # trace-mode: offered load comes from the columns — entries the
            # dead-end path dropped without materializing still count
            offered_n = len(trace)
            dropped_n += self._trace_stranded
            offered_tok = trace.tokens_offered
            t0 = float(trace.t_arrival[0])
        t1 = max((r.t_done for r in done if r.t_done), default=t0)
        makespan = max(t1 - t0, 1e-9)
        asc = self.autoscaler
        slo_tok = sum(len(r.out_tokens) for r in done if r.met_slo)
        # energy/cost meter: live replicas are charged up to `now`; retired
        # ones were stamped with t_end when freed/lost
        energy = sum(r.energy_wh(self.now) for r in self.replicas) \
            + sum(r.stats().get("energy_wh", 0.0) for r in self.retired)
        cost = sum(r.cost_usd(self.now) for r in self.replicas) \
            + sum(r.stats().get("cost_usd", 0.0) for r in self.retired)
        return FleetReport(
            offered=offered_n,
            completed=len(done),
            dropped=dropped_n,
            drops_by_reason=dict(self.drops_by_reason),
            migrated=sum(1 for r in reqs if r.migrations > 0),
            tokens_served=tokens,
            tokens_offered=offered_tok,
            makespan_s=round(makespan, 4),
            aggregate_tokens_per_s=round(tokens / makespan, 2),
            p50_ttft_s=round(_pct(ttfts, 50), 4),
            p95_ttft_s=round(_pct(ttfts, 95), 4),
            slo_attainment=round(
                sum(1 for r in done if r.met_slo) / max(1, offered_n), 4),
            served_goodput=round(tokens / max(1, offered_tok), 4),
            slo_goodput=round(slo_tok / max(1, offered_tok), 4),
            scale_ups=asc.scale_ups if asc else 0,
            scale_downs=asc.scale_downs if asc else 0,
            predictive_ups=asc.predictive_ups if asc else 0,
            straggler_swaps=sum(r.straggler_swaps
                                for r in self.retired + self.replicas),
            failures=self.failures,
            replicas_seen=self._next_rep,
            energy_wh=round(energy, 6),
            cost_usd=round(cost, 8),
            perf_watt_goodput=round(slo_tok / energy, 4) if energy > 0
            else 0.0,
            slo_tokens_per_usd=round(slo_tok / cost, 4) if cost > 0
            else 0.0,
            replicas_by_machine=dict(self.replicas_by_machine),
            replica_stats=[r.stats()
                           for r in self.retired + self.replicas],
            log=list(self.log),
        )
