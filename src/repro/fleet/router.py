"""SLO-aware request routing over the replica pool.

Policies (pick with ``RouterConfig.policy``):

  * ``least_loaded`` — send to the replica owing the fewest decode tokens
    (ties broken toward fewer queued requests, then lower id).  Cheap and
    close to optimal under uniform request shapes.
  * ``least_eta`` — shortest-expected-TTFT: rank replicas by the engine's
    queue-aware TTFT estimate plus any provisioning delay and in-flight
    chunk tail (`ServeReplica.eta_s`).  Better under mixed lengths, since a
    short queue of long requests can be worse than a long queue of short
    ones.
  * ``round_robin`` — the classic strawman, kept for comparisons.
  * ``prefix_affinity`` — score each eligible replica by how many of the
    request's leading prompt tokens its shared KV pool already holds
    (`ServeSession.prefix_lookup`, a peek into the engine's prefix trie)
    and keep only the best scorers; ties — including the no-hit-anywhere
    case — fall back to ``least_eta`` ordering.  Steering same-header
    requests (per-tier system prompts, few-shot preambles) to the replica
    that already prefilled the header turns the kv-pool's block sharing
    into a fleet-level win: the suffix-only prefill happens where the
    prefix lives.
  * ``slo_tiered`` — generation-aware tiering for heterogeneous fleets:
    batch-tier requests (TTFT deadline above ``slo_fast_ttft_s``) prefer a
    strictly-slower pool when one exists (old silicon earns its power bill
    on deadline-insensitive work, and the fast pool keeps headroom for the
    latency tier); tight-SLO requests consider every replica but the
    speed-aware ``least_eta`` ranking pulls them onto the fastest silicon
    whenever it has headroom.  On a homogeneous fleet every replica is the
    same speed and the policy degenerates to ``least_eta``.

Admission backpressure: a replica whose engine already holds
``max_queue_per_replica`` unfinished requests is not eligible; when no
replica is eligible the router returns None and the service parks the
request in its bounded wait queue (beyond that, requests are *dropped* and
reported — open-loop traffic does not magically slow down because the fleet
is full).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.fleet.replica import ServeReplica
from repro.fleet.traffic import FleetRequest
from repro.obs import Telemetry

POLICIES = ("least_loaded", "least_eta", "round_robin", "prefix_affinity",
            "slo_tiered")


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    policy: str = "least_loaded"
    max_queue_per_replica: int = 16     # unfinished requests per engine
    default_chunk_s: float = 0.05       # ETA prior before latency samples
    # slo_tiered: requests with a TTFT SLO above this bound are batch-tier
    # and prefer the slower/cheaper pool when one exists; at or under it
    # they ride the speed-aware least-ETA ranking (fast silicon first)
    slo_fast_ttft_s: float = 1.0

    def __post_init__(self):
        assert self.policy in POLICIES, self.policy
        assert self.max_queue_per_replica >= 1


class Router:
    """Dispatch fleet requests into replica engines under one policy
    (`least_loaded` by owed tokens, `least_eta` by queue-aware expected
    TTFT, or `round_robin`), with per-replica queue bounds as the
    backpressure surface."""

    def __init__(self, cfg: Optional[RouterConfig] = None,
                 obs: Optional[Telemetry] = None):
        self.cfg = cfg or RouterConfig()
        # routing counters live in the metrics registry; the old attribute
        # names (routed/rerouted/prefix_hits/prefix_misses) are property
        # views below, so existing readers are unchanged
        self.obs = obs if obs is not None else Telemetry()
        reg = self.obs.metrics
        self._c_routed = reg.counter("fleet.routed")
        self._c_rerouted = reg.counter("fleet.rerouted")
        self._c_hits = reg.counter("fleet.prefix_lookups", outcome="hit")
        self._c_misses = reg.counter("fleet.prefix_lookups", outcome="miss")
        self._rr = 0

    @property
    def routed(self) -> int:
        return self._c_routed.value

    @property
    def rerouted(self) -> int:
        """Migration re-dispatches."""
        return self._c_rerouted.value

    @property
    def prefix_hits(self) -> int:
        """Routed to a replica already holding a shared prefix."""
        return self._c_hits.value

    @property
    def prefix_misses(self) -> int:
        """No replica held any of the request's prefix."""
        return self._c_misses.value

    def eligible(self, replicas: List[ServeReplica]) -> List[ServeReplica]:
        """Replicas that may accept new work (accepting state and below
        the per-replica queue bound)."""
        return [r for r in replicas
                if r.accepting and r.depth < self.cfg.max_queue_per_replica]

    def pick(self, replicas: List[ServeReplica], now: float,
             req: Optional[FleetRequest] = None) -> Optional[ServeReplica]:
        """Choose a replica for the next request, or None (backpressure)."""
        cands = self.eligible(replicas)
        if not cands:
            return None
        if self.cfg.policy == "round_robin":
            chosen = cands[self._rr % len(cands)]
            self._rr += 1
            return chosen
        if self.cfg.policy == "prefix_affinity" and req is not None:
            # peek every candidate's prefix trie; a strict-positive best
            # score narrows the field to the replicas already holding the
            # longest shared prefix, then ETA ordering breaks ties
            scores = [getattr(r.session, "prefix_lookup",
                              lambda _p: 0)(req.prompt) for r in cands]
            best = max(scores)
            if best > 0:
                self._c_hits.inc()
                cands = [r for r, s in zip(cands, scores) if s == best]
            else:
                self._c_misses.inc()
        if self.cfg.policy == "slo_tiered" and req is not None \
                and req.ttft_slo_s > self.cfg.slo_fast_ttft_s:
            # batch-tier traffic yields the fast silicon: prefer a strictly
            # slower pool when one exists, so old machines earn their power
            # bill on deadline-insensitive work and the fast pool keeps
            # headroom for the latency tier.  Tight-SLO requests are NOT
            # hard-pinned to the fastest generation — the ETA ranking below
            # already divides by replica speed, so they gravitate to fast
            # silicon when it has headroom but can overflow to slower
            # replicas instead of queueing behind each other at peak.
            speeds = [getattr(r, "speed", 1.0) for r in cands]
            slow = [r for r, s in zip(cands, speeds) if s < max(speeds)]
            if slow:
                cands = slow
        if self.cfg.policy in ("least_eta", "prefix_affinity", "slo_tiered"):
            # price fresh replicas with the fleet-wide observed chunk cost,
            # not the static prior — otherwise a cold (sample-free) replica
            # can rank worse than a warm loaded one by prior mismatch alone
            emas = [e for e in (r.session.chunk_time_ema(0.0)
                                for r in cands if r.alive) if e > 0.0]
            prior = (sum(emas) / len(emas)) if emas \
                else self.cfg.default_chunk_s
            return min(cands, key=lambda r: (r.eta_s(now, prior), r.rep_id))
        return min(cands, key=lambda r: (
            r.tokens_owed(), r.depth, r.rep_id))

    def route(self, req: FleetRequest, replicas: List[ServeReplica],
              now: float) -> Optional[ServeReplica]:
        """Dispatch `req` to the chosen replica; None means backpressure."""
        chosen = self.pick(replicas, now, req)
        if chosen is None:
            return None
        chosen.dispatch(req)
        self._c_routed.inc()
        if req.migrations:
            self._c_rerouted.inc()
        tr = self.obs.tracer
        if tr.enabled:
            tr.event("req.route", cat="request",
                     track=f"replica:{chosen.rep_id}", t=now,
                     fid=req.fid, migrations=req.migrations)
        return chosen
