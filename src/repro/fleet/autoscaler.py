"""Elastic multi-slice autoscaling: right-size the replica pool on demand.

The paper's §2 claim is that OCS reconfiguration lets one machine carve out
right-sized slices in seconds; this controller exercises exactly that —
watching queue backlog and the observed p95 TTFT, allocating a new slice
through `Supercomputer.allocate` when the fleet falls behind and *draining*
a replica (serve out its work, then `Slice.free`) when capacity idles.

Decisions are deliberately boring: per-live-replica backlog watermarks with
a cooldown, plus an optional p95-TTFT target.  ``scale_to_zero`` lets the
pool drain entirely between bursts (min_replicas=0), paying the provisioning
latency on the next arrival — the classic serverless trade.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.fleet.replica import ACTIVE, DRAINING, PROVISIONING, ServeReplica


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 4
    tick_s: float = 0.25                # virtual seconds between decisions
    cooldown_s: float = 1.0             # between scaling actions
    scale_up_backlog: float = 4.0       # queued requests per live replica
    scale_down_backlog: float = 0.75
    target_p95_ttft_s: Optional[float] = None   # scale up when breached
    provision_s: float = 0.25           # virtual slice bring-up latency
    scale_to_zero: bool = False

    def __post_init__(self):
        assert 0 <= self.min_replicas <= self.max_replicas
        assert self.scale_down_backlog < self.scale_up_backlog


class Autoscaler:
    """Backlog/p95-watermark controller deciding scale-ups and drains.
    Pure policy: `decide` returns an action, the `FleetService` executes
    it (allocation, drain bookkeeping, cooldown recording)."""

    def __init__(self, cfg: Optional[AutoscalerConfig] = None):
        self.cfg = cfg or AutoscalerConfig()
        self.last_action_t = float("-inf")
        self.scale_ups = 0
        self.scale_downs = 0

    def decide(self, now: float, replicas: List[ServeReplica],
               wait_len: int, p95_ttft_s: Optional[float]
               ) -> Tuple[str, Optional[ServeReplica]]:
        """One control tick.  Returns ("up", None), ("down", replica-to-
        drain), or ("hold", None).  The service executes the action (it owns
        the Supercomputer and the drain bookkeeping)."""
        cfg = self.cfg
        live = [r for r in replicas if r.state in (PROVISIONING, ACTIVE)]
        backlog = wait_len + sum(r.depth for r in live)

        # the pool floor: with scale_to_zero the down-rule may empty the
        # pool, so the grow rule must use the SAME floor — otherwise the
        # two rules oscillate allocate/free forever on an idle fleet
        floor = 0 if cfg.scale_to_zero else cfg.min_replicas
        # below the floor (or scale-from-zero with work waiting): grow
        # unconditionally — cooldown must not wedge an empty pool
        if len(live) < floor or (not live and backlog > 0):
            return "up", None

        in_cooldown = now - self.last_action_t < cfg.cooldown_s
        per = backlog / max(1, len(live))
        breached = (cfg.target_p95_ttft_s is not None
                    and p95_ttft_s is not None
                    and p95_ttft_s > cfg.target_p95_ttft_s)
        if ((per > cfg.scale_up_backlog or breached)
                and len(live) < cfg.max_replicas and not in_cooldown):
            return "up", None

        if (len(live) > floor and not in_cooldown and not breached
                and per < cfg.scale_down_backlog):
            idle = [r for r in live if r.state == ACTIVE]
            if idle:
                victim = min(idle, key=lambda r: (r.depth, r.tokens_owed(),
                                                  r.rep_id))
                return "down", victim
        return "hold", None

    def record(self, action: str, now: float) -> None:
        """Note an executed action (starts the cooldown, bumps counters)."""
        self.last_action_t = now
        if action == "up":
            self.scale_ups += 1
        elif action == "down":
            self.scale_downs += 1
