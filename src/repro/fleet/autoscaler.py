"""Elastic multi-slice autoscaling: right-size the replica pool on demand.

The paper's §2 claim is that OCS reconfiguration lets one machine carve out
right-sized slices in seconds; this controller exercises exactly that —
watching queue backlog and the observed p95 TTFT, allocating a new slice
through `Supercomputer.allocate` when the fleet falls behind and *draining*
a replica (serve out its work, then `Slice.free`) when capacity idles.

Decisions are deliberately boring: per-live-replica backlog watermarks with
a cooldown, plus an optional p95-TTFT target.  ``scale_to_zero`` lets the
pool drain entirely between bursts (min_replicas=0), paying the provisioning
latency on the next arrival — the classic serverless trade.

**Predictive pre-provisioning** (`ForecastConfig`): production fleets serve
diurnal traffic whose peaks are *known* — reacting after the backlog builds
means every burst edge eats one provisioning latency of degraded TTFT.  The
`RateForecaster` bins the observed arrival stream and extrapolates the rate
one provisioning lead ahead (periodic fold when the diurnal period is
known, persistence otherwise); the controller converts that to a replica
target via the fleet's *measured* per-replica service rate and provisions
ahead of the rise, bypassing the reactive cooldown (a scheduled ramp is not
flapping).  The same forecast suppresses scale-downs into a predicted peak.
When the forecast abstains (cold start) or underpredicts (traffic deviates
from pattern), the reactive watermarks still fire — prediction only ever
*adds* capacity earlier, so a wrong forecast degrades to the reactive
controller, never below it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.fleet.replica import ACTIVE, DRAINING, PROVISIONING, ServeReplica


@dataclasses.dataclass(frozen=True)
class ForecastConfig:
    """Knobs of the arrival-rate forecaster.

    ``period_s`` is the operator's knowledge ("our traffic is daily"): with
    it, the forecaster folds history modulo the period and predicts from
    the same phase of past cycles — after one full period it sees every
    peak coming.  Without it, the forecast is persistence (recent windowed
    rate), which still pre-provisions into sustained ramps but cannot
    anticipate a phase change."""
    bin_s: float = 0.25             # arrival-history bin width (virtual s)
    period_s: Optional[float] = None    # known traffic period (None = no fold)
    lead_s: Optional[float] = None  # look-ahead; None = provision_s + tick_s
    safety: float = 1.15            # over-provision factor on predicted rate
    min_history_s: float = 1.0      # abstain (reactive only) before this
    recent_window_s: float = 1.0    # persistence-forecast averaging window

    def __post_init__(self):
        assert self.bin_s > 0 and self.safety > 0
        assert self.period_s is None or self.period_s > self.bin_s


class RateForecaster:
    """Binned arrival-rate history + short-horizon extrapolation.

    `observe` is O(1) per arrival (a counter bump into the bin of the
    arrival's virtual time); `forecast_peak` returns the predicted PEAK
    arrival rate over a look-ahead window, or None when history is too
    short to say anything — the caller treats None as "fall back to the
    reactive watermarks"."""

    def __init__(self, cfg: Optional[ForecastConfig] = None):
        self.cfg = cfg or ForecastConfig()
        self._bins: List[int] = []
        self._t_last = 0.0

    def observe(self, t: float) -> None:
        """Record one arrival at virtual time ``t``."""
        i = int(t / self.cfg.bin_s)
        if i >= len(self._bins):
            self._bins.extend([0] * (i + 1 - len(self._bins)))
        self._bins[i] += 1
        self._t_last = max(self._t_last, t)

    def _rate(self, i: int) -> float:
        if 0 <= i < len(self._bins):
            return self._bins[i] / self.cfg.bin_s
        return 0.0

    def _mean_rate(self, t0: float, t1: float) -> float:
        b = self.cfg.bin_s
        i0, i1 = int(t0 / b), max(int(t0 / b), int(math.ceil(t1 / b)) - 1)
        rates = [self._rate(i) for i in range(i0, i1 + 1)]
        return sum(rates) / max(1, len(rates))

    def forecast_peak(self, now: float, t0: float, t1: float
                      ) -> Optional[float]:
        """Predicted peak arrival rate over virtual window ``[t0, t1]``.

        With a known ``period_s`` and at least one full period of history,
        each future bin is predicted as the average of the SAME phase in
        every complete past cycle, and the window's max is returned (peaks
        matter for capacity; means under-provision the edge).  Otherwise a
        persistence forecast: the mean rate over the trailing
        ``recent_window_s`` (excluding the partially-filled current bin)."""
        cfg = self.cfg
        if now < cfg.min_history_s:
            return None
        if cfg.period_s is not None and now >= cfg.period_s:
            peak = 0.0
            b = cfg.bin_s
            n_bins = max(1, int(math.ceil((t1 - t0) / b)))
            for j in range(n_bins):
                c = t0 + (j + 0.5) * b
                vals = []
                back = c - cfg.period_s
                while back >= 0.0:
                    # only completed past bins vote (the bin containing
                    # `now` is still filling and would bias the phase low)
                    if back < now - b:
                        vals.append(self._rate(int(back / b)))
                    back -= cfg.period_s
                if vals:
                    peak = max(peak, sum(vals) / len(vals))
            return peak if peak > 0.0 else None
        cut = int(now / cfg.bin_s) * cfg.bin_s   # start of the current bin
        return self._mean_rate(max(0.0, cut - cfg.recent_window_s), cut)


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 4
    tick_s: float = 0.25                # virtual seconds between decisions
    cooldown_s: float = 1.0             # between scaling actions
    scale_up_backlog: float = 4.0       # queued requests per live replica
    scale_down_backlog: float = 0.75
    target_p95_ttft_s: Optional[float] = None   # scale up when breached
    provision_s: float = 0.25           # virtual slice bring-up latency
    scale_to_zero: bool = False

    def __post_init__(self):
        assert 0 <= self.min_replicas <= self.max_replicas
        assert self.scale_down_backlog < self.scale_up_backlog


class Autoscaler:
    """Backlog/p95-watermark controller deciding scale-ups and drains,
    optionally fronted by a `RateForecaster` for predictive
    pre-provisioning.  Pure policy: `decide` returns an action, the
    `FleetService` executes it (allocation, drain bookkeeping, cooldown
    recording)."""

    def __init__(self, cfg: Optional[AutoscalerConfig] = None,
                 forecast: Optional[ForecastConfig] = None):
        self.cfg = cfg or AutoscalerConfig()
        self.forecaster = RateForecaster(forecast) if forecast else None
        self.last_action_t = float("-inf")
        self.scale_ups = 0
        self.scale_downs = 0
        self.predictive_ups = 0
        self.predicted_rate: Optional[float] = None   # last forecast (rps)
        self._pending_predictive = False

    def observe_arrival(self, t: float) -> None:
        """Feed one arrival into the forecaster (no-op when reactive)."""
        if self.forecaster is not None:
            self.forecaster.observe(t)

    def _replica_target(self, now: float, capacity_rps: Optional[float],
                        floor: int) -> Optional[int]:
        """Forecast-implied pool size: predicted peak rate over the next
        provisioning lead, with safety margin, divided by the measured
        per-replica service rate.  None = no forecast (cold start /
        reactive mode / no throughput measurement yet)."""
        if self.forecaster is None or not capacity_rps:
            return None
        fcfg = self.forecaster.cfg
        lead = (fcfg.lead_s if fcfg.lead_s is not None
                else self.cfg.provision_s + self.cfg.tick_s)
        pred = self.forecaster.forecast_peak(now, now, now + lead)
        self.predicted_rate = pred
        if pred is None:
            return None
        want = int(math.ceil(pred * fcfg.safety / capacity_rps))
        return max(floor, min(self.cfg.max_replicas, want))

    def decide(self, now: float, replicas: List[ServeReplica],
               wait_len: int, p95_ttft_s: Optional[float], *,
               capacity_rps: Optional[float] = None
               ) -> Tuple[str, Optional[ServeReplica]]:
        """One control tick.  Returns ("up", None), ("down", replica-to-
        drain), or ("hold", None).  The service executes the action (it owns
        the Supercomputer and the drain bookkeeping).

        ``capacity_rps`` is the service's measured per-replica request
        service rate — the unit that converts a forecast (requests/s) into
        a pool size.  Without it prediction abstains."""
        cfg = self.cfg
        live = [r for r in replicas if r.state in (PROVISIONING, ACTIVE)]
        backlog = wait_len + sum(r.depth for r in live)

        # the pool floor: with scale_to_zero the down-rule may empty the
        # pool, so the grow rule must use the SAME floor — otherwise the
        # two rules oscillate allocate/free forever on an idle fleet
        floor = 0 if cfg.scale_to_zero else cfg.min_replicas
        # below the floor (or scale-from-zero with work waiting): grow
        # unconditionally — cooldown must not wedge an empty pool
        if len(live) < floor or (not live and backlog > 0):
            return "up", None

        want = self._replica_target(now, capacity_rps, floor)
        if want is not None and len(live) < want:
            # predictive pre-provision: a scheduled ramp toward a known
            # peak bypasses the reactive cooldown (one replica per tick)
            self._pending_predictive = True
            return "up", None

        in_cooldown = now - self.last_action_t < cfg.cooldown_s
        per = backlog / max(1, len(live))
        breached = (cfg.target_p95_ttft_s is not None
                    and p95_ttft_s is not None
                    and p95_ttft_s > cfg.target_p95_ttft_s)
        if ((per > cfg.scale_up_backlog or breached)
                and len(live) < cfg.max_replicas and not in_cooldown):
            return "up", None

        if (len(live) > floor and not in_cooldown and not breached
                and per < cfg.scale_down_backlog
                and (want is None or len(live) > want)):
            # the `want` clause holds capacity through a predicted peak:
            # an idle pool is not surplus if the forecast says the rate is
            # about to need it
            idle = [r for r in live if r.state == ACTIVE]
            if idle:
                # generation-aware drain: among equally-idle replicas, shed
                # the worst perf/Watt silicon first (`drain_rank` is the
                # replica's generation perf/Watt; 0.0 everywhere — the
                # homogeneous fleet — leaves the legacy rep_id ordering)
                victim = min(idle, key=lambda r: (
                    r.depth, r.tokens_owed(),
                    getattr(r, "drain_rank", 0.0), r.rep_id))
                return "down", victim
        return "hold", None

    def record(self, action: str, now: float) -> None:
        """Note an executed action (starts the cooldown, bumps counters)."""
        self.last_action_t = now
        if action == "up":
            self.scale_ups += 1
            if self._pending_predictive:
                self.predictive_ups += 1
        elif action == "down":
            self.scale_downs += 1
        self._pending_predictive = False
