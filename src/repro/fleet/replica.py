"""One serve replica: a `Slice` + `ServeSession` + virtual-time accounting.

The fleet models one 4096-chip machine carved into many serving slices.
Each replica's compute is REAL (`ServeEngine.step_chunk` runs the PR-3 fast
path and decodes actual tokens); its *time* is virtual: a chunk costs its
measured wall latency (or a fixed ``chunk_s`` in deterministic mode), and
replicas overlap on the fleet clock because they are independent slices of
the machine — the container merely serializes what the hardware would run
in parallel.  Reconfiguration downtime (`SliceEvent.downtime_s` from a
spare-swap) is charged to the replica's clock the next time it steps.

Lifecycle::

    provisioning --ready_at--> active --drain--> draining --empty--> freed
                                  \\--fail_block, no spare--> dead

A dead replica's unfinished requests are evacuated (`evacuate`) and
re-routed by the service; a draining replica keeps decoding but accepts no
new work, and is only freed once it owes nothing — `free` enforces that
invariant with a hard error rather than trusting the caller.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.cluster.slices import ServeSession, Slice, SliceEvent
from repro.cluster.straggler import StragglerDetector
from repro.fleet.traffic import FleetRequest
from repro.obs import NOOP_TRACER

PROVISIONING = "provisioning"
ACTIVE = "active"
DRAINING = "draining"
DEAD = "dead"
FREED = "freed"


class ReplicaError(RuntimeError):
    """Illegal replica lifecycle operation (e.g. freeing with work owed)."""


class ServeReplica:
    def __init__(self, rep_id: int, slice_: Slice, session: ServeSession, *,
                 now: float, provision_s: float = 0.0,
                 chunk_s: Optional[float] = None,
                 straggler: Optional[StragglerDetector] = None,
                 tracer=NOOP_TRACER,
                 speed: float = 1.0, watts: float = 0.0,
                 dollars_per_h: float = 0.0, gen: str = "",
                 drain_rank: float = 0.0):
        self.rep_id = rep_id
        self.slice = slice_
        self.session = session
        self.tracer = tracer                # fleet tracer (virtual time)
        self.track = f"replica:{rep_id}"
        self.state = PROVISIONING if provision_s > 0 else ACTIVE
        self.ready_at = now + provision_s
        self.busy_until = self.ready_at
        self.chunk_s = chunk_s              # None = measure real wall time
        self.straggler = straggler          # per-replica detector (optional)
        self.straggler_swaps = 0
        # generation economics (heterogeneous fleet): chunk latency divides
        # by ``speed`` (fig12 perf factor relative to the service's
        # reference machine; 1.0 = homogeneous fleet, bitwise-unchanged),
        # ``watts``/``dollars_per_h`` price the slice's allocated lifetime,
        # and ``drain_rank`` orders scale-down victims (worst perf/Watt
        # drains first; 0.0 everywhere preserves the legacy ordering)
        self.speed = speed
        self.watts = watts
        self.dollars_per_h = dollars_per_h
        self.gen = gen
        self.drain_rank = drain_rank
        self.t_alloc = now
        self.t_end: Optional[float] = None  # stamped at free/death
        # engine rid -> (fleet request, out_tokens length at dispatch,
        #               engine request)
        self._assigned: Dict[int, Tuple[FleetRequest, int, object]] = {}
        self._stall_seen = 0.0
        self.tokens_served = 0
        self.chunks_run = 0
        self.busy_s = 0.0
        self.truncated_migrations = 0
        self._final_stats: Optional[Dict[str, object]] = None
        session.add_listener(self._on_event)

    def __repr__(self):
        return (f"ServeReplica({self.rep_id}, {self.state}, "
                f"depth={self.depth}, job{self.slice.job_id})")

    # -- event propagation (the SliceEvent path from `fail_block`) ------------

    def _on_event(self, _session, ev: SliceEvent) -> None:
        if ev.kind == "lost":
            self.state = DEAD
        # "reconfigure" downtime lands via the session's stall_s accumulator,
        # charged to the virtual clock on the next step.

    # -- routing surface ------------------------------------------------------

    @property
    def alive(self) -> bool:
        return self.state in (PROVISIONING, ACTIVE, DRAINING)

    @property
    def accepting(self) -> bool:
        """Can the router send new work here?  Provisioning replicas accept
        (requests queue while the slice warms); draining/dead ones do not."""
        return self.state in (PROVISIONING, ACTIVE)

    @property
    def depth(self) -> int:
        """Requests this replica still owes tokens to."""
        return self.session.depth if self.alive else len(self._assigned)

    @property
    def inflight(self) -> int:
        return sum(1 for fr, _, _ in self._assigned.values()
                   if fr.status == "queued")

    def tokens_owed(self) -> int:
        return self.session.tokens_owed()

    def eta_s(self, now: float, default_chunk_s: float = 0.05) -> float:
        """Expected TTFT for the next request routed here: the engine's
        queue-aware estimate, plus any remaining provisioning delay and the
        tail of the chunk currently in flight.  In deterministic mode the
        fixed virtual chunk cost prices the estimate — the engine's real
        (wall-clock) latencies would be inconsistent with the fleet clock."""
        start_delay = max(0.0, self.ready_at - now, self.busy_until - now)
        return start_delay + self.session.expected_ttft_s(
            default_chunk_s / self.speed, chunk_time_s=self.virtual_chunk_s)

    @property
    def virtual_chunk_s(self) -> Optional[float]:
        """Deterministic-mode chunk cost on THIS replica's generation (the
        fleet-wide ``chunk_s`` divided by the generation speed factor)."""
        return None if self.chunk_s is None else self.chunk_s / self.speed

    def energy_wh(self, now: float) -> float:
        """Energy charged to this replica: allocated-lifetime Wh (a held
        slice burns power whether busy or idle — that is why perf/Watt
        placement matters)."""
        end = self.t_end if self.t_end is not None else now
        return self.watts * max(0.0, end - self.t_alloc) / 3600.0

    def cost_usd(self, now: float) -> float:
        """Dollar cost of this replica's allocated lifetime."""
        end = self.t_end if self.t_end is not None else now
        return self.dollars_per_h * max(0.0, end - self.t_alloc) / 3600.0

    # -- dispatch / step ------------------------------------------------------

    def dispatch(self, req: FleetRequest) -> None:
        """Hand one fleet request to this replica's engine.  A migrated
        request re-prefills its original prompt *plus* every token already
        decoded elsewhere, and only owes the remainder.

        The engine's prefill window is ``spec.prompt_len`` wide, so the
        continuation is conditioned on the last ``prompt_len`` tokens of
        (prompt + decoded) — bitwise-lossless whenever the combined context
        fits the window (size ``prompt_len`` generously for that), a
        sliding-window re-prefill otherwise (counted in
        ``truncated_migrations``)."""
        if not self.accepting:
            raise ReplicaError(f"replica {self.rep_id} is {self.state}")
        prompt = req.prompt
        if req.out_tokens:
            prompt = np.concatenate(
                [req.prompt, np.asarray(req.out_tokens, np.int32)])
            if len(prompt) > self.session.spec.prompt_len:
                self.truncated_migrations += 1
        er = self.session.submit(prompt,
                                 max_new_tokens=req.remaining_tokens)
        self._assigned[er.rid] = (req, len(req.out_tokens), er)
        req.status = "queued"
        req.replicas.append(self.rep_id)

    def runnable(self, now: float) -> bool:
        """Ready to start a chunk at virtual time `now`?"""
        return (self.state in (ACTIVE, DRAINING)
                and self.ready_at <= now and self.busy_until <= now
                and self.session.depth > 0)

    def next_start(self) -> Optional[float]:
        """Earliest virtual time this replica could start its next chunk,
        or None if it has nothing to run."""
        if self.state not in (ACTIVE, DRAINING, PROVISIONING):
            return None
        if self.session.depth == 0:
            return None
        return max(self.ready_at, self.busy_until)

    def step(self, now: float) -> List[FleetRequest]:
        """Run ONE real admission+decode chunk; charge its latency (measured
        or fixed, dragged by the slice's slowest block — a synchronous step
        finishes when the last block does) plus any pending reconfiguration
        stall to the virtual clock.  Returns the fleet requests that
        completed in this chunk, stamped with virtual times."""
        t0 = time.perf_counter()
        self.session.step_chunk()
        base = (time.perf_counter() - t0 if self.chunk_s is None
                else self.chunk_s) / self.speed
        lat = base * self.slice.slowdown_factor()
        self._maybe_swap_straggler(base)
        stall = self.session.stall_s - self._stall_seen
        self._stall_seen = self.session.stall_s
        end = now + lat + stall
        self.busy_until = end
        self.busy_s += lat + stall
        self.chunks_run += 1
        if self.tracer.enabled:
            # the chunk's virtual interval, known only after the fact —
            # the explicit-timestamp form exists for exactly this
            self.tracer.complete("replica.chunk", now, end, cat="serve",
                                 track=self.track, stall_s=stall)
        return self._harvest(end)

    def _maybe_swap_straggler(self, base_s: float) -> None:
        """Feed this chunk's modeled per-block times to the detector; when
        it confirms a straggler AND the recovered time pays for the
        reconfiguration blackout, swap the block.  The `SliceEvent`'s
        downtime lands in the session's stall clock and is charged on this
        very step."""
        det = self.straggler
        if det is None or self.state not in (ACTIVE, DRAINING):
            return
        blk = det.observe(self.slice.block_times(base_s))
        if blk is None:
            return
        if not det.worth_swapping(blk, base_s, self.slice.swap_cost_s(blk)):
            return
        if self.slice.swap_straggler(blk) is not None:
            det.fired(blk)
            self.straggler_swaps += 1

    def _harvest(self, t: float) -> List[FleetRequest]:
        """Sync engine progress into the fleet requests after a chunk."""
        finished: List[FleetRequest] = []
        for rid in list(self._assigned):
            req, base, er = self._assigned[rid]
            if len(er.out_tokens) > len(req.out_tokens) - base:
                new = er.out_tokens[len(req.out_tokens) - base:]
                req.out_tokens.extend(int(x) for x in new)
                self.tokens_served += len(new)
            if req.t_first is None and er.out_tokens:
                req.t_first = t
            if er.done:
                req.status = "done"
                req.t_done = t
                if self.tracer.enabled:
                    self.tracer.complete(
                        "req.lifetime", req.t_arrival, t, cat="request",
                        track=self.track, fid=req.fid,
                        migrations=req.migrations)
                finished.append(req)
                del self._assigned[rid]
        return finished

    # -- drain / death / free -------------------------------------------------

    def drain(self) -> None:
        if self.state in (PROVISIONING, ACTIVE):
            self.state = DRAINING
            self.session.drain()

    def undrain(self) -> None:
        """Cancel a drain (the autoscaler reuses a draining replica instead
        of paying a fresh provision when load returns)."""
        if self.state == DRAINING:
            self.state = ACTIVE
            self.session.undrain()

    @property
    def drained(self) -> bool:
        return self.state == DRAINING and self.session.is_drained

    def evacuate(self) -> List[FleetRequest]:
        """Pull every unfinished request off this replica (after its slice
        died): engine state is exported, fleet bookkeeping is synced, and the
        requests go back to the router with their decoded-so-far tokens as
        re-prefill context."""
        exported = self.session.export_inflight()
        exported_rids = {er.rid for er in exported}
        orphans: List[FleetRequest] = []
        for rid in list(self._assigned):
            req, base, er = self._assigned[rid]
            if rid not in exported_rids:
                continue
            # tokens decoded before death are kept — the survivor re-prefills
            # them instead of re-serving them
            got = len(req.out_tokens) - base
            if len(er.out_tokens) > got:
                req.out_tokens.extend(
                    int(x) for x in er.out_tokens[got:])
            req.status = "pending"
            req.migrations += 1
            orphans.append(req)
            del self._assigned[rid]
        return orphans

    def free(self) -> None:
        """Release the slice back to the machine.  Refuses while any request
        is still owed tokens — the autoscaler must drain first."""
        if self._assigned or (self.alive and self.session.depth):
            raise ReplicaError(
                f"replica {self.rep_id} still owes work "
                f"({len(self._assigned)} assigned); drain before free")
        if self.state != DEAD:
            self.slice.free()
        self.state = FREED

    def retire(self) -> None:
        """Drop the session/slice/engine references once this replica is
        FREED or DEAD: a long-lived service keeps retired replicas for
        their stats only, and must not pin each one's device KV cache."""
        assert self.state in (FREED, DEAD), self.state
        assert not self._assigned, "retire() before evacuation/drain"
        self._final_stats = self.stats()
        self.session = None
        self.slice = None

    def stats(self) -> Dict[str, object]:
        if self._final_stats is not None:
            return self._final_stats
        end = self.t_end if self.t_end is not None else self.t_alloc
        out = {
            "rep_id": self.rep_id,
            "state": self.state,
            "tokens_served": self.tokens_served,
            "chunks_run": self.chunks_run,
            "busy_s": round(self.busy_s, 4),
            "truncated_migrations": self.truncated_migrations,
            "straggler_swaps": self.straggler_swaps,
            "gen": self.gen,
            "speed": round(self.speed, 4),
            "watts": round(self.watts, 2),
            "energy_wh": round(self.energy_wh(end), 6),
            "cost_usd": round(self.cost_usd(end), 8),
        }
        eng = getattr(self.session, "engine", None)
        kv = eng.kv_stats() if eng is not None and hasattr(eng, "kv_stats") \
            else {}
        if kv:
            out.update({
                "prefill_flops_proxy": kv["prefill_flops_proxy"],
                "kv_prompt_tokens": kv["kv_prompt_tokens"],
                "kv_shared_tokens": kv["kv_shared_tokens"],
                "kv_migrated_shared_blocks": kv["kv_migrated_shared_blocks"],
                "kv_migrated_suffix_blocks": kv["kv_migrated_suffix_blocks"],
            })
        return out
