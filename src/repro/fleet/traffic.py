"""Open-loop traffic generation: millions of users, container-sized.

The fleet subsystem serves *offered* load — requests arrive on their own
clock whether or not the fleet keeps up (open-loop, the honest way to
measure serving systems; a closed loop would self-throttle and hide queueing
collapse).  `generate` turns a `TrafficSpec` into a deterministic arrival
trace of `FleetRequest`s:

  * **arrival process** — homogeneous Poisson ("poisson"), on/off modulated
    Poisson ("bursty": rate jumps `burst_x`-fold for `burst_len_s` every
    `burst_period_s`), or a smooth day-curve ("diurnal": sinusoid between
    trough and peak).  Non-constant rates are sampled by thinning, so every
    pattern is exact, not binned.
  * **mixed lengths** — prompt lengths are geometric-ish around a mean,
    output lengths drawn from a discrete mix (chat-short / completion-long),
    both clipped to the serving envelope.
  * **per-request SLOs** — each request carries a time-to-first-token
    deadline from its tier (interactive vs batch), so SLO attainment is a
    first-class fleet metric rather than an afterthought.

All timestamps are *virtual seconds* on the fleet clock (see
`fleet.service`): replicas are independent slices of the machine, so their
compute overlaps in virtual time even though the container serializes it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SLOTier:
    """One traffic class: a share of requests and its TTFT deadline."""
    name: str
    ttft_slo_s: float
    share: float


DEFAULT_TIERS: Tuple[SLOTier, ...] = (
    SLOTier("interactive", ttft_slo_s=0.5, share=0.7),
    SLOTier("batch", ttft_slo_s=4.0, share=0.3),
)


@dataclasses.dataclass(eq=False)
class FleetRequest:
    """One user request, tracked end-to-end across replicas.

    ``eq=False`` for the same reason as `serve.engine.Request`: identity
    semantics — the router moves these between queues and a value-`__eq__`
    over ndarray prompts would break membership tests.

    The lifecycle fields are owned by the fleet: ``status`` walks
    pending -> queued -> done (or dropped), ``replicas`` records every
    replica that held the request (len > 1 means it survived a failure or
    drain migration), and ``out_tokens`` accumulates across migrations —
    tokens decoded on a replica that later died are re-prefilled as context
    on the survivor, never re-served to the user twice.
    """
    fid: int
    t_arrival: float                    # virtual seconds
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int
    tier: str
    ttft_slo_s: float
    status: str = "pending"             # pending|queued|done|dropped
    replicas: List[int] = dataclasses.field(default_factory=list)
    migrations: int = 0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_first: Optional[float] = None     # virtual first-token time
    t_done: Optional[float] = None      # virtual completion time

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.t_first is None else self.t_first - self.t_arrival

    @property
    def met_slo(self) -> bool:
        t = self.ttft_s
        return t is not None and t <= self.ttft_slo_s

    @property
    def remaining_tokens(self) -> int:
        return max(0, self.max_new_tokens - len(self.out_tokens))


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Knobs of one offered-load scenario."""
    duration_s: float = 8.0
    rate_rps: float = 4.0               # mean request rate (base rate)
    pattern: str = "poisson"            # poisson | bursty | diurnal
    # bursty: rate jumps to burst_x * rate_rps for burst_len_s every period
    burst_x: float = 4.0
    burst_period_s: float = 4.0
    burst_len_s: float = 1.0
    # diurnal: sinusoid between trough_frac*peak and peak, peak = rate_rps
    trough_frac: float = 0.25
    diurnal_period_s: float = 8.0
    # request shapes
    prompt_len_mean: float = 8.0
    prompt_len_max: int = 16
    new_tokens_choices: Tuple[int, ...] = (8, 16, 32)
    new_tokens_weights: Tuple[float, ...] = (0.5, 0.35, 0.15)
    tiers: Tuple[SLOTier, ...] = DEFAULT_TIERS
    vocab_size: int = 256
    # shared-header mix (prefix-shared KV traffic): every request's prompt
    # opens with its TIER's fixed system-prompt header of ``header_len``
    # tokens, optionally followed by one of ``fewshot_pool`` fixed few-shot
    # preambles (``fewshot_len`` tokens, attached with ``fewshot_prob``),
    # then the per-request random tail of the usual geometric length.
    # header_len=0 (default) leaves the trace BYTE-IDENTICAL to the
    # header-free generator — the extra RNG draws are gated, not skipped.
    header_len: int = 0
    fewshot_len: int = 0
    fewshot_pool: int = 0
    fewshot_prob: float = 0.0

    def __post_init__(self):
        assert self.pattern in ("poisson", "bursty", "diurnal"), self.pattern
        assert abs(sum(t.share for t in self.tiers) - 1.0) < 1e-6, self.tiers
        assert len(self.new_tokens_choices) == len(self.new_tokens_weights)
        assert self.header_len >= 0 and self.fewshot_len >= 0
        assert 0.0 <= self.fewshot_prob <= 1.0
        if self.fewshot_prob > 0:
            assert self.fewshot_len > 0 and self.fewshot_pool > 0, \
                "fewshot_prob needs fewshot_len and fewshot_pool"

    def tier_header(self, tier_idx: int) -> np.ndarray:
        """The fixed ``header_len``-token system-prompt header of tier
        ``tier_idx`` — deterministic in (tier, vocab, length) alone, so
        every trace/seed over this spec shares the same headers (that IS
        the sharing opportunity the kv pool exploits)."""
        rng = np.random.default_rng((tier_idx + 1) * 7919)
        return rng.integers(0, self.vocab_size, size=self.header_len,
                            dtype=np.int32)

    def fewshot_block(self, block_idx: int) -> np.ndarray:
        """Fixed few-shot preamble ``block_idx`` (same determinism contract
        as ``tier_header``)."""
        rng = np.random.default_rng(104729 + block_idx)
        return rng.integers(0, self.vocab_size, size=self.fewshot_len,
                            dtype=np.int32)

    def rate_at(self, t: float) -> float:
        """Instantaneous arrival rate (requests/virtual-second) at time t."""
        if self.pattern == "poisson":
            return self.rate_rps
        if self.pattern == "bursty":
            phase = t % self.burst_period_s
            return (self.rate_rps * self.burst_x
                    if phase < self.burst_len_s else self.rate_rps)
        # diurnal: peak at period/2, trough at 0
        lo = self.rate_rps * self.trough_frac
        frac = 0.5 * (1.0 - np.cos(2 * np.pi * t / self.diurnal_period_s))
        return lo + (self.rate_rps - lo) * frac

    @property
    def rate_max(self) -> float:
        if self.pattern == "bursty":
            return self.rate_rps * self.burst_x
        return self.rate_rps

    def mean_offered_tokens_per_s(self) -> float:
        """Analytic mean decode-token demand (for capacity planning)."""
        mean_new = float(np.dot(self.new_tokens_choices,
                                self.new_tokens_weights))
        ts = np.linspace(0, self.duration_s, 257)
        mean_rate = float(np.mean([self.rate_at(t) for t in ts]))
        return mean_rate * mean_new


def generate(spec: TrafficSpec, seed: int = 0) -> List[FleetRequest]:
    """Sample one arrival trace: exact non-homogeneous Poisson via thinning.

    Deterministic in (spec, seed); requests come back sorted by arrival."""
    rng = np.random.default_rng(seed)
    lam_max = spec.rate_max
    headers = ([spec.tier_header(i) for i in range(len(spec.tiers))]
               if spec.header_len else [])
    fewshots = ([spec.fewshot_block(i) for i in range(spec.fewshot_pool)]
                if spec.header_len and spec.fewshot_pool else [])
    reqs: List[FleetRequest] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / lam_max))
        if t >= spec.duration_s:
            break
        if rng.random() * lam_max > spec.rate_at(t):
            continue                        # thinned out
        plen = int(np.clip(rng.geometric(1.0 / spec.prompt_len_mean),
                           2, spec.prompt_len_max))
        prompt = rng.integers(0, spec.vocab_size, size=plen,
                              dtype=np.int32)
        new = int(rng.choice(spec.new_tokens_choices,
                             p=np.asarray(spec.new_tokens_weights)
                             / sum(spec.new_tokens_weights)))
        tier_idx = int(rng.choice(
            len(spec.tiers), p=[ti.share for ti in spec.tiers]))
        tier = spec.tiers[tier_idx]
        if spec.header_len:
            parts = [headers[tier_idx]]
            if fewshots and rng.random() < spec.fewshot_prob:
                parts.append(fewshots[int(rng.integers(len(fewshots)))])
            parts.append(prompt)
            prompt = np.concatenate(parts)
        reqs.append(FleetRequest(
            fid=len(reqs), t_arrival=t, prompt=prompt, max_new_tokens=new,
            tier=tier.name, ttft_slo_s=tier.ttft_slo_s))
    return reqs


def uniform_burst(n: int, *, new_tokens: int = 16, prompt_len: int = 8,
                  ttft_slo_s: float = 10.0, vocab_size: int = 256,
                  seed: int = 0, t_arrival: float = 0.0
                  ) -> List[FleetRequest]:
    """N identical-shape requests arriving at once — the uniform closed
    batch used by the throughput-scaling gate and property tests."""
    rng = np.random.default_rng(seed)
    return [FleetRequest(
        fid=i, t_arrival=t_arrival,
        prompt=rng.integers(0, vocab_size, size=prompt_len, dtype=np.int32),
        max_new_tokens=new_tokens, tier="uniform", ttft_slo_s=ttft_slo_s)
        for i in range(n)]
