"""Open-loop traffic generation: millions of users, container-sized.

The fleet subsystem serves *offered* load — requests arrive on their own
clock whether or not the fleet keeps up (open-loop, the honest way to
measure serving systems; a closed loop would self-throttle and hide queueing
collapse).  `generate_trace` turns a `TrafficSpec` into a deterministic
structure-of-arrays `FleetTrace` (numpy columns: arrival time, tier,
prompt/output lengths, SLO deadline), and `generate` materializes it into
per-request `FleetRequest` objects for callers that want them:

  * **arrival process** — homogeneous Poisson ("poisson"), on/off modulated
    Poisson ("bursty": rate jumps `burst_x`-fold for `burst_len_s` every
    `burst_period_s`), or a smooth day-curve ("diurnal": sinusoid between
    trough and peak).  Non-constant rates are sampled by thinning, so every
    pattern is exact, not binned.
  * **mixed lengths** — prompt lengths are geometric-ish around a mean,
    output lengths drawn from a discrete mix (chat-short / completion-long),
    both clipped to the serving envelope.
  * **per-request SLOs** — each request carries a time-to-first-token
    deadline from its tier (interactive vs batch), so SLO attainment is a
    first-class fleet metric rather than an afterthought.

**Determinism layout.** Every column draws from its own counter-derived
PRNG substream (``default_rng([seed, column])``), and numpy fills arrays
element-by-element from the same bit stream a scalar loop would consume —
so the vectorized sampler and the retained per-request reference loop
(`generate_legacy`, the pre-vectorization generator kept as the
equivalence/speedup baseline) produce BITWISE-identical traces.  That pin
is what lets the fleet event loop trust `FleetTrace` at million-request
scale: same bits, ~100x+ cheaper.

All timestamps are *virtual seconds* on the fleet clock (see
`fleet.service`): replicas are independent slices of the machine, so their
compute overlaps in virtual time even though the container serializes it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

# substream indices of the per-column generators (default_rng([seed, k]))
_S_GAP, _S_THIN, _S_PLEN, _S_TOKENS, _S_NEW, _S_TIER, _S_FSU, _S_FSI = \
    range(8)


def _col_rng(seed: int, column: int) -> np.random.Generator:
    """The PRNG substream of one trace column: independent of every other
    column, shared bit-for-bit between the scalar reference loop and the
    vectorized sampler (array fills consume the stream element-by-element,
    exactly like repeated scalar draws)."""
    return np.random.default_rng([seed, column])


@dataclasses.dataclass(frozen=True)
class SLOTier:
    """One traffic class: a share of requests and its TTFT deadline."""
    name: str
    ttft_slo_s: float
    share: float


DEFAULT_TIERS: Tuple[SLOTier, ...] = (
    SLOTier("interactive", ttft_slo_s=0.5, share=0.7),
    SLOTier("batch", ttft_slo_s=4.0, share=0.3),
)


@dataclasses.dataclass(eq=False)
class FleetRequest:
    """One user request, tracked end-to-end across replicas.

    ``eq=False`` for the same reason as `serve.engine.Request`: identity
    semantics — the router moves these between queues and a value-`__eq__`
    over ndarray prompts would break membership tests.

    The lifecycle fields are owned by the fleet: ``status`` walks
    pending -> queued -> done (or dropped), ``replicas`` records every
    replica that held the request (len > 1 means it survived a failure or
    drain migration), and ``out_tokens`` accumulates across migrations —
    tokens decoded on a replica that later died are re-prefilled as context
    on the survivor, never re-served to the user twice.
    """
    fid: int
    t_arrival: float                    # virtual seconds
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int
    tier: str
    ttft_slo_s: float
    status: str = "pending"             # pending|queued|done|dropped
    replicas: List[int] = dataclasses.field(default_factory=list)
    migrations: int = 0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_first: Optional[float] = None     # virtual first-token time
    t_done: Optional[float] = None      # virtual completion time

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.t_first is None else self.t_first - self.t_arrival

    @property
    def met_slo(self) -> bool:
        t = self.ttft_s
        return t is not None and t <= self.ttft_slo_s

    @property
    def remaining_tokens(self) -> int:
        return max(0, self.max_new_tokens - len(self.out_tokens))


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Knobs of one offered-load scenario."""
    duration_s: float = 8.0
    rate_rps: float = 4.0               # mean request rate (base rate)
    pattern: str = "poisson"            # poisson | bursty | diurnal
    # bursty: rate jumps to burst_x * rate_rps for burst_len_s every period
    burst_x: float = 4.0
    burst_period_s: float = 4.0
    burst_len_s: float = 1.0
    # diurnal: sinusoid between trough_frac*peak and peak, peak = rate_rps
    trough_frac: float = 0.25
    diurnal_period_s: float = 8.0
    # request shapes
    prompt_len_mean: float = 8.0
    prompt_len_max: int = 16
    new_tokens_choices: Tuple[int, ...] = (8, 16, 32)
    new_tokens_weights: Tuple[float, ...] = (0.5, 0.35, 0.15)
    tiers: Tuple[SLOTier, ...] = DEFAULT_TIERS
    vocab_size: int = 256
    # shared-header mix (prefix-shared KV traffic): every request's prompt
    # opens with its TIER's fixed system-prompt header of ``header_len``
    # tokens, optionally followed by one of ``fewshot_pool`` fixed few-shot
    # preambles (``fewshot_len`` tokens, attached with ``fewshot_prob``),
    # then the per-request random tail of the usual geometric length.
    # header_len=0 (default) leaves the trace BYTE-IDENTICAL to the
    # header-free generator — the extra RNG draws are gated, not skipped.
    header_len: int = 0
    fewshot_len: int = 0
    fewshot_pool: int = 0
    fewshot_prob: float = 0.0

    def __post_init__(self):
        assert self.pattern in ("poisson", "bursty", "diurnal"), self.pattern
        assert abs(sum(t.share for t in self.tiers) - 1.0) < 1e-6, self.tiers
        assert len(self.new_tokens_choices) == len(self.new_tokens_weights)
        assert self.header_len >= 0 and self.fewshot_len >= 0
        assert 0.0 <= self.fewshot_prob <= 1.0
        if self.fewshot_prob > 0:
            assert self.fewshot_len > 0 and self.fewshot_pool > 0, \
                "fewshot_prob needs fewshot_len and fewshot_pool"

    def tier_header(self, tier_idx: int) -> np.ndarray:
        """The fixed ``header_len``-token system-prompt header of tier
        ``tier_idx`` — deterministic in (tier, vocab, length) alone, so
        every trace/seed over this spec shares the same headers (that IS
        the sharing opportunity the kv pool exploits)."""
        rng = np.random.default_rng((tier_idx + 1) * 7919)
        return rng.integers(0, self.vocab_size, size=self.header_len,
                            dtype=np.int32)

    def fewshot_block(self, block_idx: int) -> np.ndarray:
        """Fixed few-shot preamble ``block_idx`` (same determinism contract
        as ``tier_header``)."""
        rng = np.random.default_rng(104729 + block_idx)
        return rng.integers(0, self.vocab_size, size=self.fewshot_len,
                            dtype=np.int32)

    def rate_at(self, t: Union[float, np.ndarray]
                ) -> Union[float, np.ndarray]:
        """Instantaneous arrival rate (requests/virtual-second) at time
        ``t`` — a scalar, or an ndarray of times evaluated in one shot (the
        vectorized thinning path and capacity planners both use this; a
        million timestamps cost one ufunc sweep, not a Python loop)."""
        ts = np.asarray(t, dtype=np.float64)
        if self.pattern == "poisson":
            out = np.broadcast_to(np.float64(self.rate_rps), ts.shape)
        elif self.pattern == "bursty":
            phase = ts % self.burst_period_s
            out = np.where(phase < self.burst_len_s,
                           self.rate_rps * self.burst_x, self.rate_rps)
        else:
            # diurnal: peak at period/2, trough at 0
            lo = self.rate_rps * self.trough_frac
            frac = 0.5 * (1.0 - np.cos(2 * np.pi * ts
                                       / self.diurnal_period_s))
            out = lo + (self.rate_rps - lo) * frac
        if np.ndim(t) == 0:
            return float(out)
        return np.asarray(out, dtype=np.float64)

    @property
    def rate_max(self) -> float:
        if self.pattern == "bursty":
            return self.rate_rps * self.burst_x
        return self.rate_rps

    def mean_offered_tokens_per_s(self) -> float:
        """Analytic mean decode-token demand (for capacity planning)."""
        mean_new = float(np.dot(self.new_tokens_choices,
                                self.new_tokens_weights))
        ts = np.linspace(0, self.duration_s, 257)
        mean_rate = float(np.mean(self.rate_at(ts)))
        return mean_rate * mean_new

    def mean_new_tokens(self) -> float:
        """Mean decode tokens per request under the output-length mix."""
        w = np.asarray(self.new_tokens_weights, dtype=np.float64)
        return float(np.dot(self.new_tokens_choices, w / w.sum()))


@dataclasses.dataclass
class FleetTrace:
    """One arrival trace as a structure of arrays — the fleet-scale form.

    A million requests are eight numpy columns plus one flat token buffer,
    not a million Python objects: the router and `FleetService` consume the
    columns directly (cursor indexing, vectorized capacity math) and only
    materialize a `FleetRequest` view at dispatch time, when a request
    actually enters an engine.  ``materialize``/``request`` reproduce the
    per-object generator's output bitwise (see `generate_legacy`).

    Columns (all length n, sorted by arrival):
      t_arrival    f8  virtual arrival seconds
      tier_idx     i4  index into ``spec.tiers``
      ttft_slo_s   f8  per-request TTFT deadline (tier lookup, denormalized)
      new_tokens   i4  decode tokens owed
      prompt_len   i4  RANDOM-TAIL prompt length (header/few-shot excluded)
      prompt_off   i8  offset of the tail in ``tail_tokens``
      fewshot_idx  i4  attached few-shot preamble, -1 = none
      tail_tokens  i4  flat buffer of every request's random prompt tail
    """
    spec: TrafficSpec
    seed: int
    t_arrival: np.ndarray
    tier_idx: np.ndarray
    ttft_slo_s: np.ndarray
    new_tokens: np.ndarray
    prompt_len: np.ndarray
    prompt_off: np.ndarray
    fewshot_idx: np.ndarray
    tail_tokens: np.ndarray

    def __len__(self) -> int:
        return int(self.t_arrival.shape[0])

    @property
    def tokens_offered(self) -> int:
        """Total decode tokens the trace demands (vectorized sum)."""
        return int(self.new_tokens.sum())

    def prompt(self, i: int) -> np.ndarray:
        """Materialize request ``i``'s full prompt (header + optional
        few-shot preamble + random tail), exactly as the per-object
        generator would have built it."""
        off = int(self.prompt_off[i])
        tail = self.tail_tokens[off:off + int(self.prompt_len[i])]
        if self.spec.header_len:
            parts = [self.spec.tier_header(int(self.tier_idx[i]))]
            if self.fewshot_idx[i] >= 0:
                parts.append(self.spec.fewshot_block(
                    int(self.fewshot_idx[i])))
            parts.append(tail)
            return np.concatenate(parts)
        return tail.copy()

    def request(self, i: int) -> FleetRequest:
        """Materialize the `FleetRequest` view of row ``i`` (dispatch-time
        only — the event loop never builds objects for requests that have
        not arrived yet)."""
        ti = int(self.tier_idx[i])
        return FleetRequest(
            fid=i, t_arrival=float(self.t_arrival[i]),
            prompt=self.prompt(i),
            max_new_tokens=int(self.new_tokens[i]),
            tier=self.spec.tiers[ti].name,
            ttft_slo_s=float(self.ttft_slo_s[i]))

    def materialize(self) -> List[FleetRequest]:
        """Every row as a `FleetRequest` (small traces / compat callers)."""
        return [self.request(i) for i in range(len(self))]


def _arrival_times(spec: TrafficSpec, seed: int) -> np.ndarray:
    """Candidate arrival instants of the dominating homogeneous Poisson
    process, vectorized but bit-identical to a scalar ``t += exp()`` loop:
    gaps come from the gap substream in blocks, and the running time is a
    strictly sequential cumsum (same float-add association as the loop)."""
    lam = spec.rate_max
    rng = _col_rng(seed, _S_GAP)
    expect = lam * spec.duration_s
    block = max(256, int(expect + 4.0 * np.sqrt(expect)) + 64)
    out: List[np.ndarray] = []
    t_end = 0.0
    while t_end < spec.duration_s:
        gaps = rng.exponential(1.0 / lam, size=block)
        # cumsum over [t_end, g0, g1, ...] reproduces ((t_end+g0)+g1)+...
        cum = np.cumsum(np.concatenate(([t_end], gaps)))[1:]
        t_end = float(cum[-1])
        out.append(cum)
    ts = np.concatenate(out)
    return ts[ts < spec.duration_s]


def generate_trace(spec: TrafficSpec, seed: int = 0) -> FleetTrace:
    """Sample one arrival trace as a `FleetTrace`: exact vectorized
    thinned-Poisson (non-homogeneous patterns thin against the peak rate,
    so every pattern is exact, not binned).

    Deterministic in (spec, seed), sorted by arrival, and bitwise-identical
    to `generate_legacy` on every column — the per-column substream layout
    makes array fills and scalar draws consume the same bits."""
    ts = _arrival_times(spec, seed)
    u = _col_rng(seed, _S_THIN).random(ts.size)
    keep = ~(u * spec.rate_max > spec.rate_at(ts))       # thinning, exact
    ts = ts[keep]
    n = int(ts.size)

    plen = np.clip(
        _col_rng(seed, _S_PLEN).geometric(1.0 / spec.prompt_len_mean,
                                          size=n),
        2, spec.prompt_len_max).astype(np.int32)
    off = np.zeros(n, dtype=np.int64)
    np.cumsum(plen[:-1], dtype=np.int64, out=off[1:])
    # tokens are uniform ids via floor(u * vocab): one double per token,
    # an order of magnitude cheaper than bounded-integer rejection at
    # fleet scale, and bit-reproducible between array and scalar draws
    tail = (_col_rng(seed, _S_TOKENS).random(int(plen.sum()))
            * spec.vocab_size).astype(np.int32)
    w = np.asarray(spec.new_tokens_weights) / sum(spec.new_tokens_weights)
    new = _col_rng(seed, _S_NEW).choice(
        np.asarray(spec.new_tokens_choices), size=n, p=w).astype(np.int32)
    shares = [t.share for t in spec.tiers]
    tier = _col_rng(seed, _S_TIER).choice(
        len(spec.tiers), size=n, p=shares).astype(np.int32)

    fewshot = np.full(n, -1, dtype=np.int32)
    if spec.header_len and spec.fewshot_pool:
        attach = _col_rng(seed, _S_FSU).random(n) < spec.fewshot_prob
        idx = _col_rng(seed, _S_FSI).integers(
            spec.fewshot_pool, size=int(attach.sum()))
        fewshot[attach] = idx.astype(np.int32)

    slo = np.asarray([t.ttft_slo_s for t in spec.tiers],
                     dtype=np.float64)[tier]
    return FleetTrace(spec=spec, seed=seed, t_arrival=ts, tier_idx=tier,
                      ttft_slo_s=slo, new_tokens=new, prompt_len=plen,
                      prompt_off=off, fewshot_idx=fewshot,
                      tail_tokens=tail)


def generate_legacy(spec: TrafficSpec, seed: int = 0) -> List[FleetRequest]:
    """The pre-vectorization generator: one Python `FleetRequest` per
    arrival, sampled request-by-request.  Kept as (a) the bitwise
    equivalence reference for `generate_trace` and (b) the baseline the
    `BENCH_predict.json` traffic-generation speedup gate measures against.
    Same substream layout, same bits, ~100x the cost at fleet scale."""
    lam_max = spec.rate_max
    rng_gap, rng_thin = _col_rng(seed, _S_GAP), _col_rng(seed, _S_THIN)
    rng_plen, rng_tok = _col_rng(seed, _S_PLEN), _col_rng(seed, _S_TOKENS)
    rng_new, rng_tier = _col_rng(seed, _S_NEW), _col_rng(seed, _S_TIER)
    rng_fsu, rng_fsi = _col_rng(seed, _S_FSU), _col_rng(seed, _S_FSI)
    headers = ([spec.tier_header(i) for i in range(len(spec.tiers))]
               if spec.header_len else [])
    fewshots = ([spec.fewshot_block(i) for i in range(spec.fewshot_pool)]
                if spec.header_len and spec.fewshot_pool else [])
    reqs: List[FleetRequest] = []
    t = 0.0
    while True:
        t += float(rng_gap.exponential(1.0 / lam_max))
        if t >= spec.duration_s:
            break
        if rng_thin.random() * lam_max > spec.rate_at(t):
            continue                        # thinned out
        plen = int(np.clip(rng_plen.geometric(1.0 / spec.prompt_len_mean),
                           2, spec.prompt_len_max))
        prompt = (rng_tok.random(plen) * spec.vocab_size).astype(np.int32)
        new = int(rng_new.choice(spec.new_tokens_choices,
                                 p=np.asarray(spec.new_tokens_weights)
                                 / sum(spec.new_tokens_weights)))
        tier_idx = int(rng_tier.choice(
            len(spec.tiers), p=[ti.share for ti in spec.tiers]))
        tier = spec.tiers[tier_idx]
        if spec.header_len:
            parts = [headers[tier_idx]]
            if spec.fewshot_pool and rng_fsu.random() < spec.fewshot_prob:
                parts.append(fewshots[int(rng_fsi.integers(len(fewshots)))])
            parts.append(prompt)
            prompt = np.concatenate(parts)
        reqs.append(FleetRequest(
            fid=len(reqs), t_arrival=t, prompt=prompt, max_new_tokens=new,
            tier=tier.name, ttft_slo_s=tier.ttft_slo_s))
    return reqs


def generate(spec: TrafficSpec, seed: int = 0) -> List[FleetRequest]:
    """Sample one arrival trace as `FleetRequest` objects (compat surface:
    the vectorized `generate_trace` materialized — identical bits, so
    object and trace callers of the same (spec, seed) see the same
    traffic).  Prefer `generate_trace` at fleet scale."""
    return generate_trace(spec, seed).materialize()


def uniform_burst(n: int, *, new_tokens: int = 16, prompt_len: int = 8,
                  ttft_slo_s: float = 10.0, vocab_size: int = 256,
                  seed: int = 0, t_arrival: float = 0.0
                  ) -> List[FleetRequest]:
    """N identical-shape requests arriving at once — the uniform closed
    batch used by the throughput-scaling gate and property tests."""
    rng = np.random.default_rng(seed)
    return [FleetRequest(
        fid=i, t_arrival=t_arrival,
        prompt=rng.integers(0, vocab_size, size=prompt_len, dtype=np.int32),
        max_new_tokens=new_tokens, tier="uniform", ttft_slo_s=ttft_slo_s)
        for i in range(n)]
