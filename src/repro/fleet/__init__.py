"""`repro.fleet` — SLO-aware serving across many slices of one machine.

    from repro.fleet import (AutoscalerConfig, FleetService, RouterConfig,
                             TrafficSpec, generate)

    sc = Supercomputer()
    svc = FleetService(sc, cfg, params, SliceSpec(slots=4),
                       autoscale=AutoscalerConfig(max_replicas=3))
    report = svc.run(generate(TrafficSpec(pattern="bursty")))
    print(report.aggregate_tokens_per_s, report.slo_attainment)

Traffic is open-loop (`traffic`), routing is SLO-aware (`router`), capacity
is elastic (`autoscaler` drives `Supercomputer.allocate`/`Slice.free`), and
a `fail_block` on a serving slice re-routes its in-flight requests to the
surviving replicas instead of erroring the service (`service`).
"""
from repro.fleet.autoscaler import (Autoscaler, AutoscalerConfig,
                                    ForecastConfig, RateForecaster)
from repro.fleet.replica import ReplicaError, ServeReplica
from repro.fleet.router import Router, RouterConfig
from repro.fleet.service import FleetReport, FleetService
from repro.fleet.traffic import (FleetRequest, FleetTrace, SLOTier,
                                 TrafficSpec, generate, generate_legacy,
                                 generate_trace, uniform_burst)

__all__ = [
    "Autoscaler", "AutoscalerConfig", "FleetReport", "FleetRequest",
    "FleetService", "FleetTrace", "ForecastConfig", "RateForecaster",
    "ReplicaError", "Router", "RouterConfig", "SLOTier", "ServeReplica",
    "TrafficSpec", "generate", "generate_legacy", "generate_trace",
    "uniform_burst",
]
