"""`repro.serve` — continuous-batching serving engine (PR-3 fast path)."""
from repro.serve.engine import Request, ServeEngine, SliceSpec

__all__ = ["Request", "ServeEngine", "SliceSpec"]
