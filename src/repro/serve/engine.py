"""Batched serving engine: continuous prefill + decode over a request queue.

Production-shaped but container-sized: requests arrive with prompts, get
batched into fixed-size decode slots (static shapes for jit), prefill fills
the KV cache per slot, and a decode loop advances all active slots one token
per step, retiring finished requests and admitting queued ones.

Batching discipline: one prefill program (padded prompt length) + one decode
program (full slot batch), both jit'd once — the static-shape serving pattern
TPU serving stacks use.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.parallel.context import LOCAL, ParallelContext, activate


@dataclasses.dataclass(frozen=True)
class SliceSpec:
    """Serving-session shape: the static-compile envelope of one engine.

    One value object instead of loose ``slots/max_len/prompt_len`` kwargs so
    slice handles (`repro.cluster`) can pass serving configuration around,
    hash it, and log it.
    """
    slots: int = 4                  # decode batch width (static shape)
    max_len: int = 256              # KV-cache length per slot
    prompt_len: int = 32            # padded prefill length
    greedy: bool = True

    def __post_init__(self):
        assert self.slots >= 1 and 0 < self.prompt_len <= self.max_len, self


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params,
                 spec: Optional[SliceSpec] = None, *,
                 ctx: ParallelContext = LOCAL,
                 slots: Optional[int] = None,
                 max_len: Optional[int] = None,
                 prompt_len: Optional[int] = None,
                 greedy: Optional[bool] = None):
        legacy = {k: v for k, v in dict(
            slots=slots, max_len=max_len, prompt_len=prompt_len,
            greedy=greedy).items() if v is not None}
        if legacy:
            warnings.warn(
                "ServeEngine(slots=/max_len=/prompt_len=/greedy=) is "
                "deprecated; pass a SliceSpec", DeprecationWarning,
                stacklevel=2)
            spec = dataclasses.replace(spec or SliceSpec(), **legacy)
        spec = spec or SliceSpec()
        self.cfg = cfg
        self.params = params
        self.spec = spec
        self.slots = spec.slots
        self.max_len = spec.max_len
        self.prompt_len = spec.prompt_len
        self.ctx = ctx
        self.greedy = spec.greedy
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * spec.slots

        def _prefill(params, batch):
            with activate(ctx):
                return api.prefill(cfg, params, batch, ctx,
                                   max_len=spec.max_len)

        def _decode(params, cache, tokens):
            with activate(ctx):
                return api.decode_step(cfg, params, cache, tokens, ctx)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(1,))
        self.cache = None
        self.last_tokens = np.zeros((spec.slots,), np.int32)

    # -- request lifecycle ----------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> Request:
        r = Request(rid=len(self.queue), prompt=np.asarray(prompt, np.int32),
                    max_new_tokens=max_new_tokens, t_submit=time.time())
        self.queue.append(r)
        return r

    def _admit(self) -> bool:
        """Fill empty slots from the queue; (re)prefill as one batch."""
        waiting = [r for r in self.queue if not r.done
                   and r not in self.active]
        free = [i for i, a in enumerate(self.active) if a is None
                or a.done]
        if not waiting or not free:
            return False
        # Build a full prompt batch: existing actives re-prefill their
        # prompt+generated context (simple, static-shape discipline).
        for i in free:
            if not waiting:
                break
            self.active[i] = waiting.pop(0)
        prompts = np.zeros((self.slots, self.prompt_len), np.int32)
        for i, r in enumerate(self.active):
            if r is None:
                continue
            seq = np.concatenate([r.prompt, np.asarray(r.out_tokens,
                                                       np.int32)])
            seq = seq[-self.prompt_len:]
            prompts[i, -len(seq):] = seq
        batch = {"tokens": jnp.asarray(prompts)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (self.slots, self.cfg.vision_prefix, self.cfg.vision_dim),
                jnp.float32)
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (self.slots, self.prompt_len, self.cfg.d_model), jnp.float32)
        logits, self.cache = self._prefill(self.params, batch)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None and not r.done:
                r.out_tokens.append(int(nxt[i]))
                if r.t_first is None:
                    r.t_first = time.time()
        self.last_tokens = nxt
        return True

    def step(self) -> int:
        """One decode step over all slots; returns #active requests."""
        if self.cache is None:
            if not self._admit():
                return 0
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_tokens))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        n_active = 0
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            r.out_tokens.append(int(nxt[i]))
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                r.t_done = time.time()
            else:
                n_active += 1
        self.last_tokens = nxt
        return n_active

    def run(self, max_steps: int = 1000) -> Dict[str, float]:
        """Serve until the queue drains; returns latency/throughput stats."""
        produced = 0
        steps = 0
        t0 = time.time()
        while steps < max_steps:
            active = self.step()
            steps += 1
            if active == 0:
                if not any(not r.done for r in self.queue):
                    break
                if not self._admit():
                    break
        wall = time.time() - t0
        done = [r for r in self.queue if r.done]
        produced = sum(len(r.out_tokens) for r in done)
        ttfts = [r.t_first - r.t_submit for r in done if r.t_first]
        return {
            "requests_done": len(done),
            "tokens": produced,
            "wall_s": wall,
            "tokens_per_s": produced / max(wall, 1e-9),
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "decode_steps": steps,
        }
