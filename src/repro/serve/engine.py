"""Batched serving engine: incremental continuous batching + chunked decode.

Production-shaped but container-sized: requests arrive with prompts, get
batched into fixed-size decode slots (static shapes for jit), and a decode
loop advances all active slots, retiring finished requests and admitting
queued ones.

The fast path (every transformer-cache family):
  * **Incremental admission** — admitting a request prefills ONLY its slot
    (``api.prefill_slot``: a batch-1 prefill whose KV/state rows are written
    into the live batch cache), so admitting request k+1 never recomputes
    request k.  Per-slot valid lengths live in a device-resident ``seq_lens``
    vector instead of the cache's shared scalar position.
  * **Paged decode attention** — each step gathers only a slot's valid cache
    prefix (``kernels/decode_attention``: Pallas paged kernel on TPU, dense
    XLA reference elsewhere) instead of scanning the full ``max_len`` dense
    cache.
  * **Multi-step on-device decode** — ``api.decode_n`` scans ``chunk`` steps
    per dispatch with on-device argmax/sampling and per-slot done-masking,
    so the device→host sync happens once per chunk, not once per token.
    Chunking is numerics-neutral: greedy outputs are bitwise identical for
    any chunk size (the property benchmarks/cluster_session.py pins) for
    every family whose per-token compute is batch-lane independent.  The
    one caveat is MoE capacity coupling: admission lands on chunk
    boundaries, so chunk size can shift WHEN a freed slot's lane flips from
    a frozen repeat-token to a fresh request, and a saturated expert's
    token-drop choice sees those lane contents (identical admission
    schedules — e.g. uniform budgets — are still bitwise stable).

Batching discipline: one batch-1 prefill program + one chunked decode
program, both jit'd once — the static-shape serving pattern TPU serving
stacks use.  The whisper enc-dec family keeps the legacy full-batch
prefill + per-token loop (its cache layout has no per-slot insert yet).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models import quant as QUANT
from repro.obs import Telemetry
from repro.parallel.context import LOCAL, ParallelContext, activate
from repro.serve.kvpool import KVPool


@dataclasses.dataclass(frozen=True)
class SliceSpec:
    """Serving-session shape: the static-compile envelope of one engine.

    One value object instead of loose ``slots/max_len/prompt_len`` kwargs so
    slice handles (`repro.cluster`) can pass serving configuration around,
    hash it, and log it.

    ``chunk`` is the serve fast-path knob: decode tokens advanced per device
    dispatch (1 = legacy per-token host loop, same numerics).

    ``kv_block > 0`` switches the engine to the POOLED prefix-shared KV
    cache (`serve/kvpool.py`): per-slot cache rows become indirection tables
    over a shared block pool, admissions sharing a prompt prefix reuse
    already-prefilled blocks, and prefill runs as fixed-width
    ``suffix_len``-token dispatches over only the unshared suffix.
    ``kv_share=False`` keeps the pooled layout but never matches/publishes —
    the bitwise-identity baseline arm.  ``kv_blocks`` sizes the pool
    (0 = 2x the table capacity, so published prefixes survive slot churn).
    """
    slots: int = 4                  # decode batch width (static shape)
    max_len: int = 256              # KV-cache length per slot
    prompt_len: int = 32            # padded prefill length
    greedy: bool = True
    chunk: int = 8                  # decode steps per dispatch
    kv_block: int = 0               # pooled KV block size (0 = dense cache)
    kv_share: bool = True           # match/publish prompt prefixes
    kv_blocks: int = 0              # pool size (0 = 2 * slots * table width)
    suffix_len: int = 0             # suffix-prefill dispatch width
                                    # (0 = prompt_len)
    quant: str = "none"             # weight storage: "none" | "int8"
                                    # (models/quant.py tile-wise int8; the
                                    # engine quantises its params at init)

    def __post_init__(self):
        assert self.slots >= 1 and 0 < self.prompt_len <= self.max_len, self
        assert self.chunk >= 1, self
        assert self.quant in ("none", "int8"), self
        if self.kv_block:
            assert self.max_len % self.kv_block == 0, \
                f"max_len {self.max_len} not a multiple of kv_block " \
                f"{self.kv_block}"
            assert self.suffix_len >= 0 and self.kv_blocks >= 0, self


@dataclasses.dataclass(eq=False)
class Request:
    """One serving request.  ``eq=False`` keeps identity semantics: a
    generated ``__eq__`` would compare ``np.ndarray`` prompts elementwise,
    so membership tests (``r in engine.active``) could raise on value-equal
    requests."""
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@functools.lru_cache(maxsize=32)
def _fast_programs(cfg: ModelConfig, spec: SliceSpec, ctx: ParallelContext):
    """The jit'd admission + chunked-decode programs for one serving shape.

    Cached on the (frozen, hashable) config triple so every engine with the
    same shape shares ONE compilation — a fleet scale-up brings a replica
    online without recompiling, and N replicas cost one compile, not N.
    ``params``/``cache`` stay call arguments, so the cache never pins model
    weights."""
    sample_key = jax.random.PRNGKey(spec.slots)

    def _admit(params, cache, batch, slots_, rids, seq_lens, last, salt):
        with activate(ctx):
            logits, cache = api.prefill_slot(
                cfg, params, batch, cache, slots_, ctx, max_len=spec.max_len)
        # cached rows include the vision prefix for VLMs — the
        # text-token count alone would mask out valid prompt KV
        prefilled = batch["tokens"].shape[1] + (cfg.vision_prefix or 0)
        if spec.greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            # first token follows the same (salt, position) key scheme as
            # decode_n; decode positions start at prefilled+1, so the
            # streams never collide
            keys = jax.vmap(lambda b: jax.random.fold_in(
                jax.random.fold_in(sample_key, b), prefilled))(rids)
            nxt = jax.vmap(jax.random.categorical)(
                keys, logits).astype(jnp.int32)
        seq_lens = seq_lens.at[slots_].set(prefilled)
        last = last.at[slots_].set(nxt)
        salt = salt.at[slots_].set(rids)
        return nxt, cache, seq_lens, last, salt

    def _decode(params, cache, tokens, seq_lens, budget, key, salt,
                num_steps):
        with activate(ctx):
            return api.decode_n(
                cfg, params, cache, tokens, seq_lens, budget, ctx,
                num_steps=num_steps, greedy=spec.greedy, key=key, salt=salt)

    return (jax.jit(_admit, donate_argnums=(1,)),
            jax.jit(_decode, donate_argnums=(1,), static_argnums=(7,)))


@functools.lru_cache(maxsize=32)
def _pooled_programs(cfg: ModelConfig, spec: SliceSpec, ctx: ParallelContext):
    """Jit'd suffix-prefill admission + pooled chunked decode.

    The admission program is SLOT-ALIGNED (row i == slot i) and fixed-width
    (``suffix_len`` tokens): a long suffix prefills in several chained
    dispatches of this one program, and only rows whose ``commit`` flag is
    set (the chunk holding their last prompt token) fold their logits into
    the decode state — everything else is a masked no-op, so idle rows and
    mid-suffix chunks never perturb live slots."""
    sample_key = jax.random.PRNGKey(spec.slots)

    def _admit(params, cache, tokens, start, valid, tables, rids, plens,
               commit, seq_lens, last, salt):
        with activate(ctx):
            logits, cache = api.prefill_suffix(
                cfg, params, cache, tokens, start, valid, tables, ctx)
        if spec.greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            # same (salt, position) scheme as the dense fast path, but the
            # fold position is the TRUE prompt length (pooled rows are
            # left-aligned, not padded to prompt_len)
            keys = jax.vmap(lambda b, n: jax.random.fold_in(
                jax.random.fold_in(sample_key, b), n))(rids, plens)
            nxt = jax.vmap(jax.random.categorical)(
                keys, logits).astype(jnp.int32)
        seq_lens = jnp.where(commit, plens, seq_lens)
        last = jnp.where(commit, nxt, last)
        salt = jnp.where(commit, rids, salt)
        return nxt, cache, seq_lens, last, salt

    def _decode(params, cache, tokens, seq_lens, budget, key, salt, tables,
                num_steps):
        with activate(ctx):
            return api.decode_n(
                cfg, params, cache, tokens, seq_lens, budget, ctx,
                num_steps=num_steps, greedy=spec.greedy, key=key, salt=salt,
                tables=tables)

    return (jax.jit(_admit, donate_argnums=(1,)),
            jax.jit(_decode, donate_argnums=(1,), static_argnums=(8,)))


@functools.lru_cache(maxsize=8)
def _legacy_programs(cfg: ModelConfig, spec: SliceSpec,
                     ctx: ParallelContext):
    """Full-batch prefill + per-token decode (whisper enc-dec cache)."""

    def _prefill(params, batch):
        with activate(ctx):
            return api.prefill(cfg, params, batch, ctx, max_len=spec.max_len)

    def _decode(params, cache, tokens):
        with activate(ctx):
            return api.decode_step(cfg, params, cache, tokens, ctx)

    return jax.jit(_prefill), jax.jit(_decode, donate_argnums=(1,))


class ServeEngine:
    """Continuous-batching serving engine (the PR-3 fast path).

    One engine owns `spec.slots` decode slots over a paged KV cache:
    admission prefills ONLY the admitted requests (one fixed-width
    dispatch), decode advances all slots `spec.chunk` tokens per dispatch
    with on-device sampling and done-masking, and per-slot valid lengths
    drive the paged decode-attention kernel.  Greedy outputs are bitwise
    chunk-invariant.

    Args:
      cfg: model config (any family except audio rides the fast path).
      params: model parameters pytree.
      spec: `SliceSpec` serving envelope (slots/max_len/prompt_len/chunk).
      ctx: `ParallelContext` for sharded serving and kernel dispatch knobs.
    """

    def __init__(self, cfg: ModelConfig, params,
                 spec: Optional[SliceSpec] = None, *,
                 ctx: ParallelContext = LOCAL,
                 obs: Optional[Telemetry] = None,
                 obs_labels: Optional[Dict[str, Any]] = None):
        spec = spec or SliceSpec()
        self.cfg = cfg
        if spec.quant == "int8":
            params = QUANT.quantize_params(cfg, params)
        self.params = params
        self.spec = spec
        self.slots = spec.slots
        self.max_len = spec.max_len
        self.prompt_len = spec.prompt_len
        self.ctx = ctx
        self.greedy = spec.greedy
        self.queue: List[Request] = []        # every request, for stats
        self.pending: List[Request] = []      # submitted, not yet admitted
        self._next_rid = 0                    # monotonic: queue length would
                                              # recycle rids after an
                                              # export_inflight, colliding
                                              # sampling salts / fleet keys
        self.active: List[Optional[Request]] = [None] * spec.slots
        self.cache = None
        self.last_tokens = jnp.zeros((spec.slots,), jnp.int32)
        self.seq_lens = jnp.zeros((spec.slots,), jnp.int32)
        # per-slot sampling salt = rid of the request occupying the slot,
        # so distinct requests reusing a slot draw decorrelated streams
        self.sample_salt = jnp.zeros((spec.slots,), jnp.int32)
        self.chunk_lat_s: List[float] = []
        self._chunk_ema: Optional[float] = None   # O(1) running latency EMA
        self._steps = 0
        self._sample_key = jax.random.PRNGKey(spec.slots)
        # whisper's enc-dec cache has no per-slot insert; it keeps the
        # legacy full-batch prefill + per-token decode loop
        self._fast = cfg.family != "audio"
        # pooled prefix-shared KV (kvpool.py); dense-transformer only
        self._pooled = self._fast and spec.kv_block > 0
        # prefill-cost proxy (dispatch width x batch rows, summed over
        # prefill dispatches) + prefix-sharing counters — the kv-prefix
        # benchmark compares these across pooled/legacy arms.  They live in
        # the metrics registry (labeled, so a shared fleet-wide Telemetry
        # keeps engines apart); the old attribute names stay as property
        # views below.
        self.obs = obs if obs is not None else Telemetry()
        labels = dict(obs_labels or {})
        reg = self.obs.metrics
        self._c_prefill = reg.counter("serve.prefill_flops_proxy", **labels)
        self._c_kv_prompt = reg.counter("serve.kv_prompt_tokens", **labels)
        self._c_kv_shared = reg.counter("serve.kv_shared_tokens", **labels)
        self._c_mig_shared = reg.counter(
            "serve.kv_migrated_shared_blocks", **labels)
        self._c_mig_suffix = reg.counter(
            "serve.kv_migrated_suffix_blocks", **labels)
        self._h_chunk = reg.histogram("serve.chunk_s", **labels)

        if self._pooled:
            assert cfg.family == "dense", \
                "pooled prefix-shared KV is dense-transformer only"
            nb = spec.max_len // spec.kv_block
            self._nb = nb
            self._suffix_len = spec.suffix_len or spec.prompt_len
            self.kvpool = KVPool(
                num_blocks=spec.kv_blocks or 2 * spec.slots * nb,
                block_size=spec.kv_block, slots=spec.slots,
                blocks_per_slot=nb)
            # host mirror of the device tables; OOB sentinel = unadmitted
            # (the bt kernel clamps it; seq_lens=0 masks the compute)
            self._tables_np = np.full((spec.slots, nb),
                                      self.kvpool.num_blocks, np.int32)
            self.tables = jnp.asarray(self._tables_np)
            self._admit_fn, self._decode_fn = _pooled_programs(cfg, spec,
                                                               ctx)
        elif self._fast:
            self._admit_fn, self._decode_fn = _fast_programs(cfg, spec, ctx)
        else:
            self._prefill, self._decode = _legacy_programs(cfg, spec, ctx)

    # -- request lifecycle ----------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> Request:
        """Enqueue one prompt; returns its `Request` handle (admission
        happens on a later `step`/`step_chunk`).  The prompt is truncated
        to the last `spec.prompt_len` tokens at prefill."""
        r = Request(rid=self._next_rid, prompt=np.asarray(prompt, np.int32),
                    max_new_tokens=max_new_tokens, t_submit=time.time())
        self._next_rid += 1
        self.queue.append(r)
        self.pending.append(r)
        return r

    def _extra_inputs(self, n: int) -> Dict[str, Any]:
        extra: Dict[str, Any] = {}
        if self.cfg.family == "vlm":
            extra["patches"] = jnp.zeros(
                (n, self.cfg.vision_prefix, self.cfg.vision_dim),
                jnp.float32)
        return extra

    def _admit(self) -> bool:
        """Fill empty slots from the queue: the whole admission wave is ONE
        batched prefill dispatch writing only the admitted slots' cache rows
        — active slots are never recomputed.  The wave is padded to a fixed
        width of ``slots`` (static shapes: exactly one compiled admission
        program); padding rows carry an out-of-bounds slot index, so their
        scatter updates are dropped on-device."""
        if not self._fast:
            return self._admit_full()
        if self._pooled:
            return self._admit_pooled()
        if not self.pending:                   # O(1) fast-out per chunk
            return False
        free = [i for i, a in enumerate(self.active)
                if a is None or a.done]
        n = min(len(self.pending), len(free))
        if n == 0:
            return False
        if self.cache is None:
            self.cache = api.init_cache(self.cfg, self.slots, self.max_len)
        admitted = self.pending[:n]
        del self.pending[:n]
        slots = np.full((self.slots,), self.slots, np.int32)  # OOB sentinel
        slots[:n] = free[:n]
        prompts = np.zeros((self.slots, self.prompt_len), np.int32)
        for row, (slot, r) in enumerate(zip(slots[:n], admitted)):
            self.active[slot] = r
            seq = r.prompt[-self.prompt_len:]
            prompts[row, -len(seq):] = seq
        rids = np.zeros((self.slots,), np.int32)
        rids[:n] = [r.rid for r in admitted]
        self._c_prefill.inc(self.prompt_len * self.slots)
        batch = {"tokens": jnp.asarray(prompts),
                 **self._extra_inputs(self.slots)}
        nxt, self.cache, self.seq_lens, self.last_tokens, self.sample_salt = \
            self._admit_fn(self.params, self.cache, batch,
                           jnp.asarray(slots), jnp.asarray(rids),
                           self.seq_lens, self.last_tokens,
                           self.sample_salt)
        nxt = np.asarray(nxt)
        now = time.time()
        for row, r in enumerate(admitted):
            r.out_tokens.append(int(nxt[row]))
            r.t_first = now
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                r.t_done = now
        return True

    def _admit_pooled(self) -> bool:
        """Pooled admission: map each admitted prompt's shared prefix onto
        already-prefilled pool blocks (kvpool.admit) and prefill ONLY the
        unshared suffix in fixed-width ``suffix_len`` chunks — a request
        whose whole prompt header is cached pays one small dispatch instead
        of a full-width prefill.  Publication into the prefix trie happens
        AFTER the dispatches land, so two same-wave admissions can never
        alias blocks still being written."""
        if not self.pending:
            return False
        free = [i for i, a in enumerate(self.active)
                if a is None or a.done]
        n = min(len(self.pending), len(free))
        if n == 0:
            return False
        if self.cache is None:
            self.cache = api.init_kv_pool(
                self.cfg, self.kvpool.num_blocks, self.spec.kv_block)
        admitted = self.pending[:n]
        del self.pending[:n]
        bs = self.spec.kv_block
        rows = []                              # (slot, request, start, seq)
        for slot, r in zip(free[:n], admitted):
            self.active[slot] = r
            seq = np.asarray(r.prompt, np.int32)[-self.prompt_len:]
            table, matched = self.kvpool.admit(
                slot, seq, share=self.spec.kv_share)
            self._tables_np[slot] = table
            self._c_kv_prompt.inc(len(seq))
            self._c_kv_shared.inc(matched * bs)
            rows.append((slot, r, matched * bs, seq))
        self.tables = jnp.asarray(self._tables_np)
        Tc = self._suffix_len
        nchunk = max(1, -(-max(len(seq) - start
                               for (_, _, start, seq) in rows) // Tc))
        nxt_keep = np.zeros((self.slots,), np.int32)
        for c in range(nchunk):
            tok = np.zeros((self.slots, Tc), np.int32)
            st = np.zeros((self.slots,), np.int32)
            vd = np.zeros((self.slots,), np.int32)
            rids = np.zeros((self.slots,), np.int32)
            plens = np.zeros((self.slots,), np.int32)
            commit = np.zeros((self.slots,), bool)
            for slot, r, start, seq in rows:
                s0 = start + c * Tc
                v = max(0, min(Tc, len(seq) - s0))
                st[slot] = min(s0, len(seq))
                vd[slot] = v
                rids[slot] = r.rid
                plens[slot] = len(seq)
                if v:
                    tok[slot, :v] = seq[s0:s0 + v]
                    commit[slot] = s0 + v == len(seq)
            self._c_prefill.inc(Tc * self.slots)
            nxt, self.cache, self.seq_lens, self.last_tokens, \
                self.sample_salt = self._admit_fn(
                    self.params, self.cache, jnp.asarray(tok),
                    jnp.asarray(st), jnp.asarray(vd), self.tables,
                    jnp.asarray(rids), jnp.asarray(plens),
                    jnp.asarray(commit), self.seq_lens, self.last_tokens,
                    self.sample_salt)
            if commit.any():
                nxt_np = np.asarray(nxt)
                nxt_keep[commit] = nxt_np[commit]
        now = time.time()
        for slot, r, start, seq in rows:
            r.out_tokens.append(int(nxt_keep[slot]))
            r.t_first = now
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                r.t_done = now
            if self.spec.kv_share:
                self.kvpool.publish(slot)
        return True

    def _budgets(self) -> np.ndarray:
        """Decode tokens still owed per slot.  Requests longer than the
        ``max_len`` cache envelope degrade exactly like the legacy engine:
        the KV write clamps to the last row while tokens keep flowing."""
        b = np.zeros((self.slots,), np.int32)
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            b[i] = max(0, r.max_new_tokens - len(r.out_tokens))
        return b

    def _decode_chunk(self, num_steps: int) -> None:
        """One device dispatch advancing every live slot up to ``num_steps``
        tokens; host-side bookkeeping runs once on the returned chunk."""
        budgets = self._budgets()
        t0 = time.perf_counter()
        if self._pooled:
            toks, self.cache, self.seq_lens, self.last_tokens = \
                self._decode_fn(
                    self.params, self.cache, self.last_tokens,
                    self.seq_lens, jnp.asarray(budgets), self._sample_key,
                    self.sample_salt, self.tables, num_steps)
        else:
            toks, self.cache, self.seq_lens, self.last_tokens = \
                self._decode_fn(
                    self.params, self.cache, self.last_tokens,
                    self.seq_lens, jnp.asarray(budgets), self._sample_key,
                    self.sample_salt, num_steps)
        toks = np.asarray(toks)                      # (num_steps, B) — syncs
        self._record_latency(time.perf_counter() - t0)
        self._steps += num_steps
        now = time.time()
        for i, r in enumerate(self.active):
            got = int(min(budgets[i], num_steps))
            if r is None or r.done or got == 0:
                continue
            r.out_tokens.extend(int(t) for t in toks[:got, i])
            if budgets[i] <= got:                    # budget met this chunk
                r.done = True
                r.t_done = now

    def _n_active(self) -> int:
        return sum(1 for r in self.active
                   if r is not None and not r.done)

    # -- fleet introspection / migration --------------------------------------
    # The queue-depth/ETA surface the fleet router reads every scheduling
    # decision, and the in-flight export the fleet uses to move requests off
    # a dying replica.  All host-side: no device sync.

    @property
    def n_active(self) -> int:
        """Requests currently occupying decode slots (not yet done)."""
        return self._n_active()

    @property
    def n_pending(self) -> int:
        """Requests submitted but not yet admitted to a slot."""
        return len(self.pending)

    @property
    def free_slots(self) -> int:
        """Slots currently available for admission."""
        return sum(1 for r in self.active if r is None or r.done)

    @property
    def depth(self) -> int:
        """Total requests this engine still owes work to."""
        return self.n_active + self.n_pending

    def tokens_owed(self) -> int:
        """Decode tokens still owed across active + pending requests."""
        owed = int(self._budgets().sum())
        owed += sum(r.max_new_tokens for r in self.pending)
        return owed

    def chunk_time_ema(self, default: float = 0.05) -> float:
        """Smoothed per-dispatch latency (seconds), maintained O(1) per
        chunk — the router reads this per routing decision."""
        return default if self._chunk_ema is None else self._chunk_ema

    # -- telemetry views -------------------------------------------------------
    # The pre-registry counter attributes, now thin read-only views over the
    # registry instruments (same names, same values — existing readers and
    # benchmark arms compare unchanged).

    @property
    def prefill_flops_proxy(self) -> int:
        return self._c_prefill.value

    @property
    def kv_prompt_tokens(self) -> int:
        return self._c_kv_prompt.value

    @property
    def kv_shared_tokens(self) -> int:
        return self._c_kv_shared.value

    @property
    def kv_migrated_shared_blocks(self) -> int:
        return self._c_mig_shared.value

    @property
    def kv_migrated_suffix_blocks(self) -> int:
        return self._c_mig_suffix.value

    def _record_latency(self, lat: float) -> None:
        self.chunk_lat_s.append(lat)
        self._h_chunk.observe(lat)
        # `run` resets the list per batch, but a fleet replica steps chunk
        # by chunk for the service's lifetime — bound the history so a
        # long-lived engine doesn't leak (EMA carries the tail)
        if len(self.chunk_lat_s) > 4096:
            del self.chunk_lat_s[:2048]
        self._chunk_ema = (lat if self._chunk_ema is None
                           else 0.7 * self._chunk_ema + 0.3 * lat)

    def expected_ttft_s(self, default_chunk_s: float = 0.05, *,
                        chunk_time_s: Optional[float] = None) -> float:
        """Heuristic TTFT estimate for the NEXT request submitted here: one
        admission dispatch once a slot frees, queued behind the decode work
        already owed (measured in chunk dispatches at the engine's smoothed
        chunk latency — or at ``chunk_time_s`` when the caller accounts time
        itself, e.g. the fleet's deterministic virtual clock).  The router's
        shortest-expected-TTFT policy ranks replicas by this number."""
        per_chunk = (chunk_time_s if chunk_time_s is not None
                     else self.chunk_time_ema(default_chunk_s))
        if self.free_slots > 0 and not self.pending:
            return per_chunk                      # admit next dispatch
        ahead = self.tokens_owed()
        width = max(1, self.slots) * max(1, self.spec.chunk)
        waves = 1.0 + ahead / width
        return per_chunk * waves

    def step_chunk(self) -> int:
        """Admit + advance ONE decode chunk (`spec.chunk` steps); returns the
        number of still-active requests.  The single-dispatch quantum fleet
        replicas advance by — same dataflow as `run`, externally paced."""
        if self._fast:
            self._admit()
            if self._n_active() == 0:
                return 0
            self._decode_chunk(self.spec.chunk)
            return self._n_active()
        self._admit()
        n = 0
        for _ in range(self.spec.chunk):
            n = self.step()
            if n == 0:
                break
        return n

    def export_inflight(self) -> List[Request]:
        """Remove and return every request still owed tokens (admitted and
        pending), clearing their slots.  Used when a slice dies under the
        engine: the survivors re-prefill ``prompt + out_tokens`` and generate
        the remainder, so no request is lost with its replica.  Exported
        requests leave `queue` too — this engine's stats no longer own them.

        Pooled engines also release every slot's block table and account
        the migration split: only each in-flight request's PRIVATE suffix
        blocks would ship with it (``kv_migrated_suffix_blocks``) — its
        shared-prefix blocks stay behind in this pool's trie (or are
        re-matched from the destination's trie), so a migration moves
        ``suffix/(shared+suffix)`` of the naive KV payload."""
        moved: List[Request] = []
        for i, r in enumerate(self.active):
            if self._pooled and self.kvpool.table(i) is not None:
                if r is not None and not r.done:
                    shared = self.kvpool.shared_blocks(i)
                    self._c_mig_shared.inc(shared)
                    self._c_mig_suffix.inc(self._nb - shared)
                self.kvpool.release(i)
                self._tables_np[i] = self.kvpool.num_blocks
            if r is not None and not r.done:
                moved.append(r)
            self.active[i] = None
        if self._pooled:
            self.tables = jnp.asarray(self._tables_np)
        moved.extend(self.pending)
        self.pending = []
        for r in moved:
            if r in self.queue:
                self.queue.remove(r)
        return moved

    # -- pooled-KV introspection ----------------------------------------------

    def prefix_lookup(self, prompt: np.ndarray) -> int:
        """Shareable prefix TOKENS this engine's trie holds for ``prompt``
        right now (0 when not pooled).  Peek only — no references taken, no
        LRU touch — so the fleet router can score every replica per
        routing decision (the prefix-affinity policy)."""
        if not self._pooled:
            return 0
        seq = np.asarray(prompt, np.int32)[-self.prompt_len:]
        return self.kvpool.match_len(seq) * self.spec.kv_block

    def weight_stream_bytes(self) -> int:
        """HBM weight bytes streamed per decode *step* (every weight is read
        once per step regardless of batch width).  Divide by active slots
        for bytes/token — the meter the quantization benchmark gates on."""
        return QUANT.storage_bytes(self.params)

    def kv_stats(self) -> Dict[str, int]:
        """Sharing/migration counters, plus pool accounting when pooled.
        ``prefill_flops_proxy`` (dispatch width x slots, summed over
        prefill dispatches) is counted on the legacy fast path too, so an
        unshared baseline arm and a pooled arm compare on the same
        meter."""
        s = self.kvpool.stats() if self._pooled else {}
        s.update(
            prefill_flops_proxy=self.prefill_flops_proxy,
            kv_prompt_tokens=self.kv_prompt_tokens,
            kv_shared_tokens=self.kv_shared_tokens,
            kv_migrated_shared_blocks=self.kv_migrated_shared_blocks,
            kv_migrated_suffix_blocks=self.kv_migrated_suffix_blocks,
        )
        return s

    def kv_close(self) -> None:
        """Release every slot table and the prefix trie, then audit the
        pool: asserts every block returned to the free list (the zero-leak
        gate the kv-prefix benchmark enforces)."""
        if not self._pooled:
            return
        self.kvpool.close()
        self._tables_np[:] = self.kvpool.num_blocks
        self.tables = jnp.asarray(self._tables_np)

    def step(self) -> int:
        """One decode step over all slots; returns #active requests.

        Per-token compatibility surface: a chunk of exactly one step, so the
        numerics match ``run`` at any chunk size.  Like ``run``, the fast
        path admits before every step so free slots never starve while
        others are mid-request.
        """
        if self._fast:
            self._admit()
            if self._n_active() == 0:
                return 0
            self._decode_chunk(1)
            return self._n_active()
        if self._n_active() == 0 and not self._admit():
            return 0
        return self._step_legacy()

    def run(self, max_steps: int = 1000) -> Dict[str, float]:
        """Serve until the queue drains; returns latency/throughput stats."""
        self.chunk_lat_s = []
        self._steps = 0
        t0 = time.time()
        if self._fast:
            while self._steps < max_steps:
                self._admit()
                if self._n_active() == 0:
                    break
                # always dispatch the full chunk: num_steps is static, so a
                # data-dependent remainder would recompile the decode
                # program mid-serve (budgets absorb any overshoot)
                self._decode_chunk(self.spec.chunk)
        else:
            while self._steps < max_steps:
                if self.step() == 0:
                    if not any(not r.done for r in self.queue):
                        break
                    if not self._admit():
                        break
        wall = time.time() - t0
        done = [r for r in self.queue if r.done]
        produced = sum(len(r.out_tokens) for r in done)
        # latency stats cover only THIS run's completions — a prior warmup
        # run's compile-tainted TTFT must not pollute the percentiles
        # (requests_done/tokens stay cumulative over the queue, as pinned)
        ttfts = [r.t_first - r.t_submit for r in done
                 if r.t_first and r.t_done and r.t_done >= t0]
        return {
            "requests_done": len(done),
            "tokens": produced,
            "wall_s": wall,
            "tokens_per_s": produced / max(wall, 1e-9),
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "p50_ttft_s": _pct(ttfts, 50),
            "p95_ttft_s": _pct(ttfts, 95),
            "decode_steps": self._steps,
            "chunk": self.spec.chunk if self._fast else 1,
            "p50_chunk_s": _pct(self.chunk_lat_s, 50),
            "p95_chunk_s": _pct(self.chunk_lat_s, 95),
        }

    # -- legacy full-batch path (whisper enc-dec cache) -----------------------

    def _admit_full(self) -> bool:
        """Legacy admission: (re)prefill the whole slot batch."""
        free = [i for i, a in enumerate(self.active) if a is None
                or a.done]
        if not self.pending or not free:
            return False
        for i in free:
            if not self.pending:
                break
            self.active[i] = self.pending.pop(0)
        prompts = np.zeros((self.slots, self.prompt_len), np.int32)
        for i, r in enumerate(self.active):
            if r is None:
                continue
            seq = np.concatenate([r.prompt, np.asarray(r.out_tokens,
                                                       np.int32)])
            seq = seq[-self.prompt_len:]
            prompts[i, -len(seq):] = seq
        batch = {"tokens": jnp.asarray(prompts)}
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (self.slots, self.prompt_len, self.cfg.d_model), jnp.float32)
        logits, self.cache = self._prefill(self.params, batch)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        now = time.time()
        for i, r in enumerate(self.active):
            if r is not None and not r.done:
                r.out_tokens.append(int(nxt[i]))
                if r.t_first is None:
                    r.t_first = now
                if len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
                    r.t_done = now
        self.last_tokens = jnp.asarray(nxt)
        return True

    def _step_legacy(self) -> int:
        t0 = time.perf_counter()
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_tokens)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._record_latency(time.perf_counter() - t0)
        self._steps += 1
        n_active = 0
        now = time.time()
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            r.out_tokens.append(int(nxt[i]))
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                r.t_done = now
            else:
                n_active += 1
        self.last_tokens = jnp.asarray(nxt)
        return n_active
