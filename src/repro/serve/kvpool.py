"""Refcounted paged KV block pool with a copy-on-write prefix trie.

The serving engine's KV cache becomes a pool of fixed-size *blocks*
(`kv_block` tokens each); every decode slot owns an indirection table
mapping its logical blocks to physical pool blocks.  Admissions that share
a prompt prefix map their leading table entries onto blocks another request
already prefilled — keyed by the *token content* of each full block through
a prefix trie — and prefill only the unshared suffix.

Sharing is copy-on-write by construction rather than by trapping writes:

  * only FULL prompt blocks are ever published to the trie (a request's
    final partial block and its decode region stay private), and the match
    is capped so at least one suffix token always remains (the admission
    needs the last prompt position's logits);
  * decode writes land at positions ``>= prompt_len``, i.e. strictly past
    every published block, so a shared block is never written after it
    becomes shareable — no write ever needs to fork a block;
  * a slot's final block is never published (the engine clamps
    past-``max_len`` decode writes into it, legacy-style degrade).

Ownership is reference counting: a physical block is held by each slot
table that maps it plus one reference for its trie node.  Blocks return to
the free list when the count reaches zero; LRU leaf eviction drops
trie-only blocks when allocation starves.  ``check()`` asserts the
conservation invariant (every block exactly free xor referenced, and the
reference total equals table references + trie nodes) — the accounting the
kv-prefix benchmark gates on (zero blocks leaked).

All host-side and synchronous: the engine consults this pool at admission
/ retirement / migration; device code only ever sees the resulting int32
block tables.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class BlockPool:
    """Free list + per-block reference counts over ``num_blocks`` physical
    KV blocks of ``block_size`` tokens each."""

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 1 and block_size >= 1, (num_blocks, block_size)
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._refs: List[int] = [0] * num_blocks

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def allocated_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def refcount(self, b: int) -> int:
        return self._refs[b]

    def alloc(self) -> Optional[int]:
        """Take a free block with refcount 1 (None when exhausted)."""
        if not self._free:
            return None
        b = self._free.pop()
        assert self._refs[b] == 0, f"block {b} on free list with refs"
        self._refs[b] = 1
        return b

    def incref(self, b: int) -> None:
        assert self._refs[b] > 0, f"incref of unallocated block {b}"
        self._refs[b] += 1

    def decref(self, b: int) -> bool:
        """Drop one reference; returns True when the block was freed."""
        assert self._refs[b] > 0, f"double free of block {b}"
        self._refs[b] -= 1
        if self._refs[b] == 0:
            self._free.append(b)
            return True
        return False

    def check(self) -> None:
        """Conservation: every block is exactly free xor referenced."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate free-list entry"
        for b in range(self.num_blocks):
            assert (self._refs[b] == 0) == (b in free), \
                f"block {b}: refs={self._refs[b]} free={b in free}"


class _Node:
    __slots__ = ("key", "parent", "block", "children", "tick")

    def __init__(self, key: bytes, parent: Optional["_Node"], block: int):
        self.key = key
        self.parent = parent
        self.block = block
        self.children: Dict[bytes, "_Node"] = {}
        self.tick = 0


class PrefixTrie:
    """Content-addressed chains of full token blocks -> physical blocks.

    Each node keys one full block of prompt tokens (by its raw int32 bytes,
    scoped under its parent — equal contents under different prefixes are
    different nodes) and holds ONE pool reference on the physical block
    carrying that block's KV.  ``match`` walks the chain for a prompt and
    increfs every matched block on behalf of the caller's slot table;
    ``insert`` publishes a freshly prefilled chain, keeping any existing
    node where one already covers a block (the caller's private copy stays
    private — the contents are bitwise-identical, see serve/engine.py).
    """

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self._children: Dict[bytes, _Node] = {}
        self._nodes: List[_Node] = []
        self._tick = 0

    @property
    def n_nodes(self) -> int:
        return len(self._nodes)

    def _blocks_of(self, tokens: np.ndarray) -> List[bytes]:
        bs = self.pool.block_size
        toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
        n = len(toks) // bs
        return [toks[i * bs:(i + 1) * bs].tobytes() for i in range(n)]

    def _touch(self, node: _Node) -> None:
        self._tick += 1
        node.tick = self._tick

    def match(self, tokens: np.ndarray) -> List[int]:
        """Longest full-block prefix match; increfs each matched block for
        the caller (who now co-owns them via its slot table)."""
        out: List[int] = []
        children = self._children
        for key in self._blocks_of(tokens):
            node = children.get(key)
            if node is None:
                break
            self._touch(node)
            self.pool.incref(node.block)
            out.append(node.block)
            children = node.children
        return out

    def match_len(self, tokens: np.ndarray) -> int:
        """Peek variant of ``match``: matched block count, no references
        taken, no LRU touch (routing probes must not pin blocks)."""
        n = 0
        children = self._children
        for key in self._blocks_of(tokens):
            node = children.get(key)
            if node is None:
                break
            n += 1
            children = node.children
        return n

    def insert(self, tokens: np.ndarray, blocks: List[int]) -> int:
        """Publish a prefilled chain: ``blocks[i]`` holds the KV of the
        i-th full token block.  Existing nodes win (their block carries
        bitwise-identical KV); each newly created node increfs its block.
        Returns the number of nodes created."""
        created = 0
        children = self._children
        parent: Optional[_Node] = None
        for key, blk in zip(self._blocks_of(tokens), blocks):
            node = children.get(key)
            if node is None:
                node = _Node(key, parent, blk)
                self.pool.incref(blk)
                children[key] = node
                self._nodes.append(node)
                created += 1
            self._touch(node)
            parent = node
            children = node.children
        return created

    def _remove(self, node: _Node) -> bool:
        """Drop one (leaf) node; returns True when its block was freed."""
        assert not node.children
        siblings = (node.parent.children if node.parent is not None
                    else self._children)
        del siblings[node.key]
        self._nodes.remove(node)
        return self.pool.decref(node.block)

    def evict(self, need: int = 1) -> int:
        """LRU-evict leaf nodes whose block has no other holder (refcount
        1 = trie only) until ``need`` blocks were freed or no candidate is
        left.  Removing a leaf can expose its parent as the next
        candidate."""
        freed = 0
        while freed < need:
            cands = [n for n in self._nodes
                     if not n.children and self.pool.refcount(n.block) == 1]
            if not cands:
                break
            victim = min(cands, key=lambda n: n.tick)
            if self._remove(victim):
                freed += 1
        return freed

    def drop_all(self) -> None:
        """Release every node (blocks still table-held stay allocated)."""
        while self._nodes:
            leaf = next(n for n in self._nodes if not n.children)
            self._remove(leaf)
        self._children = {}


class KVPool:
    """Slot-table facade over ``BlockPool`` + ``PrefixTrie`` — the surface
    the serving engine drives.

    One serving slot at a time owns each table; ``admit`` releases the
    previous occupant's table, matches the prompt's shared prefix (capped
    to full blocks, to at most ``blocks_per_slot - 1`` blocks, and so that
    at least one suffix token remains), and allocates private blocks for
    the rest of the table.  ``publish`` (called after the suffix prefill
    dispatch completes, so same-wave admissions never alias in-flight
    writes) inserts the slot's full prompt blocks into the trie.
    """

    def __init__(self, *, num_blocks: int, block_size: int, slots: int,
                 blocks_per_slot: int):
        assert num_blocks >= slots * blocks_per_slot, \
            "pool must at least cover every slot's table"
        self.pool = BlockPool(num_blocks, block_size)
        self.trie = PrefixTrie(self.pool)
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.slots = slots
        self.blocks_per_slot = blocks_per_slot
        self._tables: List[Optional[List[int]]] = [None] * slots
        self._matched: List[int] = [0] * slots
        self._tokens: List[Optional[np.ndarray]] = [None] * slots

    # -- admission / retirement ----------------------------------------------

    def _alloc(self) -> Optional[int]:
        b = self.pool.alloc()
        while b is None:
            if not self.trie.evict(1):
                return None
            b = self.pool.alloc()
        return b

    def max_shared_blocks(self, prompt_tokens: int) -> int:
        """Cap on shareable blocks for a prompt: full blocks only, ≥1
        suffix token left for the admission logits, final table block
        always private (it absorbs clamped overflow decode writes)."""
        return max(0, min((prompt_tokens - 1) // self.block_size,
                          self.blocks_per_slot - 1))

    def admit(self, slot: int, tokens: np.ndarray, *, share: bool = True):
        """Bind ``slot`` to a fresh table for ``tokens`` (the truncated
        prompt).  Returns ``(table, matched)`` — the (blocks_per_slot,)
        int32 physical-block table and the number of leading blocks mapped
        onto already-prefilled shared blocks."""
        self.release(slot)
        tokens = np.asarray(tokens, np.int32)
        cap = self.max_shared_blocks(len(tokens))
        matched = (self.trie.match(tokens[:cap * self.block_size])
                   if share and cap else [])
        table = list(matched)
        for _ in range(self.blocks_per_slot - len(matched)):
            b = self._alloc()
            if b is None:
                for blk in table:
                    self.pool.decref(blk)
                raise RuntimeError(
                    f"KV pool exhausted ({self.num_blocks} blocks, "
                    f"{self.trie.n_nodes} trie nodes)")
            table.append(b)
        self._tables[slot] = table
        self._matched[slot] = len(matched)
        self._tokens[slot] = tokens
        return np.asarray(table, np.int32), len(matched)

    def publish(self, slot: int) -> int:
        """Insert the slot's full prompt blocks into the trie (call after
        the prefill dispatch lands).  Returns nodes created."""
        tokens = self._tokens[slot]
        table = self._tables[slot]
        assert tokens is not None and table is not None, f"slot {slot} empty"
        nfull = self.max_shared_blocks(len(tokens) + 1)
        # nfull counts FULL prompt blocks (cap formula with one virtual
        # extra token admits an exactly-full final prompt block), still
        # excluding the table's last block
        nfull = min(nfull, len(tokens) // self.block_size)
        return self.trie.insert(tokens[:nfull * self.block_size],
                                table[:nfull])

    def release(self, slot: int) -> None:
        """Drop the slot's table references (retire / export / reassign)."""
        table = self._tables[slot]
        if table is None:
            return
        for b in table:
            self.pool.decref(b)
        self._tables[slot] = None
        self._matched[slot] = 0
        self._tokens[slot] = None

    # -- introspection --------------------------------------------------------

    def table(self, slot: int) -> Optional[List[int]]:
        return self._tables[slot]

    def shared_blocks(self, slot: int) -> int:
        return self._matched[slot]

    def match_len(self, tokens: np.ndarray) -> int:
        """Shareable-block count a prompt would match right now (peek — the
        router's prefix-affinity score; takes no references)."""
        tokens = np.asarray(tokens, np.int32)
        cap = self.max_shared_blocks(len(tokens))
        return self.trie.match_len(tokens[:cap * self.block_size])

    def stats(self) -> Dict[str, int]:
        table_refs = sum(len(t) for t in self._tables if t is not None)
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "free_blocks": self.pool.free_blocks,
            "allocated_blocks": self.pool.allocated_blocks,
            "trie_nodes": self.trie.n_nodes,
            "table_refs": table_refs,
            "shared_table_blocks": sum(self._matched),
        }

    def check(self) -> None:
        """Full accounting audit: free-list/refcount conservation AND the
        reference total equals table references + trie nodes (no block
        leaked, none double-held)."""
        self.pool.check()
        want = [0] * self.num_blocks
        for t in self._tables:
            for b in (t or []):
                want[b] += 1
        for n in self.trie._nodes:
            want[n.block] += 1
        for b in range(self.num_blocks):
            assert self.pool.refcount(b) == want[b], \
                f"block {b}: refs={self.pool.refcount(b)} holders={want[b]}"

    def close(self) -> None:
        """Release every slot and the trie; asserts nothing leaked."""
        for slot in range(self.slots):
            self.release(slot)
        self.trie.drop_all()
        self.check()
        assert self.pool.allocated_blocks == 0, \
            f"{self.pool.allocated_blocks} blocks leaked"
