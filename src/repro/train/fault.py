"""Fault-injection harness: checkpoint/restart + OCS re-routing end-to-end.

Simulates the paper's §2.3 availability story at container scale on top of
the `repro.cluster` session API:
  1. a job trains on a `Supercomputer`-allocated slice, checkpointing
     periodically;
  2. a block (or its CPU hosts) fails mid-run;
  3. the machine swaps in a spare block (circuits move in ~10 ms) and the
     slice's live session records the reconfiguration event;
  4. the trainer restores the last checkpoint and continues;
  5. (static-cabling mode: the job instead dies and waits for repair).

``run_fault_drill(run, mesh, ...)`` is kept as a thin compatibility wrapper
over the cluster API for existing call sites (tests/test_system.py).
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
from typing import Dict, List, Optional

import numpy as np

from repro.cluster import Supercomputer
from repro.configs.base import RunConfig


@dataclasses.dataclass
class FaultDrillReport:
    steps_run: int
    final_loss: float
    restarts: int
    circuits_moved: int
    reroute_seconds: float
    losses_match_clean_run: bool
    events: List[str]


def run_fault_drill(run: RunConfig, mesh=None, *, total_steps: int = 12,
                    fail_at: int = 7, ckpt_every: int = 5,
                    ckpt_dir: Optional[str] = None) -> FaultDrillReport:
    """Train, kill a block mid-run, re-route, restore, finish — then verify
    the final state matches an uninterrupted run bit-for-bit (deterministic
    data + deterministic restore)."""
    tmp = ckpt_dir or tempfile.mkdtemp(prefix="repro_fault_")
    ref_dir = tmp + "_ref"
    sc = Supercomputer()
    faulted_slice = sc.allocate((8, 8, 8), mesh=mesh)   # 512 chips, 8 blocks
    ref_slice = sc.allocate((8, 8, 8), mesh=mesh)       # coexisting session

    # --- clean reference run
    ref = ref_slice.train(run, total_steps, ckpt_dir=ref_dir,
                          ckpt_every=ckpt_every, log_every=1)
    ref_losses = {m["step"]: m["loss"] for m in ref.metrics_log
                  if "loss" in m}

    # --- faulted run: block failure injected at `fail_at`
    sess = faulted_slice.train(run, total_steps, ckpt_dir=tmp,
                               ckpt_every=ckpt_every, fail_at=fail_at,
                               log_every=1)
    reconfigs = [e for e in sess.interruptions if e.kind == "reconfigure"]
    moved = reconfigs[0].circuits_moved if reconfigs else 0
    secs = reconfigs[0].downtime_s if reconfigs else 0.0
    restarts = sum(1 for m in sess.metrics_log if m.get("event"))
    fl = {m["step"]: m["loss"] for m in sess.metrics_log if "loss" in m}
    final_key = max(fl)
    match = np.isclose(fl[final_key], ref_losses.get(final_key, np.nan),
                       rtol=1e-5)
    events = list(sc.events)

    ref_slice.free()
    faulted_slice.free()
    shutil.rmtree(tmp, ignore_errors=True)
    shutil.rmtree(ref_dir, ignore_errors=True)
    return FaultDrillReport(
        steps_run=sess.state.step,
        final_loss=float(fl[final_key]),
        restarts=restarts,
        circuits_moved=moved,
        reroute_seconds=secs,
        losses_match_clean_run=bool(match),
        events=events,
    )
