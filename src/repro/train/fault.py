"""Fault-injection harness: checkpoint/restart + OCS re-routing end-to-end.

Simulates the paper's §2.3 availability story at container scale:
  1. a job trains on an OCS-scheduled slice, checkpointing periodically;
  2. a block (or its CPU hosts) fails mid-run;
  3. the scheduler swaps in a spare block (circuits move in ~10 ms);
  4. the trainer restores the last checkpoint and continues;
  5. (static-cabling mode: the job instead dies and waits for repair).

Also exercises straggler mitigation (swap a slow block) and elastic restore
(same checkpoint, different mesh shape).
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.core.scheduler import SliceScheduler
from repro.train.trainer import Trainer, TrainerState


@dataclasses.dataclass
class FaultDrillReport:
    steps_run: int
    final_loss: float
    restarts: int
    circuits_moved: int
    reroute_seconds: float
    losses_match_clean_run: bool
    events: List[str]


def run_fault_drill(run: RunConfig, mesh, *, total_steps: int = 12,
                    fail_at: int = 7, ckpt_every: int = 5,
                    ckpt_dir: Optional[str] = None) -> FaultDrillReport:
    """Train, kill a block mid-run, re-route, restore, finish — then verify
    the final state matches an uninterrupted run bit-for-bit (deterministic
    data + deterministic restore)."""
    tmp = ckpt_dir or tempfile.mkdtemp(prefix="repro_fault_")
    scheduler = SliceScheduler()
    job = scheduler.allocate((8, 8, 8))          # 512-chip slice, 8 blocks

    # --- clean reference run
    ref_dir = tmp + "_ref"
    t_ref = Trainer(run, mesh, ckpt_dir=ref_dir, ckpt_every=ckpt_every)
    ref_state = t_ref.train(total_steps, log_every=1)
    ref_losses = {m["step"]: m["loss"] for m in t_ref.metrics_log
                  if "loss" in m}

    # --- faulted run
    trainer = Trainer(run, mesh, ckpt_dir=tmp, ckpt_every=ckpt_every)
    moved = 0
    secs = 0.0
    state = trainer.train(total_steps, fail_at=fail_at,
                          scheduler=scheduler, job_id=job.job_id,
                          log_every=1)
    for ev in scheduler.events:
        if "re-routed" in ev:
            moved = int(ev.split("(")[1].split(" ")[0])
            secs = float(ev.split(", ")[1].split("ms")[0]) / 1e3
    restarts = sum(1 for m in trainer.metrics_log if m.get("event"))
    fl = {m["step"]: m["loss"] for m in trainer.metrics_log if "loss" in m}
    final_key = max(fl)
    match = np.isclose(fl[final_key], ref_losses.get(final_key, np.nan),
                       rtol=1e-5)

    shutil.rmtree(tmp, ignore_errors=True)
    shutil.rmtree(ref_dir, ignore_errors=True)
    return FaultDrillReport(
        steps_run=state.step,
        final_loss=float(fl[final_key]),
        restarts=restarts,
        circuits_moved=moved,
        reroute_seconds=secs,
        losses_match_clean_run=bool(match),
        events=list(scheduler.events),
    )
