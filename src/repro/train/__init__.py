"""`repro.train` — fault-tolerant training: `Trainer` + checkpointing.

The PR-1 `run_fault_drill` compatibility wrapper is gone (PR 4); drive the
§2.3 drill through `repro.cluster`: ``slice.train(run, steps, fail_at=k)``.
"""
from repro.train import checkpoint
from repro.train.trainer import Trainer, TrainerState

__all__ = ["Trainer", "TrainerState", "checkpoint"]
