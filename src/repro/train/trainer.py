"""Fault-tolerant, preemptible trainer (DESIGN.md §8).

Orchestrates: synthetic data -> sharded train step -> periodic checkpoints,
with the OCS scheduler in the loop: on an (injected or real) block failure
the scheduler swaps a spare block in (§2.3), and the trainer restores from
the last checkpoint and continues — the paper's checkpoint/restore,
everything-must-work HPC training style, made cheap by OCS re-routing.

Training is also an *elastic tenant*: `request_preempt` (driven by the
cluster layer's ``"preempt"`` `SliceEvent`) makes the loop checkpoint at
the next step boundary and return early, so a serving burst can reclaim
the blocks.  The checkpoint is slice-shape-elastic (`repro.train.
checkpoint`): a fresh `Trainer` on a *differently shaped* slice restores
it bitwise and continues the exact same loss curve — the data cursor is
just the step (the synthetic `Dataset` is pure in ``(seed, step)``).

On this CPU container the "mesh" is whatever devices exist; the fault and
preemption paths exercise the full restore logic regardless of scale.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, ParallelConfig,
                                RunConfig, ShapeConfig)
from repro.core.scheduler import SliceScheduler
from repro.data.synthetic import Dataset
from repro.launch import steps as STEPS
from repro.launch.mesh import mesh_scope
from repro.models import api
from repro.obs import Telemetry
from repro.optim import adam as OPT
from repro.parallel import sharding as SH
from repro.train import checkpoint as CKPT


@dataclasses.dataclass
class TrainerState:
    """Everything training needs to continue: parameters, optimizer state,
    and the global step (which doubles as the data cursor)."""
    params: Any
    opt_state: Any
    step: int


class Trainer:
    """Training loop bound to one mesh, with checkpoint/restore, fault
    drills, and cooperative preemption.

    Args:
      run: full `RunConfig` (model, shape, parallelism, optimizer).
      mesh: jax mesh to compile and run the train step on.
      ckpt_dir: checkpoint root (no checkpoints when None).
      ckpt_every: periodic checkpoint interval in steps.
      accum_steps: optional gradient-accumulation microsteps.
      slice_dims: chip geometry of the slice this trainer runs on, recorded
        in checkpoint manifests so an elastic resume can report the shape
        change (purely observational).
    """

    def __init__(self, run: RunConfig, mesh, *, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, accum_steps: Optional[int] = None,
                 slice_dims: Optional[tuple] = None,
                 obs: Optional[Telemetry] = None,
                 obs_labels: Optional[Dict[str, Any]] = None):
        self.run = run
        self.mesh = mesh
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.slice_dims = slice_dims
        self.preempt_requested = False
        self.preempted = False
        self.ctx = SH.make_context(mesh, run.parallel)
        self.dataset = Dataset(run.model, run.shape, seed=run.seed)
        # the per-step metric log lives in the registry as a Series;
        # `metrics_log` below is a view of its samples, so the attribute
        # surface (and everything reading it) is unchanged
        self.obs = obs if obs is not None else Telemetry()
        self._obs_labels = dict(obs_labels or {})
        self._series = self.obs.metrics.series("train.metrics",
                                               **self._obs_labels)

        with mesh_scope(mesh):
            # ONE step builder for every entry point (shapes_and_shardings
            # -> make_train_step), so ParallelConfig knobs — notably
            # grad_compression — can't silently apply on one path only
            args, in_sh, out_sh, step = STEPS.shapes_and_shardings(
                run.model, run.shape, run.parallel, run.optimizer, self.ctx,
                accum_steps=accum_steps)
            self._in_sh = jax.tree.map(self._named, in_sh,
                                       is_leaf=self._is_spec)
            self._out_sh = jax.tree.map(self._named, out_sh,
                                        is_leaf=self._is_spec)
            self.train_step = jax.jit(step, in_shardings=self._in_sh,
                                      out_shardings=self._out_sh,
                                      donate_argnums=(0, 1))

    @property
    def metrics_log(self) -> List[Dict[str, float]]:
        """Per-step metric dicts (a view of the registry Series' samples —
        the list object is live, appends land in the registry)."""
        return self._series.samples

    def _named(self, s):
        if s is None:
            return None
        return jax.sharding.NamedSharding(self.mesh, s)

    @staticmethod
    def _is_spec(x):
        return isinstance(x, jax.sharding.PartitionSpec) or x is None

    # -- state ------------------------------------------------------------------

    def init_state(self) -> TrainerState:
        """Fresh params + optimizer state at step 0 (seeded by the run)."""
        key = jax.random.PRNGKey(self.run.seed)
        with mesh_scope(self.mesh):
            params = jax.jit(
                lambda: api.init_params(self.run.model, key, self.ctx),
                out_shardings=self._in_sh[0])()
            opt = jax.jit(
                lambda p: OPT.init(self.run.optimizer, p),
                out_shardings=self._in_sh[1])(params)
        return TrainerState(params, opt, 0)

    def save(self, state: TrainerState) -> None:
        """Checkpoint ``state`` (params + optimizer + data cursor).  The
        manifest records the data seed and source-slice geometry, so a
        resume on a different slice can verify it continues the same data
        stream."""
        if not self.ckpt_dir:
            return
        CKPT.save(self.ckpt_dir, state.step,
                  {"params": state.params, "opt": state.opt_state},
                  extra={"step": state.step, "data_seed": self.run.seed,
                         "slice_dims": (list(self.slice_dims)
                                        if self.slice_dims else None)})

    def request_preempt(self) -> None:
        """Cooperative preemption: ask the running loop to checkpoint and
        stop at the next step boundary (idempotent; safe before `train`
        too — the loop then checkpoints immediately and returns).

        Persistence needs ``ckpt_dir``: without one the loop still stops
        and returns its state, but nothing lands on disk — the caller must
        keep the returned `TrainerState` (passing it back to `train`)
        or the resume falls back to a fresh init."""
        self.preempt_requested = True

    def restore(self, *, mesh=None) -> Optional[TrainerState]:
        """Restore latest checkpoint, optionally onto a different mesh
        (elastic rescale path).  Returns None with no checkpoint on disk."""
        if not self.ckpt_dir or CKPT.latest_step(self.ckpt_dir) is None:
            return None
        key = jax.random.PRNGKey(self.run.seed)
        params_shape = jax.eval_shape(
            lambda: api.init_params(self.run.model, key, self.ctx))
        opt_shape = jax.eval_shape(
            lambda: OPT.init(self.run.optimizer, jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                params_shape)))
        tree, step, extra = CKPT.restore(
            self.ckpt_dir, {"params": params_shape, "opt": opt_shape},
            shardings={"params": self._in_sh[0], "opt": self._in_sh[1]})
        saved_seed = extra.get("data_seed")
        assert saved_seed is None or saved_seed == self.run.seed, (
            f"checkpoint was trained on data seed {saved_seed}, this run "
            f"uses {self.run.seed}: resuming would fork the data stream")
        return TrainerState(tree["params"], tree["opt"], step)

    # -- loop ------------------------------------------------------------------

    def _put_batch(self, step: int):
        batch = self.dataset.batch(step)
        return jax.device_put(batch, self._in_sh[2])

    def train(self, num_steps: int, *, state: Optional[TrainerState] = None,
              fail_at: Optional[int] = None,
              preempt_at: Optional[int] = None,
              scheduler: Optional[SliceScheduler] = None,
              job_id: Optional[int] = None,
              log_every: int = 10,
              on_step: Optional[Callable[[int, float], None]] = None
              ) -> TrainerState:
        """Run the loop to ``num_steps`` (absolute step count).

        Args:
          state: state to continue from (default: latest checkpoint, else a
            fresh init).
          fail_at: inject a block failure at this step — the §2.3 drill:
            the scheduler swaps in a spare and training restores from the
            last checkpoint.
          preempt_at: inject `request_preempt` at this step (tests the
            cooperative-eviction path without a cluster driver).
          scheduler/job_id: OCS scheduler wiring for the fault drill.
          log_every: metric logging period.
          on_step: called after every executed step with
            ``(step, step_wall_s)`` — the hook the straggler detector
            rides (`TrainSession.run` feeds per-block step times from it).

        Returns the final `TrainerState`.  If a preemption request arrived
        (externally or via ``preempt_at``), the loop checkpointed, set
        `preempted`, and returned early — the caller frees the slice and
        resumes later from the checkpoint, on any slice shape."""
        state = state or self.restore() or self.init_state()
        t0 = time.time()
        step = state.step
        self.preempted = False
        while step < num_steps:
            if preempt_at is not None and step == preempt_at:
                preempt_at = None
                self.request_preempt()
            if self.preempt_requested:
                # cooperative eviction: persist everything (params, opt
                # state, data cursor = step) and hand the slice back
                self.save(state)
                self.preempt_requested = False
                self.preempted = True
                self._series.append({"step": step, "preempt": 1.0})
                self.obs.event("train.preempt", cat="train", track="train",
                               step=step, **self._obs_labels)
                return state
            if fail_at is not None and step == fail_at:
                # -- simulated block failure (TrainSession.run drives this)
                if scheduler is not None and job_id is not None:
                    blk = scheduler.jobs[job_id].blocks[0]
                    scheduler.fail_block(blk)
                fail_at = None
                restored = self.restore()
                if restored is not None:
                    state = restored
                    step = state.step
                    self._series.append({"step": step, "event": 1.0})
                    self.obs.event("train.restore", cat="train",
                                   track="train", step=step,
                                   **self._obs_labels)
                    continue
            t_step = time.perf_counter()
            with self.obs.span("train.step", cat="train", track="train",
                               step=step):
                batch = self._put_batch(step)
                with mesh_scope(self.mesh):
                    params, opt, metrics = self.train_step(
                        state.params, state.opt_state, batch)
            state = TrainerState(params, opt, step + 1)
            step += 1
            if on_step is not None:
                on_step(step, time.perf_counter() - t_step)
            if step % log_every == 0 or step == num_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, wall_s=round(time.time() - t0, 2))
                self._series.append(m)
                # wire accounting rides the registry too: last-observed
                # per-step payload bytes from the compressed collectives
                for k in ("wire_bytes", "wire_bytes_full",
                          "wire_overhead_bytes"):
                    if k in m:
                        self.obs.metrics.gauge(
                            f"train.{k}", **self._obs_labels).set(m[k])
            if self.ckpt_dir and step % self.ckpt_every == 0:
                self.save(state)
        if self.preempt_requested:
            # a request that arrived with no steps left to run (entered at
            # step >= num_steps, or raced the final step): service it here
            # so the flag never leaks into the next call and the caller
            # still gets the checkpointed/preempted contract
            self.save(state)
            self.preempt_requested = False
            self.preempted = True
            self._series.append({"step": step, "preempt": 1.0})
            self.obs.event("train.preempt", cat="train", track="train",
                           step=step, **self._obs_labels)
            return state
        if self.ckpt_dir:
            self.save(state)
        return state
