"""Fault-tolerant trainer (DESIGN.md §8).

Orchestrates: synthetic data -> sharded train step -> periodic checkpoints,
with the OCS scheduler in the loop: on an (injected or real) block failure
the scheduler swaps a spare block in (§2.3), and the trainer restores from
the last checkpoint and continues — the paper's checkpoint/restore,
everything-must-work HPC training style, made cheap by OCS re-routing.

On this CPU container the "mesh" is whatever devices exist; the fault path
exercises the full restore logic regardless of scale.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, ParallelConfig,
                                RunConfig, ShapeConfig)
from repro.core.scheduler import SliceScheduler
from repro.data.synthetic import Dataset
from repro.launch import steps as STEPS
from repro.launch.mesh import mesh_scope
from repro.models import api
from repro.optim import adam as OPT
from repro.parallel import sharding as SH
from repro.train import checkpoint as CKPT


@dataclasses.dataclass
class TrainerState:
    params: Any
    opt_state: Any
    step: int


class Trainer:
    def __init__(self, run: RunConfig, mesh, *, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, accum_steps: Optional[int] = None):
        self.run = run
        self.mesh = mesh
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.ctx = SH.make_context(mesh, run.parallel)
        self.dataset = Dataset(run.model, run.shape, seed=run.seed)
        self.metrics_log: List[Dict[str, float]] = []

        with mesh_scope(mesh):
            args, in_sh, out_sh, step = STEPS.shapes_and_shardings(
                run.model, run.shape, run.parallel, run.optimizer, self.ctx)
            if accum_steps is not None:
                step = STEPS.make_train_step(
                    run.model, run.shape, run.parallel, run.optimizer,
                    self.ctx, accum_steps=accum_steps)
            self._in_sh = jax.tree.map(self._named, in_sh,
                                       is_leaf=self._is_spec)
            self._out_sh = jax.tree.map(self._named, out_sh,
                                        is_leaf=self._is_spec)
            self.train_step = jax.jit(step, in_shardings=self._in_sh,
                                      out_shardings=self._out_sh,
                                      donate_argnums=(0, 1))

    def _named(self, s):
        if s is None:
            return None
        return jax.sharding.NamedSharding(self.mesh, s)

    @staticmethod
    def _is_spec(x):
        return isinstance(x, jax.sharding.PartitionSpec) or x is None

    # -- state ------------------------------------------------------------------

    def init_state(self) -> TrainerState:
        key = jax.random.PRNGKey(self.run.seed)
        with mesh_scope(self.mesh):
            params = jax.jit(
                lambda: api.init_params(self.run.model, key, self.ctx),
                out_shardings=self._in_sh[0])()
            opt = jax.jit(
                lambda p: OPT.init(self.run.optimizer, p),
                out_shardings=self._in_sh[1])(params)
        return TrainerState(params, opt, 0)

    def save(self, state: TrainerState) -> None:
        if not self.ckpt_dir:
            return
        CKPT.save(self.ckpt_dir, state.step,
                  {"params": state.params, "opt": state.opt_state},
                  extra={"step": state.step})

    def restore(self, *, mesh=None) -> Optional[TrainerState]:
        """Restore latest checkpoint, optionally onto a different mesh
        (elastic rescale path)."""
        if not self.ckpt_dir or CKPT.latest_step(self.ckpt_dir) is None:
            return None
        key = jax.random.PRNGKey(self.run.seed)
        params_shape = jax.eval_shape(
            lambda: api.init_params(self.run.model, key, self.ctx))
        opt_shape = jax.eval_shape(
            lambda: OPT.init(self.run.optimizer, jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                params_shape)))
        tree, step, _ = CKPT.restore(
            self.ckpt_dir, {"params": params_shape, "opt": opt_shape},
            shardings={"params": self._in_sh[0], "opt": self._in_sh[1]})
        return TrainerState(tree["params"], tree["opt"], step)

    # -- loop ------------------------------------------------------------------

    def _put_batch(self, step: int):
        batch = self.dataset.batch(step)
        return jax.device_put(batch, self._in_sh[2])

    def train(self, num_steps: int, *, state: Optional[TrainerState] = None,
              fail_at: Optional[int] = None,
              scheduler: Optional[SliceScheduler] = None,
              job_id: Optional[int] = None,
              log_every: int = 10) -> TrainerState:
        state = state or self.restore() or self.init_state()
        t0 = time.time()
        step = state.step
        while step < num_steps:
            if fail_at is not None and step == fail_at:
                # -- simulated block failure (TrainSession.run drives this)
                if scheduler is not None and job_id is not None:
                    blk = scheduler.jobs[job_id].blocks[0]
                    scheduler.fail_block(blk)
                fail_at = None
                restored = self.restore()
                if restored is not None:
                    state = restored
                    step = state.step
                    self.metrics_log.append(
                        {"step": step, "event": 1.0})
                    continue
            batch = self._put_batch(step)
            with mesh_scope(self.mesh):
                params, opt, metrics = self.train_step(
                    state.params, state.opt_state, batch)
            state = TrainerState(params, opt, step + 1)
            step += 1
            if step % log_every == 0 or step == num_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, wall_s=round(time.time() - t0, 2))
                self.metrics_log.append(m)
            if self.ckpt_dir and step % self.ckpt_every == 0:
                self.save(state)
        if self.ckpt_dir:
            self.save(state)
        return state
