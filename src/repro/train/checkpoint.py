"""Sharded checkpointing with elastic restore (DESIGN.md §8).

Layout: one directory per step containing
  * ``manifest.json`` — pytree structure, per-leaf shape/dtype, step metadata;
  * ``arrays.npz``    — every leaf as a dense host array (single-process
    container; in a multi-host deployment each host writes its shard files —
    the manifest format already records per-leaf sharding for that).

Elastic restore: arrays are saved mesh-agnostically (fully materialised), so
``restore(..., shardings=...)`` can re-lay them out onto a *different* mesh —
the checkpoint/restart path when the OCS scheduler re-slices after failures
or when scaling the job up/down (§2.3 / §2.5).
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        out[jax.tree_util.keystr(path)] = leaf
    return out


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None
         ) -> pathlib.Path:
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        dtype = str(arr.dtype)
        if dtype not in ("float64", "float32", "float16", "int64", "int32",
                         "int16", "int8", "uint8", "uint16", "uint32",
                         "uint64", "bool"):
            # npz can't serialise ml_dtypes (bfloat16 etc.) — store a
            # lossless float32 upcast and record the original dtype
            arr = arr.astype(np.float32)
        arrays[k] = arr
        manifest["leaves"][k] = {"shape": list(arr.shape), "dtype": dtype}
    np.savez(d / "arrays.npz", **arrays)
    (d / "manifest.json").write_text(json.dumps(manifest))
    (pathlib.Path(ckpt_dir) / "LATEST").write_text(str(step))
    return d


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = pathlib.Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(ckpt_dir: str, tree_like, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like`` (shapes/dtypes pytree).

    ``shardings``: optional matching pytree of NamedShardings for the target
    mesh (elastic re-layout happens here via device_put).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, like in flat:
        k = jax.tree_util.keystr(path)
        arr = data[k]
        want = tuple(like.shape)
        assert tuple(arr.shape) == want, (k, arr.shape, want)
        leaves.append(jnp.asarray(arr).astype(like.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step, manifest.get("extra", {})
