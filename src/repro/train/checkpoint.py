"""Slice-shape-elastic sharded checkpointing.

The checkpoint is the unit of elasticity in this repo: a preempted or
failed training job saves here, frees its blocks, and later resumes on a
slice with a *different* block count / geometry / mesh — the §2.3/§2.5
carve-and-reclaim story needs state that outlives any particular slice.

Layout — one directory per step:

  * ``manifest.json`` — format version, step, data cursor/extra metadata,
    pytree structure with per-leaf global shape/dtype and the list of
    *spans* (index ranges) each shard file holds;
  * ``shard_NNN.npz`` — the leaf data, one file per writer.  A leaf that is
    sharded across devices (or split with ``shards=N`` for parallel IO)
    appears as several spans spread over several files; a replicated leaf
    is written once.

Elasticity comes from the span representation: ``save`` records *where in
the global array* each saved chunk lives (taken from the jax.Array's
addressable shards, deduplicated across replicas), and ``restore``
reassembles the global array from spans and re-lays it out onto the target
mesh via ``device_put`` with the caller's shardings.  Nothing about the
source mesh shape survives into the restored arrays, so save on an 8-block
slice / restore on a 2-block slice is the same code path as a same-shape
round-trip (bitwise-identical — pinned by tests/test_optim_checkpoint.py).

Format v1 (single ``arrays.npz``, PR-1..4 checkpoints) restores
transparently.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

FORMAT_VERSION = 2

# dtypes npz can serialise natively; anything else (bfloat16 & friends from
# ml_dtypes) is stored as a lossless float32 upcast and cast back on restore
_NATIVE_DTYPES = ("float64", "float32", "float16", "int64", "int32",
                  "int16", "int8", "uint8", "uint16", "uint32", "uint64",
                  "bool")


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        out[jax.tree_util.keystr(path)] = leaf
    return out


def _leaf_spans(leaf, arr: np.ndarray, shards: int
                ) -> List[Tuple[Tuple[int, ...], Tuple[int, ...],
                                np.ndarray]]:
    """Break one leaf into (start, stop, data) spans.

    Sharded jax.Arrays contribute their addressable shards (deduplicated
    across replicas — each distinct index range is written once); host
    arrays and replicated leaves are optionally split along their first
    axis into ``shards`` chunks for parallel IO."""
    ndim = arr.ndim
    if isinstance(leaf, jax.Array) and not leaf.is_fully_replicated:
        seen = set()
        spans = []
        for sh in leaf.addressable_shards:
            idx = sh.index if isinstance(sh.index, tuple) else (sh.index,)
            start = tuple((s.start or 0) for s in idx)
            stop = tuple(s.stop if s.stop is not None else dim
                         for s, dim in zip(idx, arr.shape))
            if (start, stop) in seen:
                continue
            seen.add((start, stop))
            # slice the (dtype-normalised) global host copy rather than
            # sh.data: spans must all be in saved_dtype
            sel = tuple(slice(a, b) for a, b in zip(start, stop))
            spans.append((start, stop, arr[sel]))
        if spans:
            return spans
    if shards > 1 and ndim >= 1 and arr.shape[0] >= 2:
        n = min(shards, arr.shape[0])
        cuts = np.linspace(0, arr.shape[0], n + 1, dtype=int)
        spans = []
        for lo, hi in zip(cuts[:-1], cuts[1:]):
            if lo == hi:
                continue
            start = (int(lo),) + (0,) * (ndim - 1)
            stop = (int(hi),) + tuple(arr.shape[1:])
            spans.append((start, stop, arr[lo:hi]))
        return spans
    full_start = (0,) * ndim
    return [(full_start, tuple(arr.shape), arr)]


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None,
         shards: int = 1, keep: Optional[int] = None) -> pathlib.Path:
    """Write one elastic checkpoint.

    Args:
      ckpt_dir: checkpoint root; the step lands in ``step_{step:08d}/``.
      step: global training step (also the data cursor — the synthetic
        `Dataset` is pure in ``(seed, step)``, so step alone pins the
        exact next batch on resume).
      tree: any pytree of jax/numpy arrays (params, optimizer state, …).
      extra: JSON-serialisable metadata stored in the manifest (the trainer
        records the data seed and source-slice geometry here).
      shards: split each unsharded leaf into up to this many spans along
        its first axis (parallel-IO layout; sharded jax.Arrays already
        write one span per distinct device shard).
      keep: retention policy — after this save fully lands (manifest +
        LATEST written), prune all but the newest ``keep`` span-manifest
        step directories (`gc`).  None keeps everything.

    Returns the step directory path."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    manifest: Dict[str, Any] = {"format": FORMAT_VERSION, "step": step,
                                "extra": extra or {}, "leaves": {}}
    files: List[Dict[str, np.ndarray]] = []      # shard file -> npz payload
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        dtype = str(arr.dtype)
        if dtype not in _NATIVE_DTYPES:
            arr = arr.astype(np.float32)
        spans = _leaf_spans(v, arr, shards)
        entry = {"shape": list(arr.shape), "dtype": dtype,
                 "saved_dtype": str(arr.dtype), "spans": []}
        for i, (start, stop, data) in enumerate(spans):
            while len(files) <= i:
                files.append({})
            # NB: ascontiguousarray would promote 0-d leaves to 1-d
            files[i][k] = (np.ascontiguousarray(data) if data.ndim
                           else np.asarray(data))
            entry["spans"].append({"file": f"shard_{i:03d}",
                                   "start": list(start),
                                   "stop": list(stop)})
        manifest["leaves"][k] = entry
    for i, payload in enumerate(files):
        np.savez(d / f"shard_{i:03d}.npz", **payload)
    (d / "manifest.json").write_text(json.dumps(manifest))
    (pathlib.Path(ckpt_dir) / "LATEST").write_text(str(step))
    if keep is not None:
        gc(ckpt_dir, keep)
    return d


def gc(ckpt_dir: str, keep: int) -> List[pathlib.Path]:
    """Prune old span-manifest checkpoints, keeping the newest ``keep``.

    Only directories this module wrote in the current format are
    candidates: a ``step_*`` directory is pruned iff it carries a
    ``manifest.json`` with ``format >= 2`` (the span-manifest layout).
    Legacy v1 checkpoints (``arrays.npz``, format-1 manifests) and any
    unrecognised directory are never touched — retention must not eat
    checkpoints written by code that predates the policy.  The step named
    by ``LATEST`` is always kept, whatever its age.

    Runs after a *successful* save (`save(..., keep=N)` calls it once the
    manifest and LATEST are on disk), so a crash mid-save never costs an
    old checkpoint.  Returns the pruned directories."""
    assert keep >= 1, keep
    root = pathlib.Path(ckpt_dir)
    latest = latest_step(ckpt_dir)
    cands: List[Tuple[int, pathlib.Path]] = []
    for d in root.glob("step_*"):
        if not d.is_dir():
            continue
        try:
            step = int(d.name.split("_", 1)[1])
        except ValueError:
            continue
        mf = d / "manifest.json"
        if not mf.exists():
            continue                      # not ours (or torn) — keep
        try:
            fmt = json.loads(mf.read_text()).get("format", 1)
        except (json.JSONDecodeError, OSError):
            continue                      # unreadable — keep, never guess
        if fmt < 2 or (d / "arrays.npz").exists():
            continue                      # legacy v1 layout — never GC'd
        cands.append((step, d))
    cands.sort()
    prune = [d for step, d in cands[:-keep] if step != latest]
    for d in prune:
        for f in d.iterdir():
            f.unlink()
        d.rmdir()
    return prune


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Step number of the newest checkpoint under ``ckpt_dir`` (or None)."""
    p = pathlib.Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def read_manifest(ckpt_dir: str, step: Optional[int] = None
                  ) -> Dict[str, Any]:
    """Load a checkpoint's manifest (latest step when ``step`` is None)."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((d / "manifest.json").read_text())


def _assemble(d: pathlib.Path, entry: Dict[str, Any], key: str,
              shard_cache: Dict[str, Any]) -> np.ndarray:
    """Rebuild one leaf's global host array from its manifest spans."""
    shape = tuple(entry["shape"])
    spans = entry["spans"]
    if (len(spans) == 1 and tuple(spans[0]["start"]) == (0,) * len(shape)
            and tuple(spans[0]["stop"]) == shape):
        data = _shard(d, spans[0]["file"], shard_cache)[key]
        return np.asarray(data).reshape(shape)
    out = np.empty(shape, dtype=np.dtype(entry["saved_dtype"]))
    covered = 0
    for sp in spans:
        sel = tuple(slice(a, b) for a, b in zip(sp["start"], sp["stop"]))
        chunk = _shard(d, sp["file"], shard_cache)[key]
        out[sel] = chunk
        covered += int(np.prod([b - a for a, b in
                                zip(sp["start"], sp["stop"])]))
    assert covered == int(np.prod(shape)), \
        f"{key}: spans cover {covered} of {int(np.prod(shape))} elements"
    return out


def _shard(d: pathlib.Path, name: str, cache: Dict[str, Any]):
    if name not in cache:
        cache[name] = np.load(d / f"{name}.npz")
    return cache[name]


def restore(ckpt_dir: str, tree_like, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, Dict]:
    """Restore a checkpoint into the structure of ``tree_like``.

    Args:
      ckpt_dir: checkpoint root written by `save`.
      tree_like: pytree of ``ShapeDtypeStruct``-likes giving the target
        structure, shapes, and dtypes (shapes must match the saved global
        shapes — elasticity changes the *layout*, not the math).
      step: explicit step to restore (default: latest).
      shardings: optional matching pytree of ``NamedSharding``s for the
        target mesh — this is the elastic re-layout: spans are assembled
        into the global array on host and ``device_put`` carves it onto
        whatever mesh the *new* slice has, regardless of how the source
        slice was shaped.

    Returns ``(tree, step, extra)``."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    version = manifest.get("format", 1)
    shard_cache: Dict[str, Any] = {}
    legacy = np.load(d / "arrays.npz") if version < 2 else None

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, like in flat:
        k = jax.tree_util.keystr(path)
        if legacy is not None:
            arr = legacy[k]
        else:
            arr = _assemble(d, manifest["leaves"][k], k, shard_cache)
        want = tuple(like.shape)
        assert tuple(arr.shape) == want, (k, arr.shape, want)
        leaves.append(jnp.asarray(arr).astype(like.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step, manifest.get("extra", {})
