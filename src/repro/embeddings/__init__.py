"""`repro.embeddings` — the SparseCore embedding pipeline (§3)."""
from repro.embeddings.cache import HotIdCache
from repro.embeddings.dedup import dedup_ids, dedup_ratio
from repro.embeddings.engine import (EmbeddingCollection,
                                     PipelinedEmbeddingExecutor,
                                     lookup_reference, materialize_tables)
from repro.embeddings.sharding import (Placement, plan_placement,
                                       plan_summary)

__all__ = [
    "EmbeddingCollection", "HotIdCache", "PipelinedEmbeddingExecutor",
    "Placement", "dedup_ids", "dedup_ratio", "lookup_reference",
    "materialize_tables", "plan_placement", "plan_summary",
]
