"""Embedding-table placement planner (paper §3.3).

"There are three methods for partitioning: (1) column sharding splits tables
along their width, (2) row sharding splits tables along their vocabulary size,
and (3) table sharding places different tables on different chips.  For small
embedding tables, replication across all chips (using data parallelism) is
better for performance."

The planner assigns each table one of:
  * ``replicate``  — small tables, zero comm at lookup, all-reduce grads;
  * ``row``        — vocab split over the model axis, ids/vectors all-to-all;
  * ``table``      — whole table on one model shard (greedy size balancing),
                     results psum-merged;
  * ``column``     — width split over the model axis (kept for wide tables
                     feeding width-sharded dense layers).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

from repro.configs.base import EmbeddingTableConfig

REPLICATE_BYTES = 4 << 20       # tables under 4 MiB replicate
TABLE_SHARD_BYTES = 256 << 20   # mid-size tables are whole-table placed


@dataclass(frozen=True)
class Placement:
    strategy: str               # replicate | row | table | column
    shard: int = 0              # owning shard (table strategy)
    padded_vocab: int = 0       # vocab padded to a multiple of the axis size


def plan_placement(tables: Sequence[EmbeddingTableConfig],
                   num_shards: int,
                   bytes_per_param: int = 4) -> Dict[str, Placement]:
    """Greedy plan matching the paper's guidance."""
    plan: Dict[str, Placement] = {}
    load = [0] * max(num_shards, 1)
    # big tables first so table-sharding balances well
    order = sorted(tables, key=lambda t: -t.vocab_size * t.dim)
    for t in order:
        size = t.vocab_size * t.dim * bytes_per_param
        if num_shards <= 1 or size <= REPLICATE_BYTES:
            plan[t.name] = Placement("replicate")
            continue
        if size <= TABLE_SHARD_BYTES:
            shard = min(range(num_shards), key=lambda i: load[i])
            load[shard] += size
            plan[t.name] = Placement("table", shard=shard)
            continue
        pad = (-t.vocab_size) % num_shards
        for i in range(num_shards):
            load[i] += size // num_shards
        plan[t.name] = Placement("row", padded_vocab=t.vocab_size + pad)
    return plan


def plan_summary(plan: Dict[str, Placement]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for p in plan.values():
        out[p.strategy] = out.get(p.strategy, 0) + 1
    return out
