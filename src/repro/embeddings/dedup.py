"""Feature-ID deduplication (paper §3.4).

"To reduce load imbalance, deduplication of frequent feature values is
commonly used ... Deduplication also reduces the number of memory accesses,
and the quantity of data sent over the interconnection network."

Sort-based, static-size (jit-compatible) dedup: returns the unique ids (padded
with -1) plus the inverse map so gathered vectors can be broadcast back to
every occurrence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def dedup_ids(ids: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """ids: (N,) int32 with -1 padding.

    Returns (unique (N,) int32 sorted, padded with -1 at the tail;
             inverse (N,) int32 s.t. unique[inverse] == ids for valid entries;
             num_unique () int32).
    """
    n = ids.shape[0]
    # Map padding to a sentinel that sorts last, then unique with static size.
    big = jnp.int32(2147483647)
    clean = jnp.where(ids < 0, big, ids)
    uniq, inv = jnp.unique(clean, return_inverse=True, size=n,
                           fill_value=big)
    num = jnp.sum(uniq != big).astype(jnp.int32)
    uniq = jnp.where(uniq == big, -1, uniq)
    return uniq, inv.astype(jnp.int32), num


def dedup_ratio(ids: jax.Array) -> jax.Array:
    """Fraction of lookups saved by dedup (0 = all distinct)."""
    valid = (ids >= 0).sum()
    _, _, num = dedup_ids(ids)
    return 1.0 - num / jnp.maximum(valid, 1)
