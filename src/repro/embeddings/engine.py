"""Sharded embedding engine — the SparseCore execution model in JAX (§3.5).

The SC places embedding tables anywhere in the machine's collective HBM and
moves (deduplicated) ids to row owners and vectors back with variable-length
all-to-alls over ICI.  This engine reproduces that dataflow:

  ids --dedup--> unique ids --all-to-all--> row owners --gather (Pallas)-->
  vectors --all-to-all--> requesters --segment combine--> dense activations

Two distributed modes share the row-sharded storage:
  * ``a2a``  — the paper-faithful path above (ids sharded over the model axis).
  * ``psum`` — ids replicated over the model axis; each shard partially
    combines its local rows and the partials are psum-merged.  Cheaper for
    small valency, used as an auto fallback and as a §Perf comparison point.

Tables of the same width are concatenated into one row space ("groups");
table-sharding (paper §3.3) is row-sharding the concatenation with
shard-aligned offsets, so all strategies use one code path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig
from repro.embeddings.dedup import dedup_ids
from repro.embeddings.sharding import Placement, plan_placement
from repro.parallel.context import LOCAL, ParallelContext, shard_map

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# Group layout
# ---------------------------------------------------------------------------

@dataclass
class TableSlot:
    spec: EmbeddingTableConfig
    offset: int            # row offset inside the group array
    rows: int              # padded rows reserved


@dataclass
class Group:
    dim: int
    slots: List[TableSlot] = field(default_factory=list)
    total_rows: int = 0

    @property
    def name(self) -> str:
        return f"group_d{self.dim}"


class EmbeddingCollection:
    """Plans placement and owns the parameter layout for a set of tables."""

    def __init__(self, tables: Sequence[EmbeddingTableConfig],
                 num_shards: int):
        self.tables = list(tables)
        self.num_shards = max(1, num_shards)
        self.plan = plan_placement(tables, self.num_shards)
        self.replicated: List[EmbeddingTableConfig] = []
        self.groups: Dict[int, Group] = {}
        # deterministic order: big tables first within each group
        for t in sorted(tables, key=lambda t: -t.vocab_size * t.dim):
            placement = self.plan[t.name]
            if placement.strategy == "replicate":
                self.replicated.append(t)
                continue
            g = self.groups.setdefault(t.dim, Group(dim=t.dim))
            off = g.total_rows
            if placement.strategy == "table":
                # shard-align so the table lands on as few shards as possible
                pass  # alignment applied after all rows known (below)
            rows = t.vocab_size
            g.slots.append(TableSlot(t, off, rows))
            g.total_rows += rows
        # pad every group to a multiple of num_shards
        for g in self.groups.values():
            pad = (-g.total_rows) % self.num_shards
            g.total_rows += pad

    # -- params -------------------------------------------------------------

    def init(self, key) -> Dict[str, Any]:
        params: Dict[str, Any] = {}
        keys = jax.random.split(key, len(self.groups) + len(self.replicated))
        i = 0
        for dim, g in sorted(self.groups.items()):
            params[g.name] = (jax.random.normal(
                keys[i], (g.total_rows, dim), jnp.float32) * 0.01)
            i += 1
        for t in self.replicated:
            params[t.name] = (jax.random.normal(
                keys[i], (t.vocab_size, t.dim), jnp.float32) * 0.01)
            i += 1
        return params

    def param_specs(self, ctx: ParallelContext) -> Dict[str, Any]:
        """PartitionSpecs matching init()'s pytree."""
        specs: Dict[str, Any] = {}
        for dim, g in sorted(self.groups.items()):
            specs[g.name] = ctx.spec(ctx.model_axis, None)
        for t in self.replicated:
            specs[t.name] = ctx.spec(None, None)
        return specs

    # -- lookup ---------------------------------------------------------------

    def lookup(self, params, features: Dict[str, jax.Array],
               ctx: ParallelContext = LOCAL, *, method: str = "auto",
               use_kernel: bool = False) -> Dict[str, jax.Array]:
        """features: name -> (B, max_valency) int32 ids, -1 padded.

        Returns name -> (B, dim) combined embeddings.
        """
        out: Dict[str, jax.Array] = {}
        for t in self.replicated:
            out[t.name] = _combine(
                _gather_rows(params[t.name], features[t.name], use_kernel),
                features[t.name], t.combiner)
        for dim, g in sorted(self.groups.items()):
            got = self._lookup_group(params[g.name], g, features, ctx,
                                     method=method, use_kernel=use_kernel)
            out.update(got)
        return out

    def _lookup_group(self, table, g: Group, features, ctx: ParallelContext,
                      *, method: str, use_kernel: bool):
        # concat ids with offsets; remember per-table column spans
        cols: List[Tuple[str, int, int, str]] = []
        parts = []
        c0 = 0
        for s in g.slots:
            ids = features[s.spec.name]
            parts.append(jnp.where(ids >= 0, ids + s.offset, -1))
            cols.append((s.spec.name, c0, c0 + ids.shape[1], s.spec.combiner))
            c0 += ids.shape[1]
        ids_all = jnp.concatenate(parts, axis=1)          # (B, Vg)

        ms = ctx.model_axis_size
        if method == "auto" and ctx.emb_method != "auto":
            method = ctx.emb_method
        if ms <= 1 or not ctx.has_mesh or method == "local":
            rows = _gather_rows(table, ids_all, use_kernel)
            out = {}
            for name, a, b, combiner in cols:
                out[name] = _combine(rows[:, a:b], ids_all[:, a:b], combiner)
            return out
        # distributed paths combine INSIDE the shard_map so only (B, K, D)
        # combined vectors cross shard boundaries, never (B, Vg, D) rows
        if method == "psum" or (method == "auto" and ids_all.shape[1] <= 4):
            combined = _rowsharded_psum(table, ids_all, ctx, cols=cols)
        else:
            combined = _rowsharded_a2a(
                table, ids_all, ctx, cols=cols,
                capacity_factor=ctx.emb_capacity_factor)
        return {name: combined[:, i]
                for i, (name, a, b, comb) in enumerate(cols)}


# ---------------------------------------------------------------------------
# Local gather + combine
# ---------------------------------------------------------------------------

def _gather_rows(table, ids, use_kernel: bool = False):
    """(V, D), (B, Vl) -> (B, Vl, D); invalid ids give zero rows."""
    if use_kernel:
        from repro.kernels import ops as KOPS
        return KOPS.embedding_gather(table, ids)
    valid = (ids >= 0)[..., None]
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    return jnp.where(valid, rows, 0.0)


def _combine(rows, ids, combiner: str):
    """(B, Vl, D), (B, Vl) -> (B, D)."""
    valid = (ids >= 0).astype(rows.dtype)
    out = (rows * valid[..., None]).sum(axis=1)
    if combiner == "mean":
        out = out / jnp.maximum(valid.sum(axis=1), 1.0)[..., None]
    return out


# ---------------------------------------------------------------------------
# Distributed row-sharded lookups
# ---------------------------------------------------------------------------

def _segment_combine(rows, ids, cols):
    """(B, Vg, D) rows -> (B, K, D) per-table combined vectors (local op)."""
    B, Vg, D = rows.shape
    K = len(cols)
    sel = np.zeros((Vg, K), np.float32)
    for i, (name, a, b, comb) in enumerate(cols):
        sel[a:b, i] = 1.0
    sel = jnp.asarray(sel)
    valid = (ids >= 0).astype(rows.dtype)
    out = jnp.einsum("bvd,vk->bkd", rows * valid[..., None], sel)
    counts = jnp.einsum("bv,vk->bk", valid, sel)
    means = jnp.asarray([c == "mean" for *_, c in cols])
    denom = jnp.where(means[None, :], jnp.maximum(counts, 1.0), 1.0)
    return out / denom[..., None]


def _rowsharded_psum(table, ids, ctx: ParallelContext, *, cols):
    """ids replicated over the model axis; shards partially gather, combine
    locally to (B, K, D), and psum the combined vectors."""
    axis = ctx.model_axis
    ms = ctx.model_axis_size
    bspec = (ctx.batch_axes or None) if ctx.has_mesh else None
    V = table.shape[0]
    rps = V // ms

    def local(table_loc, ids_loc):
        base = jax.lax.axis_index(axis) * rps
        lid = ids_loc - base
        ok = (ids_loc >= 0) & (lid >= 0) & (lid < rps)
        rows = jnp.take(table_loc, jnp.clip(lid, 0, rps - 1), axis=0)
        rows = jnp.where(ok[..., None], rows, 0.0)
        combined = _segment_combine(rows, ids_loc, cols)
        if ctx.emb_wire_bf16:
            combined = combined.astype(jnp.bfloat16)  # §Perf: half traffic
        return jax.lax.psum(combined, axis)

    fn = shard_map(
        local, mesh=ctx.mesh,
        in_specs=(P(axis, None), P(bspec, None)),
        out_specs=P(bspec, None, None), check_vma=False)
    return fn(table, ids)


def _rowsharded_a2a(table, ids, ctx: ParallelContext, *, cols,
                    capacity_factor: float = 2.0):
    """The paper-faithful SparseCore path: dedup → id all-to-all → owner
    gather → vector all-to-all → per-occurrence broadcast → LOCAL combine.

    ids: (B, Vl) with B sharded over (batch_axes, model) — the sparse stage
    splits the batch over the model axis too, exactly like SC's per-chip
    sample ownership.  Output (B, K, D) combined vectors (only those cross
    shard boundaries on the way back to the dense stack).
    """
    axis = ctx.model_axis
    ms = ctx.model_axis_size
    bspec = (ctx.batch_axes or None) if ctx.has_mesh else None
    batch_both = tuple([*(ctx.batch_axes or ()), axis])
    V, D = table.shape
    rps = V // ms

    def local(table_loc, ids_loc):
        Bl, Vl = ids_loc.shape
        N = Bl * Vl
        C = max(8, int(math.ceil(N / ms * capacity_factor)))
        flat = ids_loc.reshape(N)
        uids, inv, num = dedup_ids(flat)                 # sorted, -1 tail
        valid_u = uids >= 0
        dest = jnp.where(valid_u, uids // rps, ms)       # ms = drop bucket
        # uids sorted => dest monotonic: rank within dest via running index
        start = jnp.searchsorted(dest, jnp.arange(ms), side="left")
        rank = jnp.arange(N) - start[jnp.clip(dest, 0, ms - 1)]
        keep = valid_u & (rank < C)
        slot = jnp.where(keep, dest * C + rank, ms * C)
        send_ids = jnp.full((ms * C + 1,), -1, jnp.int32).at[slot].set(
            uids, mode="drop")[:-1]
        recv_ids = jax.lax.all_to_all(
            send_ids.reshape(ms, C), axis, 0, 0)         # (ms, C)
        # owner-side gather (SC Fetch unit)
        base = jax.lax.axis_index(axis) * rps
        lid = recv_ids - base
        ok = (recv_ids >= 0) & (lid >= 0) & (lid < rps)
        rows = jnp.take(table_loc, jnp.clip(lid, 0, rps - 1), axis=0)
        rows = jnp.where(ok[..., None], rows, 0.0)       # (ms, C, D)
        if ctx.emb_wire_bf16:
            rows = rows.astype(jnp.bfloat16)   # §Perf: halve vector traffic
        vecs = jax.lax.all_to_all(rows, axis, 0, 0)      # (ms, C, D) back
        vflat = jnp.concatenate(
            [vecs.reshape(ms * C, D), jnp.zeros((1, D), vecs.dtype)], 0)
        uvecs = vflat[slot] * keep[:, None].astype(vflat.dtype)
        occ = uvecs[inv]                                 # broadcast to ids
        return _segment_combine(occ.reshape(Bl, Vl, D), ids_loc, cols)

    fn = shard_map(
        local, mesh=ctx.mesh,
        in_specs=(P(axis, None), P(batch_both, None)),
        out_specs=P(batch_both, None, None), check_vma=False)
    # reshard batch over (data, model) for the sparse stage, back after
    ids = jax.lax.with_sharding_constraint(
        ids, jax.sharding.NamedSharding(ctx.mesh, P(batch_both, None)))
    combined = fn(table, ids)
    return jax.lax.with_sharding_constraint(
        combined, jax.sharding.NamedSharding(ctx.mesh, P(bspec, None, None)))


# ---------------------------------------------------------------------------
# Reference (oracle for tests)
# ---------------------------------------------------------------------------

def materialize_tables(coll: EmbeddingCollection, params
                       ) -> Dict[str, jax.Array]:
    """Slice the grouped storage back into per-table (V, D) arrays."""
    out = {}
    for t in coll.replicated:
        out[t.name] = params[t.name]
    for dim, g in sorted(coll.groups.items()):
        arr = params[g.name]
        for s in g.slots:
            out[s.spec.name] = arr[s.offset: s.offset + s.spec.vocab_size]
    return out


def lookup_reference(tables: Dict[str, jax.Array],
                     specs: Sequence[EmbeddingTableConfig],
                     features: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    out = {}
    for t in specs:
        rows = _gather_rows(tables[t.name], features[t.name])
        out[t.name] = _combine(rows, features[t.name], t.combiner)
    return out
