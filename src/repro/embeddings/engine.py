"""Sharded embedding engine — the SparseCore execution model in JAX (§3.5).

The SC places embedding tables anywhere in the machine's collective HBM and
moves (deduplicated) ids to row owners and vectors back with variable-length
all-to-alls over ICI.  This engine reproduces that dataflow as a **pipelined
multi-group executor**:

  ids --dedup--> unique ids --all-to-all--> row owners --gather (Pallas)-->
  vectors --all-to-all--> requesters --segment combine--> dense activations

Fused descriptor layout
-----------------------
Locally-resident tables (every table on one device; the replicated set under
sharding) are no longer looked up one launch per table.  All of them are
viewed as ONE row space: the concatenation of each width-group's rows, lanes
padded to the widest dim, addressed by a *descriptor stream* —

    rows  (B, S) : absolute fused row id per (sample, descriptor column),
                   i.e. ``group_offset + table_offset + feature id``
    slots (S,)   : which output slot (table) each descriptor column feeds
    means (K,)   : per-slot combiner flag

— exactly the SC Fetch unit's per-table descriptor list.  One Pallas grid
(``kernels.embedding_lookup.fused_lookup_kernel_call``) then covers every
table, amortising per-launch (CISC instruction issue) overhead across the
whole table batch; the backward is one fused Flush-unit scatter with an
exact ``custom_vjp`` (``kernels.ops.fused_lookup``).

Pipelined distributed dataflow
------------------------------
Two distributed modes share the row-sharded storage:
  * ``a2a``  — the paper-faithful path above (ids sharded over the model axis).
  * ``psum`` — ids replicated over the model axis; each shard partially
    combines its local rows and the partials are psum-merged.  Cheaper for
    small valency, used as an auto fallback and as a §Perf comparison point.

With ``ctx.emb_pipeline`` (default) all width-groups of a mode run inside a
single ``shard_map`` and are software-pipelined (``parallel.overlap.
software_pipeline``): group k+1's id all-to-all is issued before group k's
owner-gather + vector all-to-all + combine consumes its buffers, so the
exchanges ride under the previous group's compute instead of serialising.

Hot-id cache
------------
An optional per-group LFU cache (``embeddings.cache.HotIdCache``) keeps the
hottest rows replicated on every shard.  Cache hits are served locally and
never enter the all-to-all (the send-capacity can shrink by the cache's
``capacity_scale``); gradients remain exact because the cached lookup is
wrapped in a ``custom_vjp`` whose backward differentiates the *uncached*
dataflow, scattering every gradient back to the authoritative sharded rows.

Tables of the same width are concatenated into one row space ("groups");
table-sharding (paper §3.3) is row-sharding the concatenation with
shard-aligned offsets, so all strategies use one code path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig
from repro.embeddings.cache import HotIdCache
from repro.embeddings.dedup import dedup_ids
from repro.embeddings.sharding import Placement, plan_placement
from repro.parallel.context import LOCAL, ParallelContext, shard_map
from repro.parallel.overlap import software_pipeline

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# Group layout
# ---------------------------------------------------------------------------

@dataclass
class TableSlot:
    spec: EmbeddingTableConfig
    offset: int            # row offset inside the group array
    rows: int              # padded rows reserved


@dataclass
class Group:
    dim: int
    slots: List[TableSlot] = field(default_factory=list)
    total_rows: int = 0
    prefix: str = "group"   # "group" = row-sharded, "local" = replicated

    @property
    def name(self) -> str:
        return f"{self.prefix}_d{self.dim}"


@dataclass(frozen=True)
class FusedSlot:
    """One output slot of the fused descriptor stream (= one table)."""
    name: str
    combiner: str
    dim: int
    row_base: int          # absolute row offset in the fused row space
    cols: Tuple[int, int]  # descriptor-column span [a, b)


class EmbeddingCollection:
    """Plans placement and owns the parameter layout for a set of tables.

    With ``fused_storage`` (the pipeline-v2 layout, used by the DLRM stack)
    the locally-resident (replicated) tables are also packed into per-width
    ``local_d{D}`` row spaces — the descriptor-addressed layout the fused
    lookup consumes directly (one native-width gather per width-group, no
    per-table parameters and no per-step re-concatenation).  Sharded
    width-groups keep their own per-dim row spaces either way.
    """

    def __init__(self, tables: Sequence[EmbeddingTableConfig],
                 num_shards: int, *, fused_storage: bool = False):
        self.tables = list(tables)
        self.num_shards = max(1, num_shards)
        self.fused_storage = fused_storage
        self.plan = plan_placement(tables, self.num_shards)
        self.replicated: List[EmbeddingTableConfig] = []
        self.groups: Dict[int, Group] = {}
        # deterministic order: big tables first within each group
        for t in sorted(tables, key=lambda t: -t.vocab_size * t.dim):
            placement = self.plan[t.name]
            if placement.strategy == "replicate":
                self.replicated.append(t)
                continue
            g = self.groups.setdefault(t.dim, Group(dim=t.dim))
            off = g.total_rows
            if placement.strategy == "table":
                # shard-align so the table lands on as few shards as possible
                pass  # alignment applied after all rows known (below)
            rows = t.vocab_size
            g.slots.append(TableSlot(t, off, rows))
            g.total_rows += rows
        # pad every group to a multiple of num_shards
        for g in self.groups.values():
            pad = (-g.total_rows) % self.num_shards
            g.total_rows += pad
        # fused_storage: locally-resident tables pack into per-width
        # "local_d{D}" row spaces (native lane width, no padding waste)
        self.local_groups: Dict[int, Group] = {}
        if fused_storage:
            for t in self.replicated:
                g = self.local_groups.setdefault(
                    t.dim, Group(dim=t.dim, prefix="local"))
                g.slots.append(TableSlot(t, g.total_rows, t.vocab_size))
                g.total_rows += t.vocab_size

    # -- params -------------------------------------------------------------

    def init(self, key) -> Dict[str, Any]:
        """Initialise all embedding tables: one fused ``local_d{D}`` row
        space per width-group under fused storage, per-table arrays
        otherwise.  Returns the params dict consumed by `lookup`."""
        params: Dict[str, Any] = {}
        keys = jax.random.split(key, len(self.groups) + len(self.replicated))
        i = 0
        for dim, g in sorted(self.groups.items()):
            params[g.name] = (jax.random.normal(
                keys[i], (g.total_rows, dim), jnp.float32) * 0.01)
            i += 1
        rep: Dict[str, jax.Array] = {}
        for t in self.replicated:
            rep[t.name] = (jax.random.normal(
                keys[i], (t.vocab_size, t.dim), jnp.float32) * 0.01)
            i += 1
        if self.fused_storage:
            for dim, g in sorted(self.local_groups.items()):
                params[g.name] = jnp.concatenate(
                    [rep[s.spec.name] for s in g.slots], axis=0)
        else:
            params.update(rep)
        return params

    def param_specs(self, ctx: ParallelContext) -> Dict[str, Any]:
        """PartitionSpecs matching init()'s pytree."""
        specs: Dict[str, Any] = {}
        for dim, g in sorted(self.groups.items()):
            specs[g.name] = ctx.spec(ctx.model_axis, None)
        if self.fused_storage:
            for dim, g in sorted(self.local_groups.items()):
                specs[g.name] = ctx.spec(None, None)
        else:
            for t in self.replicated:
                specs[t.name] = ctx.spec(None, None)
        return specs

    def table_view(self, params, t: EmbeddingTableConfig) -> jax.Array:
        """Per-table (V, D) view of wherever the table's rows live."""
        if self.fused_storage and t.dim in self.local_groups:
            g = self.local_groups[t.dim]
            for s in g.slots:
                if s.spec.name == t.name:
                    return params[g.name][s.offset: s.offset + s.rows]
        return params[t.name]

    def _local_units(self, params) -> List[Tuple[Group, jax.Array]]:
        """(width-group, its full row-space array) for the local set."""
        if self.fused_storage:
            return [(g, params[g.name])
                    for dim, g in sorted(self.local_groups.items())]
        units = []
        for t in self.replicated:
            g = Group(dim=t.dim, prefix="local")
            g.slots.append(TableSlot(t, 0, t.vocab_size))
            g.total_rows = t.vocab_size
            units.append((g, params[t.name]))
        return units

    # -- fused descriptor layout --------------------------------------------

    def fused_entries(self, which: str = "all"
                      ) -> Tuple[List[Tuple[str, str, int, int]], int]:
        """(name, combiner, dim, row_base) per table + fused row count.

        Row bases follow ``fused_table``'s concatenation order: local width-
        groups (or bare replicated tables) sorted by dim, then the sharded
        width-groups.  ``which``: "all" (every table — the full fused row
        space) or "replicated" (only the locally-resident set).
        """
        entries: List[Tuple[str, str, int, int]] = []
        base = 0
        if self.fused_storage:
            for dim, g in sorted(self.local_groups.items()):
                for s in g.slots:
                    entries.append((s.spec.name, s.spec.combiner, dim,
                                    base + s.offset))
                base += g.total_rows
        else:
            for t in self.replicated:
                entries.append((t.name, t.combiner, t.dim, base))
                base += t.vocab_size
        if which == "all":
            for dim, g in sorted(self.groups.items()):
                for s in g.slots:
                    entries.append((s.spec.name, s.spec.combiner, dim,
                                    base + s.offset))
                base += g.total_rows
        return entries, base

    def fused_table(self, params, which: str = "all") -> jax.Array:
        """The selected storage as one (R, Dmax) row space — the single-
        grid view the Pallas descriptor kernel consumes."""
        dims = [t.dim for t in self.replicated]
        if which == "all":
            dims += list(self.groups)
        dmax = max(dims)
        parts = []
        if self.fused_storage:
            parts.extend(self._pad_lanes(params[g.name], dmax)
                         for dim, g in sorted(self.local_groups.items()))
        else:
            parts.extend(self._pad_lanes(params[t.name], dmax)
                         for t in self.replicated)
        if which == "all":
            for dim, g in sorted(self.groups.items()):
                parts.append(self._pad_lanes(params[g.name], dmax))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)

    @staticmethod
    def _pad_lanes(arr, dmax: int):
        if arr.shape[1] == dmax:
            return arr
        return jnp.pad(arr, ((0, 0), (0, dmax - arr.shape[1])))

    def _fused_plan(self, features, which: str = "all"
                    ) -> Tuple[List[FusedSlot], jax.Array, jax.Array]:
        """(slots, desc slot stream (S,), mean flags (K,)) for ``features``.

        Slots are ordered by valency (descriptor-span width) so that
        same-valency tables sit in contiguous descriptor runs — the combine
        then collapses each valency class into ONE reshaped masked-sum.
        """
        entries, _ = self.fused_entries(which)
        entries = sorted(entries,
                         key=lambda e: features[e[0]].shape[1])
        fslots: List[FusedSlot] = []
        c0 = 0
        for name, comb, dim, base in entries:
            vl = features[name].shape[1]
            fslots.append(FusedSlot(name, comb, dim, base, (c0, c0 + vl)))
            c0 += vl
        widths = [s.cols[1] - s.cols[0] for s in fslots]
        slots = jnp.asarray(np.repeat(np.arange(len(fslots)), widths),
                            jnp.int32)
        means = jnp.asarray([s.combiner == "mean" for s in fslots], jnp.int32)
        return fslots, slots, means

    def _lookup_fused(self, params, features, *, which: str = "all",
                      use_kernel: bool = False) -> Dict[str, jax.Array]:
        """One descriptor-stream launch over every selected table."""
        if use_kernel:
            # Pallas: the single-grid Fetch-unit model — one launch over
            # the whole padded fused row space
            fslots, slots, means = self._fused_plan(features, which)
            if not fslots:
                return {}
            table = self.fused_table(params, which)
            parts = [jnp.where(features[s.name] >= 0,
                               features[s.name] + s.row_base, -1)
                     for s in fslots]
            rows = (parts[0] if len(parts) == 1
                    else jnp.concatenate(parts, axis=1))
            from repro.kernels import ops as KOPS
            out3 = KOPS.fused_lookup(table, rows, slots, means)
            return {s.name: out3[:, i, :s.dim]
                    for i, s in enumerate(fslots)}
        # XLA: one program, one native-width gather per width-group, one
        # masked reshape-sum per valency class within it
        units = self._local_units(params)
        if which == "all":
            units += [(g, params[g.name])
                      for dim, g in sorted(self.groups.items())]
        out: Dict[str, jax.Array] = {}
        for g, arr in units:
            out.update(_group_fused_lookup(arr, g, features))
        return out

    # -- lookup ---------------------------------------------------------------

    def lookup(self, params, features: Dict[str, jax.Array],
               ctx: ParallelContext = LOCAL, *, method: str = "auto",
               use_kernel: bool = False, fused: Optional[bool] = None,
               cache: Optional[Any] = None) -> Dict[str, jax.Array]:
        """features: name -> (B, max_valency) int32 ids, -1 padded.

        Returns name -> (B, dim) combined embeddings.  ``fused=None`` follows
        ``ctx.emb_pipeline``; ``cache`` is a ``HotIdCache`` (or its
        ``arrays()`` dict) consulted by the distributed a2a path.
        """
        if method == "auto" and ctx.emb_method != "auto":
            method = ctx.emb_method
        if fused is None:
            fused = ctx.emb_pipeline
        cache_arrays = (cache.arrays() if isinstance(cache, HotIdCache)
                        else (cache or {}))
        cache_scale = (cache.capacity_scale
                       if isinstance(cache, HotIdCache) else 1.0)
        ms = ctx.model_axis_size
        local_only = ms <= 1 or not ctx.has_mesh or method == "local"

        out: Dict[str, jax.Array] = {}
        if local_only:
            if fused and (self.replicated or self.groups):
                return self._lookup_fused(params, features, which="all",
                                          use_kernel=use_kernel)
            out.update(self._lookup_replicated_legacy(params, features,
                                                      use_kernel))
            for dim, g in sorted(self.groups.items()):
                ids_all, cols = self._concat_group_ids(g, features)
                rows = _gather_rows(params[g.name], ids_all, use_kernel)
                for name, a, b, combiner in cols:
                    out[name] = _combine(rows[:, a:b], ids_all[:, a:b],
                                         combiner)
            return out

        # locally-resident tables: fused single launch (or legacy per-table)
        if fused and self.replicated:
            out.update(self._lookup_fused(params, features,
                                          which="replicated",
                                          use_kernel=use_kernel))
        else:
            out.update(self._lookup_replicated_legacy(params, features,
                                                      use_kernel))

        # sharded width-groups: resolve the exchange mode per group, then run
        # each mode's groups through one pipelined shard_map
        psum_set: List[Tuple[Group, jax.Array, List]] = []
        a2a_set: List[Tuple[Group, jax.Array, List]] = []
        for dim, g in sorted(self.groups.items()):
            ids_all, cols = self._concat_group_ids(g, features)
            if method == "psum" or (method == "auto"
                                    and ids_all.shape[1] <= 4):
                psum_set.append((g, ids_all, cols))
            else:
                a2a_set.append((g, ids_all, cols))

        if psum_set:
            if fused:
                combined = _rowsharded_psum_multi(
                    tuple(params[g.name] for g, _, _ in psum_set),
                    tuple(i for _, i, _ in psum_set), ctx,
                    cols_list=[c for _, _, c in psum_set])
            else:
                combined = [_rowsharded_psum(params[g.name], ids, ctx,
                                             cols=cols)
                            for g, ids, cols in psum_set]
            for (g, ids, cols), comb in zip(psum_set, combined):
                out.update({name: comb[:, i]
                            for i, (name, a, b, c) in enumerate(cols)})
        if a2a_set:
            caches = [cache_arrays.get(g.name) for g, _, _ in a2a_set]
            if fused:
                combined = _rowsharded_a2a_pipelined(
                    tuple(params[g.name] for g, _, _ in a2a_set),
                    tuple(i for _, i, _ in a2a_set), ctx,
                    cols_list=[c for _, _, c in a2a_set],
                    capacity_factor=ctx.emb_capacity_factor,
                    caches=caches, cache_scale=cache_scale)
            else:
                combined = [_rowsharded_a2a(params[g.name], ids, ctx,
                                            cols=cols,
                                            capacity_factor=
                                            ctx.emb_capacity_factor)
                            for g, ids, cols in a2a_set]
            for (g, ids, cols), comb in zip(a2a_set, combined):
                out.update({name: comb[:, i]
                            for i, (name, a, b, c) in enumerate(cols)})
        return out

    def _lookup_replicated_legacy(self, params, features,
                                  use_kernel: bool) -> Dict[str, jax.Array]:
        """Pre-v2 dataflow: one gather+combine per locally-resident table."""
        return {t.name: _combine(
            _gather_rows(self.table_view(params, t), features[t.name],
                         use_kernel),
            features[t.name], t.combiner) for t in self.replicated}

    @staticmethod
    def _concat_group_ids(g: Group, features):
        """Concat a group's feature ids with row offsets; remember spans."""
        cols: List[Tuple[str, int, int, str]] = []
        parts = []
        c0 = 0
        for s in g.slots:
            ids = features[s.spec.name]
            parts.append(jnp.where(ids >= 0, ids + s.offset, -1))
            cols.append((s.spec.name, c0, c0 + ids.shape[1], s.spec.combiner))
            c0 += ids.shape[1]
        return jnp.concatenate(parts, axis=1), cols


# ---------------------------------------------------------------------------
# Pipelined executor facade
# ---------------------------------------------------------------------------

class PipelinedEmbeddingExecutor:
    """EmbeddingCollection + hot-id cache + per-step LFU bookkeeping.

    The stateless ``coll.lookup`` stays jit-friendly; this facade owns the
    host-side loop around it: observe the step's ids into the LFU, refresh
    the replicated hot rows every ``refresh_every`` steps, and thread the
    cache arrays into the lookup as arguments (never closures, so refreshes
    do not recompile).
    """

    def __init__(self, coll: EmbeddingCollection, *,
                 cache: Optional[HotIdCache] = None,
                 refresh_every: int = 1, method: str = "auto",
                 use_kernel: bool = False):
        self.coll = coll
        self.cache = cache
        self.refresh_every = max(1, refresh_every)
        self.method = method
        self.use_kernel = use_kernel
        self._step = 0

    def observe(self, features) -> None:
        """Fold one step's feature ids into the LFU counts (host-side).

        Only groups the engine will route through the a2a exchange are
        tracked — psum-routed (small-valency) groups never consult the
        cache, so counting them would skew hit_rate and waste snapshots.
        """
        if self.cache is None:
            return
        for dim, g in sorted(self.coll.groups.items()):
            vl = sum(features[s.spec.name].shape[1] for s in g.slots)
            if self.method in ("psum", "local") or (self.method == "auto"
                                                    and vl <= 4):
                continue
            for s in g.slots:
                ids = np.asarray(features[s.spec.name])
                ids = np.where(ids >= 0, ids + s.offset, -1)
                self.cache.observe(g.name, ids)

    def step(self, params, features) -> None:
        """Per-step bookkeeping: observe + periodic refresh."""
        self.observe(features)
        self._step += 1
        if self.cache is not None and self._step % self.refresh_every == 0:
            self.cache.refresh_all(self.coll, params)

    def lookup(self, params, features, ctx: ParallelContext = LOCAL
               ) -> Dict[str, jax.Array]:
        """Pipelined fused multi-group lookup: name -> (B, dim) combined
        embeddings (see `EmbeddingCollection.lookup`; this executor pins
        ``fused=True`` and threads its hot-id cache through)."""
        return self.coll.lookup(params, features, ctx, method=self.method,
                                use_kernel=self.use_kernel, fused=True,
                                cache=self.cache)


# ---------------------------------------------------------------------------
# Local gather + combine
# ---------------------------------------------------------------------------

def _group_fused_lookup(arr, g: Group, features) -> Dict[str, jax.Array]:
    """Descriptor-stream lookup over ONE width-group's (R, D) row space.

    Slots are ordered by valency so same-valency tables occupy contiguous
    equal-width descriptor runs; each run-class combines as a single
    (B, nw, W, D) masked reduction — the XLA shape of the fused grid.
    """
    slots = sorted(g.slots, key=lambda s: features[s.spec.name].shape[1])
    parts = [jnp.where(features[s.spec.name] >= 0,
                       features[s.spec.name] + s.offset, -1) for s in slots]
    rows = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    B = rows.shape[0]
    D = arr.shape[1]
    valid = rows >= 0
    # mode="clip" routes the -1 invalids to row 0; the mask zeroes them
    vecs = jnp.take(arr, rows, axis=0, mode="clip")           # (B, S, D)
    out: Dict[str, jax.Array] = {}
    i = c0 = 0
    while i < len(slots):
        w = features[slots[i].spec.name].shape[1]
        j = i
        while j < len(slots) and \
                features[slots[j].spec.name].shape[1] == w:
            j += 1
        cls = slots[i:j]
        nw = len(cls)
        a, b = c0, c0 + nw * w
        block = vecs[:, a:b].reshape(B, nw, w, D)
        vmask = valid[:, a:b].reshape(B, nw, w).astype(vecs.dtype)
        seg = (block * vmask[..., None]).sum(axis=2)          # (B, nw, D)
        cnt = vmask.sum(axis=2)
        is_mean = jnp.asarray([s.spec.combiner == "mean" for s in cls])
        denom = jnp.where(is_mean[None, :], jnp.maximum(cnt, 1.0), 1.0)
        seg = seg / denom[..., None]
        for k, s in enumerate(cls):
            out[s.spec.name] = seg[:, k]
        i, c0 = j, b
    return out


def _gather_rows(table, ids, use_kernel: bool = False):
    """(V, D), (B, Vl) -> (B, Vl, D); invalid ids give zero rows."""
    if use_kernel:
        from repro.kernels import ops as KOPS
        return KOPS.embedding_gather(table, ids)
    valid = (ids >= 0)[..., None]
    rows = jnp.take(table, ids, axis=0, mode="clip")
    return jnp.where(valid, rows, 0.0)


def _combine(rows, ids, combiner: str):
    """(B, Vl, D), (B, Vl) -> (B, D)."""
    valid = (ids >= 0).astype(rows.dtype)
    out = (rows * valid[..., None]).sum(axis=1)
    if combiner == "mean":
        out = out / jnp.maximum(valid.sum(axis=1), 1.0)[..., None]
    return out


# ---------------------------------------------------------------------------
# Distributed row-sharded lookups
# ---------------------------------------------------------------------------

def _segment_combine(rows, ids, cols):
    """(B, Vg, D) rows -> (B, K, D) per-table combined vectors (local op)."""
    B, Vg, D = rows.shape
    K = len(cols)
    sel = np.zeros((Vg, K), np.float32)
    for i, (name, a, b, comb) in enumerate(cols):
        sel[a:b, i] = 1.0
    sel = jnp.asarray(sel)
    valid = (ids >= 0).astype(rows.dtype)
    out = jnp.einsum("bvd,vk->bkd", rows * valid[..., None], sel)
    counts = jnp.einsum("bv,vk->bk", valid, sel)
    means = jnp.asarray([c == "mean" for *_, c in cols])
    denom = jnp.where(means[None, :], jnp.maximum(counts, 1.0), 1.0)
    return out / denom[..., None]


def _rowsharded_psum(table, ids, ctx: ParallelContext, *, cols):
    """ids replicated over the model axis; shards partially gather, combine
    locally to (B, K, D), and psum the combined vectors."""
    axis = ctx.model_axis
    ms = ctx.model_axis_size
    bspec = (ctx.batch_axes or None) if ctx.has_mesh else None
    V = table.shape[0]
    rps = V // ms

    def local(table_loc, ids_loc):
        combined = _psum_partial(table_loc, ids_loc, axis, rps, cols, ctx)
        return jax.lax.psum(combined, axis)

    fn = shard_map(
        local, mesh=ctx.mesh,
        in_specs=(P(axis, None), P(bspec, None)),
        out_specs=P(bspec, None, None), check_vma=False)
    return fn(table, ids)


def _psum_partial(table_loc, ids_loc, axis, rps, cols, ctx):
    """The shard-local compute half of the psum mode."""
    base = jax.lax.axis_index(axis) * rps
    lid = ids_loc - base
    ok = (ids_loc >= 0) & (lid >= 0) & (lid < rps)
    rows = jnp.take(table_loc, lid, axis=0, mode="clip")
    rows = jnp.where(ok[..., None], rows, 0.0)
    combined = _segment_combine(rows, ids_loc, cols)
    if ctx.emb_wire_bf16:
        combined = combined.astype(jnp.bfloat16)  # §Perf: half traffic
    return combined


def _rowsharded_psum_multi(tables, ids_list, ctx: ParallelContext, *,
                           cols_list):
    """All psum-mode width-groups in ONE shard_map, software-pipelined:
    group k+1's local gather+combine is issued before group k's psum, so
    the reduction rides under the next group's compute."""
    axis = ctx.model_axis
    ms = ctx.model_axis_size
    bspec = (ctx.batch_axes or None) if ctx.has_mesh else None
    n = len(tables)
    rps = [t.shape[0] // ms for t in tables]

    def local(tabs, idss):
        def stage_a(k):          # compute: shard-local partial combine
            return _psum_partial(tabs[k], idss[k], axis, rps[k],
                                 cols_list[k], ctx)

        def stage_b(partial, k):  # communicate: merge partials
            return jax.lax.psum(partial, axis)

        return tuple(software_pipeline(stage_a, stage_b, range(n)))

    fn = shard_map(
        local, mesh=ctx.mesh,
        in_specs=(tuple(P(axis, None) for _ in range(n)),
                  tuple(P(bspec, None) for _ in range(n))),
        out_specs=tuple(P(bspec, None, None) for _ in range(n)),
        check_vma=False)
    return list(fn(tuple(tables), tuple(ids_list)))


def _a2a_descriptors(ids_loc, ms: int, rps: int, C: int, cache):
    """Dedup one group's shard-local ids and lay out the send descriptors.

    Returns (send_ids (ms, C), slot (N,), keep (N,), inv (N,), hit (N,),
    cpos (N,)): the id all-to-all payload plus everything the consume stage
    needs to reassemble per-occurrence vectors.  Cache hits are routed to
    the drop bucket — they never enter the exchange.
    """
    N = ids_loc.size
    flat = ids_loc.reshape(N)
    uids, inv, num = dedup_ids(flat)                 # sorted, -1 tail
    valid_u = uids >= 0
    if cache is not None:
        cids, _ = cache
        cpos = jnp.clip(jnp.searchsorted(cids, uids), 0, cids.shape[0] - 1)
        hit = valid_u & (cids[cpos] == uids)
    else:
        cpos = jnp.zeros((N,), jnp.int32)
        hit = jnp.zeros((N,), bool)
    want = valid_u & jnp.logical_not(hit)
    # uids sorted => dest monotonic over the wanted subsequence; rank within
    # each destination = wanted-before-me minus wanted-before-my-bucket
    full_dest = jnp.where(valid_u, uids // rps, ms)
    dest = jnp.where(want, full_dest, ms)            # ms = drop bucket
    wanted = want.astype(jnp.int32)
    cum = jnp.cumsum(wanted) - wanted                # exclusive prefix count
    cum_ext = jnp.concatenate([cum, jnp.sum(wanted)[None]])
    starts = jnp.searchsorted(full_dest, jnp.arange(ms), side="left")
    before = cum_ext[starts]                         # wanted with dest < d
    rank = cum - before[jnp.clip(dest, 0, ms - 1)]
    keep = want & (rank < C)
    slot = jnp.where(keep, dest * C + rank, ms * C)
    send_ids = jnp.full((ms * C + 1,), -1, jnp.int32).at[slot].set(
        uids, mode="drop")[:-1]
    return send_ids.reshape(ms, C), slot, keep, inv, hit, cpos


def _a2a_consume(table_loc, desc, ids_loc, cols, ctx, axis, rps: int, cache):
    """Owner-side gather + vector all-to-all + reassembly + combine."""
    recv_ids, slot, keep, inv, hit, cpos = desc
    Bl, Vl = ids_loc.shape
    ms, C = recv_ids.shape
    D = table_loc.shape[1]
    base = jax.lax.axis_index(axis) * rps
    lid = recv_ids - base
    ok = (recv_ids >= 0) & (lid >= 0) & (lid < rps)
    rows = jnp.take(table_loc, lid, axis=0, mode="clip")
    rows = jnp.where(ok[..., None], rows, 0.0)       # (ms, C, D)
    if ctx.emb_wire_bf16:
        rows = rows.astype(jnp.bfloat16)   # §Perf: halve vector traffic
    vecs = jax.lax.all_to_all(rows, axis, 0, 0)      # (ms, C, D) back
    vflat = jnp.concatenate(
        [vecs.reshape(ms * C, D), jnp.zeros((1, D), vecs.dtype)], 0)
    uvecs = vflat[slot] * keep[:, None].astype(vflat.dtype)
    if cache is not None:
        _, crows = cache
        hot = crows[cpos].astype(uvecs.dtype)        # replicated hot rows
        uvecs = jnp.where(hit[:, None], hot, uvecs)
    occ = uvecs[inv]                                 # broadcast to ids
    return _segment_combine(occ.reshape(Bl, Vl, D), ids_loc, cols)


def _a2a_capacity(ids, ms: int, capacity_factor: float,
                  scale: float = 1.0) -> int:
    N = ids.shape[0] * ids.shape[1]
    return max(8, int(math.ceil(N / ms * capacity_factor * scale)))


def _rowsharded_a2a(table, ids, ctx: ParallelContext, *, cols,
                    capacity_factor: float = 2.0):
    """The paper-faithful SparseCore path for ONE width-group: dedup → id
    all-to-all → owner gather → vector all-to-all → per-occurrence broadcast
    → LOCAL combine.

    ids: (B, Vl) with B sharded over (batch_axes, model) — the sparse stage
    splits the batch over the model axis too, exactly like SC's per-chip
    sample ownership.  Output (B, K, D) combined vectors (only those cross
    shard boundaries on the way back to the dense stack).
    """
    return _rowsharded_a2a_pipelined(
        (table,), (ids,), ctx, cols_list=[cols],
        capacity_factor=capacity_factor, caches=[None])[0]


def _rowsharded_a2a_pipelined(tables, ids_list, ctx: ParallelContext, *,
                              cols_list, capacity_factor: float = 2.0,
                              caches=None, cache_scale: float = 1.0):
    """All a2a-mode width-groups in ONE shard_map, double-buffered: group
    k+1's descriptor build + id all-to-all overlaps group k's gather +
    vector all-to-all + combine (``software_pipeline``)."""
    axis = ctx.model_axis
    ms = ctx.model_axis_size
    bspec = (ctx.batch_axes or None) if ctx.has_mesh else None
    batch_both = tuple([*(ctx.batch_axes or ()), axis])
    n = len(tables)
    caches = list(caches) if caches is not None else [None] * n
    rps = [t.shape[0] // ms for t in tables]
    cache_args = tuple(c for c in caches if c is not None)
    cache_slots = [i for i, c in enumerate(caches) if c is not None]

    def make_run(with_cache: bool):
        # the cached forward provisions miss-only exchange buffers
        # (capacity * cache_scale); the uncached dataflow — also the exact
        # backward — keeps full capacity so no gradient is ever dropped
        caps = [_a2a_capacity(
            ids, ms, capacity_factor,
            cache_scale if (with_cache and caches[k] is not None) else 1.0)
            for k, ids in enumerate(ids_list)]

        def local(tabs, idss, cargs):
            cmap = ({k: cargs[j] for j, k in enumerate(cache_slots)}
                    if with_cache else {})

            def stage_a(k):          # descriptor build + id exchange
                send, slot, keep, inv, hit, cpos = _a2a_descriptors(
                    idss[k], ms, rps[k], caps[k], cmap.get(k))
                recv = jax.lax.all_to_all(send, axis, 0, 0)
                return recv, slot, keep, inv, hit, cpos

            def stage_b(desc, k):    # gather + vector exchange + combine
                return _a2a_consume(tabs[k], desc, idss[k], cols_list[k],
                                    ctx, axis, rps[k], cmap.get(k))

            return tuple(software_pipeline(stage_a, stage_b, range(n)))

        cache_specs = (tuple((P(None), P(None, None)) for _ in cache_args)
                       if with_cache else ())
        fn = shard_map(
            local, mesh=ctx.mesh,
            in_specs=(tuple(P(axis, None) for _ in range(n)),
                      tuple(P(batch_both, None) for _ in range(n)),
                      cache_specs),
            out_specs=tuple(P(batch_both, None, None) for _ in range(n)),
            check_vma=False)

        def run(tabs, idss, cargs):
            # reshard batch over (data, model) for the sparse stage, back
            idss = tuple(
                jax.lax.with_sharding_constraint(
                    i, jax.sharding.NamedSharding(ctx.mesh,
                                                  P(batch_both, None)))
                for i in idss)
            outs = fn(tabs, idss, cargs)
            return tuple(
                jax.lax.with_sharding_constraint(
                    o, jax.sharding.NamedSharding(ctx.mesh,
                                                  P(bspec, None, None)))
                for o in outs)
        return run

    run_plain = make_run(False)
    if not cache_args:
        return list(run_plain(tuple(tables), tuple(ids_list), ()))
    return list(_cached_vjp(make_run(True), run_plain,
                            tuple(tables), tuple(ids_list), cache_args))


def _cached_vjp(run_cached, run_plain, tables, ids_list, cache_args):
    """Exact-gradient wrapper for the cached forward.

    The forward serves hits from the (possibly slightly stale) replicated
    cache; the backward differentiates the *uncached* dataflow at the same
    primals, so every gradient is scattered back through the real id/vector
    all-to-all to the authoritative sharded rows.  No gradient ever flows
    into the cache snapshot.
    """
    @jax.custom_vjp
    def cached(tabs, idss, cargs):
        return run_cached(tabs, idss, cargs)

    def fwd(tabs, idss, cargs):
        return run_cached(tabs, idss, cargs), (tabs, idss)

    def bwd(res, g):
        tabs, idss = res
        _, vjp = jax.vjp(lambda tt: run_plain(tt, idss, ()), tabs)
        (dt,) = vjp(g)
        return dt, None, None

    cached.defvjp(fwd, bwd)
    return cached(tables, ids_list, cache_args)


# ---------------------------------------------------------------------------
# Reference (oracle for tests)
# ---------------------------------------------------------------------------

def materialize_tables(coll: EmbeddingCollection, params
                       ) -> Dict[str, jax.Array]:
    """Slice the grouped storage back into per-table (V, D) arrays."""
    out = {}
    for t in coll.replicated:
        out[t.name] = coll.table_view(params, t)
    for dim, g in sorted(coll.groups.items()):
        arr = params[g.name]
        for s in g.slots:
            out[s.spec.name] = arr[s.offset: s.offset + s.spec.vocab_size]
    return out


def lookup_reference(tables: Dict[str, jax.Array],
                     specs: Sequence[EmbeddingTableConfig],
                     features: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    out = {}
    for t in specs:
        rows = _gather_rows(tables[t.name], features[t.name])
        out[t.name] = _combine(rows, features[t.name], t.combiner)
    return out
