"""Hot-id embedding cache — an LFU layer over deduplicated ids (§3.4-§3.5).

Production DLRM id streams are Zipf-skewed: a small set of rows absorbs most
lookups.  The SparseCore dataflow still pays the id/vector all-to-all for
every deduplicated id each step; this cache keeps the hottest rows replicated
on every shard so their lookups short-circuit the exchange entirely — only
cache *misses* ride the all-to-all (and, with ``capacity_scale`` < 1, the
statically provisioned exchange buffers shrink to match).

Design (host-side state, functional on-device use):
  * ``observe``   — decayed per-group frequency counts over the ids of a step
    (LFU with aging, so yesterday's hot rows decay out);
  * ``refresh``   — snapshot the top-``capacity`` rows per group out of the
    (possibly sharded) parameter arrays into replicated ``(ids, rows)``
    buffers.  Ids are sorted ascending and padded with an int32 sentinel so
    shard-local hit tests are a single ``searchsorted``;
  * ``entries``   — the per-group ``(ids (C,), rows (C, D))`` device arrays
    the engine threads into its lookup (as *arguments*, never closures, so a
    refresh does not recompile the train step).

Gradient contract: the forward may serve slightly stale cached rows, but the
backward is exact — the engine wraps the cached lookup in a ``custom_vjp``
whose backward differentiates the *uncached* dataflow, so every gradient is
scattered back to the authoritative sharded rows (see engine._cached_vjp).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

# sorts after every real row id; searchsorted never matches it
SENTINEL = np.int32(2 ** 31 - 1)


class HotIdCache:
    """Per-group LFU over deduplicated row ids."""

    def __init__(self, capacity: int = 64, *, decay: float = 0.9,
                 capacity_scale: float = 1.0):
        assert capacity >= 1
        self.capacity = capacity
        self.decay = decay
        # Scales the all-to-all send capacity the engine provisions when this
        # cache is active (< 1.0 models the miss-only exchange buffers).
        # CONTRACT: the caller owns provisioning — if the hit rate sags (id
        # distribution shifts between refreshes) and per-shard misses exceed
        # the shrunken capacity, the surplus lands in the drop bucket and
        # reads back as zero vectors, exactly like the uncached path's
        # over-capacity drops but on a tighter budget.  Keep 1.0 (the
        # default) unless the workload's miss rate is known; the backward
        # always uses full capacity, so gradients never drop.
        self.capacity_scale = capacity_scale
        self._counts: Dict[str, Dict[int, float]] = {}
        self._entries: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}
        self.hits = 0.0
        self.lookups = 0.0

    # -- statistics ----------------------------------------------------------

    def observe(self, group: str, ids) -> None:
        """Fold one step's (already offset-adjusted) id batch into the LFU
        counts.  ``ids``: any int array; negatives are padding."""
        flat = np.asarray(ids).reshape(-1)
        flat = flat[flat >= 0]
        if flat.size == 0:
            return
        counts = self._counts.setdefault(group, {})
        for k in list(counts):
            counts[k] *= self.decay
        uniq, freq = np.unique(flat, return_counts=True)
        for u, f in zip(uniq.tolist(), freq.tolist()):
            counts[u] = counts.get(u, 0.0) + float(f)
        if len(counts) > 8 * self.capacity:      # bound host memory
            keep = sorted(counts, key=counts.get, reverse=True)
            for k in keep[8 * self.capacity:]:
                del counts[k]
        # running hit-rate estimate against the current entry set
        ids_arr, _ = self._entries.get(group, (None, None))
        if ids_arr is not None:
            cached = np.asarray(ids_arr)
            self.hits += float(np.isin(flat, cached[cached != SENTINEL]).sum())
        self.lookups += float(flat.size)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1.0)

    # -- snapshot ------------------------------------------------------------

    def refresh(self, group: str, table) -> None:
        """Snapshot the top-``capacity`` rows of ``table`` (the group's
        (R, D) parameter array) for the hottest ids seen so far."""
        counts = self._counts.get(group, {})
        hot = sorted(counts, key=counts.get, reverse=True)[: self.capacity]
        ids = np.full((self.capacity,), SENTINEL, np.int32)
        ids[: len(hot)] = np.asarray(sorted(hot), np.int32)
        rows = jnp.take(table, jnp.minimum(jnp.asarray(ids),
                                           table.shape[0] - 1), axis=0)
        rows = jnp.where((jnp.asarray(ids) != SENTINEL)[:, None], rows, 0.0)
        self._entries[group] = (jnp.asarray(ids), rows)

    def refresh_all(self, coll, params) -> None:
        """Refresh every *observed* width-group of an ``EmbeddingCollection``
        (groups the executor never routes through the a2a exchange have no
        counts and get no snapshot)."""
        for dim, g in sorted(coll.groups.items()):
            if g.name in self._counts:
                self.refresh(g.name, params[g.name])

    # -- device view ---------------------------------------------------------

    def entries(self, group: str
                ) -> Optional[Tuple[jnp.ndarray, jnp.ndarray]]:
        return self._entries.get(group)

    def arrays(self) -> Dict[str, Tuple[jnp.ndarray, jnp.ndarray]]:
        """group name -> (ids (C,) sorted i32 w/ sentinel pad, rows (C, D))."""
        return dict(self._entries)
