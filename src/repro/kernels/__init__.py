# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from typing import Optional

import jax


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Shared kernel-launch policy: ``None`` auto-detects by backend —
    compile natively on TPU, fall back to interpret mode elsewhere."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
