"""Jit'd wrappers around the Pallas kernels with automatic interpret fallback.

On a TPU backend the kernels compile natively; on CPU (this container) they
run under ``interpret=True`` for correctness validation.  ``use_pallas=False``
call sites fall back to the jnp reference — that is what the multi-device
dry-run lowers, since Pallas TPU kernels cannot lower for host devices.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.embedding_grad import scatter_kernel_call
from repro.kernels.embedding_lookup import gather_kernel_call, lookup_kernel_call
from repro.kernels.flash_attention import flash_attention as _flash


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=())
def embedding_gather(table, ids):
    return gather_kernel_call(table, ids, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("combiner",))
def embedding_lookup(table, ids, combiner: str = "sum"):
    return lookup_kernel_call(table, ids, combiner=combiner,
                              interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("vocab",))
def embedding_scatter(grads, ids, vocab: int):
    return scatter_kernel_call(grads, ids, vocab, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    bq: int = 128, bk: int = 256):
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, bq=bq, bk=bk, interpret=_interpret())
