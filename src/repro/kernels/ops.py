"""Jit'd wrappers around the Pallas kernels with automatic interpret fallback.

On a TPU backend the kernels compile natively; on CPU (this container) they
run under ``interpret=True`` for correctness validation.  ``use_pallas=False``
call sites fall back to the jnp reference — that is what the multi-device
dry-run lowers, since Pallas TPU kernels cannot lower for host devices.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels import resolve_interpret
from repro.kernels.decode_attention import (
    paged_decode_attention_bt_kernel_call, paged_decode_attention_kernel_call)
from repro.kernels.embedding_grad import (fused_scatter_kernel_call,
                                          scatter_kernel_call)
from repro.kernels.embedding_lookup import (fused_lookup_kernel_call,
                                            gather_kernel_call,
                                            lookup_kernel_call)
from repro.kernels.flash_attention import flash_attention as _flash


def _interpret() -> bool:
    return resolve_interpret(None)


@functools.partial(jax.jit, static_argnames=())
def embedding_gather(table, ids):
    return gather_kernel_call(table, ids, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("combiner",))
def embedding_lookup(table, ids, combiner: str = "sum"):
    return lookup_kernel_call(table, ids, combiner=combiner,
                              interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("vocab",))
def embedding_scatter(grads, ids, vocab: int):
    return scatter_kernel_call(grads, ids, vocab, interpret=_interpret())


# ---------------------------------------------------------------------------
# Fused multi-group lookup: forward = fused Fetch/combine kernel, backward =
# fused Flush scatter kernel (exact, including mean-combiner rescaling)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fused_lookup(table, rows, slots, means):
    """Differentiable one-launch multi-table lookup.

    table (R, Dm) fused row space; rows (B, S) absolute fused row ids;
    slots (S,) slot per descriptor column; means (K,) mean flags
    -> (B, K, Dm).  Gradients flow to ``table`` only.
    """
    return fused_lookup_kernel_call(table, rows, slots, means,
                                    interpret=_interpret())


def _fused_lookup_fwd(table, rows, slots, means):
    out = fused_lookup(table, rows, slots, means)
    return out, (table, rows, slots, means)


def _fused_lookup_bwd(res, g):
    table, rows, slots, means = res
    vocab, dtype = table.shape[0], table.dtype
    K = means.shape[0]
    valid = (rows >= 0).astype(jnp.float32)                 # (B, S)
    onehot = jax.nn.one_hot(slots, K, dtype=jnp.float32)    # (S, K)
    cnt = valid @ onehot                                    # (B, K)
    scale = jnp.where(means[None, :] > 0,
                      1.0 / jnp.maximum(cnt, 1.0), 1.0)
    g_scaled = (g.astype(jnp.float32) * scale[..., None]).astype(dtype)
    dtable = fused_scatter_kernel_call(g_scaled, rows, slots, vocab,
                                       interpret=_interpret())
    return dtable.astype(dtype), None, None, None


fused_lookup.defvjp(_fused_lookup_fwd, _fused_lookup_bwd)


@functools.partial(jax.jit, static_argnames=())
def fused_lookup_q(table, scales, rows, slots, means):
    """Serving-side fused lookup over an int8 table (forward only).

    table (R, Dm) int8 + scales (R, nt) f32 (``models/quant.QTensor``
    per-row tile scales, ``nt`` tiles of ``Dm // nt`` lanes) -> (B, K, Dm)
    f32.  The row stream out of HBM is 1 byte/lane; dequantisation happens
    in VMEM inside the combine.  Inference path — no custom VJP."""
    return fused_lookup_kernel_call(table, rows, slots, means,
                                    scales=scales, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    bq: int = 128, bk: int = 256):
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, bq=bq, bk=bk, interpret=None)


def paged_decode_attention(q, k, v, seq_lens, *,
                           k_scale=None, v_scale=None,
                           window=None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           bk: int = 128,
                           impl: str = "auto"):
    """Serving decode attention dispatcher.

    q (B, H, d); k, v (B, S, KH, d); seq_lens (B,) valid rows per slot
    -> (B, H, d).  ``impl``: "pallas" launches the paged kernel (native on
    TPU, interpret elsewhere), "xla" the dense reference, "auto" picks the
    kernel only on TPU — interpret-mode Pallas is far too slow for a decode
    hot loop, and the dense XLA form is what host backends lower well.
    The Pallas path needs a STATIC window (block skipping); a traced window
    (scanned per-layer schedule) falls back to XLA.

    int8 KV cache: pass k, v as int8 with per-row f32 ``k_scale``/``v_scale``
    (B, S, KH) (``models/quant.quantize_kv`` layout).  The Pallas path
    dequantises per block inside the kernel; the XLA fallback widens first.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas" and (window is None or isinstance(window, int)):
        return paged_decode_attention_kernel_call(
            q, k, v, seq_lens, k_scale=k_scale, v_scale=v_scale,
            window=window, softcap=softcap, scale=scale,
            bk=bk, interpret=None)
    if k_scale is not None:
        from repro.models import quant as QUANT
        k = QUANT.dequantize_kv(k, k_scale, dtype=q.dtype)
        v = QUANT.dequantize_kv(v, v_scale, dtype=q.dtype)
    return REF.paged_decode_attention_ref(
        q, k, v, seq_lens, window=window, softcap=softcap, scale=scale)


def paged_decode_attention_bt(q, k, v, seq_lens, tables, *,
                              k_scale=None, v_scale=None,
                              window=None,
                              softcap: Optional[float] = None,
                              scale: Optional[float] = None,
                              impl: str = "auto"):
    """Block-table-indexed decode attention dispatcher (pooled KV).

    q (B, H, d); k, v (NB, bs, KH, d) physical block pool; tables (B, nb)
    logical->physical block map -> (B, H, d).  Same backend policy as
    ``paged_decode_attention``: the Pallas kernel (table in scalar-prefetch
    SMEM) natively on TPU with a static window, the gather-based dense
    reference elsewhere.  int8 pools take (NB, bs, KH) f32 scale pools via
    ``k_scale``/``v_scale`` (same convention as `paged_decode_attention`)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas" and (window is None or isinstance(window, int)):
        return paged_decode_attention_bt_kernel_call(
            q, k, v, seq_lens, tables, k_scale=k_scale, v_scale=v_scale,
            window=window, softcap=softcap, scale=scale, interpret=None)
    if k_scale is not None:
        from repro.models import quant as QUANT
        k = QUANT.dequantize_kv(k, k_scale, dtype=q.dtype)
        v = QUANT.dequantize_kv(v, v_scale, dtype=q.dtype)
    return REF.paged_decode_attention_bt_ref(
        q, k, v, seq_lens, tables, window=window, softcap=softcap,
        scale=scale)
