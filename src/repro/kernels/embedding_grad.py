"""Pallas TPU kernels: embedding gradient scatter (the SC Flush unit, §3.5).

"The Flush Unit writes updated parameters to HBM during the backward pass."

``scatter_kernel_call``: ids are UNIQUE (the engine always deduplicates
before the backward all-to-all, paper §3.4) and sorted ascending with -1
padding at the tail.  Each grid step DMAs one gradient row VMEM→HBM into the
(aliased) table-shaped gradient buffer; untouched rows keep their zero
initialisation via input/output aliasing.

``fused_scatter_kernel_call``: the backward of the fused multi-group lookup —
the same (rows, slots) descriptor stream drives one grid over every table,
read-modify-writing each descriptor's upstream slot gradient into its fused
row.  Descriptor rows may repeat (interpret mode runs the grid sequentially,
so read-after-write accumulation is exact; on real hardware duplicate rows
would be serialised per HBM channel by the Flush unit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(ids_ref, grads_ref, zeros_ref, out_ref):
    i = pl.program_id(0)
    valid = ids_ref[i] >= 0

    @pl.when(valid)
    def _():
        out_ref[...] = zeros_ref[...] + grads_ref[...]


def scatter_kernel_call(grads: jax.Array, ids: jax.Array, vocab: int, *,
                        interpret: bool = True) -> jax.Array:
    """grads (N, D), unique sorted ids (N,) i32 (-1 tail) -> (V, D) grad table."""
    N, D = grads.shape
    dtable0 = jnp.zeros((vocab, D), grads.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, D), lambda i, ids: (i, 0)),                 # grads
            pl.BlockSpec((1, D), lambda i, ids: (jnp.maximum(ids[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda i, ids: (jnp.maximum(ids[i], 0), 0)),
    )
    fn = pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((vocab, D), grads.dtype),
        input_output_aliases={2: 0},   # alias the zero table (arg idx incl. ids)
        interpret=interpret,
    )
    return fn(ids, grads, dtable0)


# ---------------------------------------------------------------------------
# Fused multi-group gradient scatter
# ---------------------------------------------------------------------------

def _fused_scatter_kernel(rows_ref, slots_ref, gout_ref, zeros_ref, out_ref):
    b = pl.program_id(0)
    s = pl.program_id(1)
    del zeros_ref  # present only to seed the aliased output with zeros
    valid = rows_ref[b, s] >= 0

    @pl.when(valid)
    def _():
        out_ref[0, :] += gout_ref[0, 0, :].astype(out_ref.dtype)


def fused_scatter_kernel_call(gout: jax.Array, rows: jax.Array,
                              slots: jax.Array, vocab: int, *,
                              interpret: bool = True) -> jax.Array:
    """gout (B, K, Dm) slot grads (pre-scaled for mean combiners); rows (B, S)
    absolute fused row ids (-1 invalid); slots (S,) i32 slot per descriptor
    column -> (R, Dm) accumulated gradient over the fused row space."""
    B, K, Dm = gout.shape
    S = rows.shape[1]
    dtable0 = jnp.zeros((vocab, Dm), gout.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, S),
        in_specs=[
            pl.BlockSpec((1, 1, Dm),
                         lambda b, s, rows, slots: (b, slots[s], 0)),
            pl.BlockSpec((1, Dm),
                         lambda b, s, rows, slots:
                         (jnp.maximum(rows[b, s], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, Dm),
                               lambda b, s, rows, slots:
                               (jnp.maximum(rows[b, s], 0), 0)),
    )
    fn = pl.pallas_call(
        _fused_scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((vocab, Dm), gout.dtype),
        input_output_aliases={3: 0},   # alias the zero table (arg idx incl.
        interpret=interpret,           # the two prefetched descriptor args)
    )
    return fn(rows, slots, gout, dtable0)
