"""Pallas TPU kernel: embedding gradient scatter (the SC Flush unit, §3.5).

"The Flush Unit writes updated parameters to HBM during the backward pass."

Contract: ids are UNIQUE (the engine always deduplicates before the backward
all-to-all, paper §3.4) and sorted ascending with -1 padding at the tail.
Each grid step DMAs one gradient row VMEM→HBM into the (aliased) table-shaped
gradient buffer; untouched rows keep their zero initialisation via
input/output aliasing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(ids_ref, grads_ref, zeros_ref, out_ref):
    i = pl.program_id(0)
    valid = ids_ref[i] >= 0

    @pl.when(valid)
    def _():
        out_ref[...] = zeros_ref[...] + grads_ref[...]


def scatter_kernel_call(grads: jax.Array, ids: jax.Array, vocab: int, *,
                        interpret: bool = True) -> jax.Array:
    """grads (N, D), unique sorted ids (N,) i32 (-1 tail) -> (V, D) grad table."""
    N, D = grads.shape
    dtable0 = jnp.zeros((vocab, D), grads.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, D), lambda i, ids: (i, 0)),                 # grads
            pl.BlockSpec((1, D), lambda i, ids: (jnp.maximum(ids[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda i, ids: (jnp.maximum(ids[i], 0), 0)),
    )
    fn = pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((vocab, D), grads.dtype),
        input_output_aliases={2: 0},   # alias the zero table (arg idx incl. ids)
        interpret=interpret,
    )
    return fn(ids, grads, dtable0)
