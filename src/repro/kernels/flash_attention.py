"""Pallas TPU kernel: blocked flash attention (forward).

VMEM-tiled online-softmax attention for the 32k-prefill hot spot.  Supports
GQA, causal masking, sliding windows, and gemma2-style logit soft-capping —
the union of what the assigned architectures need.

Tiling: grid (B, H, nq, nk); q tile (bq, d) stays resident across the nk inner
steps; k/v tiles (bk, d) stream through VMEM; m/l/acc live in VMEM scratch.
bq/bk default to 128/256 — multiples of the 128-wide MXU/VPU lanes; d
(head_dim 64..256 across the pool) is MXU-aligned for all assigned archs.
Causal+window block skipping is done with ``pl.when`` on block indices so
fully-masked tiles cost no FLOPs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = iq * bq
    k0 = ik * bk
    # block-level skip: any (i, j) with j <= i reachable? window reachable?
    reachable = True
    if causal:
        reachable = jnp.asarray(k0 <= q0 + bq - 1)
    if window is not None:
        reachable = jnp.logical_and(
            reachable, jnp.asarray(q0 - (k0 + bk - 1) < window))

    @pl.when(reachable)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        allow = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            allow &= kpos <= qpos
        if window is not None:
            allow &= (qpos - kpos) < window
        s = jnp.where(allow, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None]) * allow
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p.astype(v_ref.dtype), v_ref[0, 0],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    bq: int = 128, bk: int = 256,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q (B, H, T, d); k, v (B, KH, S, d) -> (B, H, T, d).

    GQA handled by per-head index mapping (H % KH == 0); no KV duplication.
    ``interpret=None`` auto-detects: native compile on TPU, interpret mode
    on host backends (kernels.resolve_interpret).
    """
    interpret = resolve_interpret(interpret)
    B, H, T, d = q.shape
    KH, S = k.shape[1], k.shape[2]
    G = H // KH
    if scale is None:
        scale = d ** -0.5
    bq = min(bq, T)
    bk = min(bk, S)
    assert T % bq == 0 and S % bk == 0, (T, bq, S, bk)
    nq, nk = T // bq, S // bk

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk)
    fn = pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, T, d), q.dtype),
        interpret=interpret,
    )
    return fn(q, k, v)
