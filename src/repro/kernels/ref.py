"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def embedding_gather_ref(table: jax.Array, ids: jax.Array) -> jax.Array:
    """(V, D), (B, Vl) -> (B, Vl, D); rows for ids < 0 are zero."""
    valid = (ids >= 0)[..., None]
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    return jnp.where(valid, rows, 0.0)


def embedding_lookup_ref(table: jax.Array, ids: jax.Array,
                         combiner: str = "sum") -> jax.Array:
    """(V, D), (B, Vl) -> (B, D) combined."""
    rows = embedding_gather_ref(table, ids)
    valid = (ids >= 0).astype(table.dtype)
    out = rows.sum(axis=1)
    if combiner == "mean":
        out = out / jnp.maximum(valid.sum(axis=1), 1.0)[..., None]
    return out


def embedding_scatter_ref(grads: jax.Array, ids: jax.Array,
                          vocab: int) -> jax.Array:
    """(N, D), (N,) unique ids (-1 pad) -> (V, D) gradient table."""
    valid = (ids >= 0)[:, None]
    safe = jnp.maximum(ids, 0)
    return jnp.zeros((vocab, grads.shape[1]), grads.dtype).at[safe].add(
        jnp.where(valid, grads, 0.0))


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """q (B, H, T, d); k, v (B, KH, S, d) -> (B, H, T, d)."""
    B, H, T, d = q.shape
    KH, S = k.shape[1], k.shape[2]
    G = H // KH
    if scale is None:
        scale = d ** -0.5
    qr = q.reshape(B, KH, G, T, d).astype(jnp.float32) * scale
    s = jnp.einsum("bkgtd,bksd->bkgts", qr, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    allow = jnp.ones((T, S), bool)
    if causal:
        allow &= kpos <= qpos
    if window is not None:
        allow &= (qpos - kpos) < window
    s = jnp.where(allow, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bksd->bkgtd", p, v.astype(jnp.float32))
    return o.reshape(B, H, T, d).astype(q.dtype)
