"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def embedding_gather_ref(table: jax.Array, ids: jax.Array) -> jax.Array:
    """(V, D), (B, Vl) -> (B, Vl, D); rows for ids < 0 are zero."""
    valid = (ids >= 0)[..., None]
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    return jnp.where(valid, rows, 0.0)


def embedding_lookup_ref(table: jax.Array, ids: jax.Array,
                         combiner: str = "sum") -> jax.Array:
    """(V, D), (B, Vl) -> (B, D) combined."""
    rows = embedding_gather_ref(table, ids)
    valid = (ids >= 0).astype(table.dtype)
    out = rows.sum(axis=1)
    if combiner == "mean":
        out = out / jnp.maximum(valid.sum(axis=1), 1.0)[..., None]
    return out


def embedding_scatter_ref(grads: jax.Array, ids: jax.Array,
                          vocab: int) -> jax.Array:
    """(N, D), (N,) unique ids (-1 pad) -> (V, D) gradient table."""
    valid = (ids >= 0)[:, None]
    safe = jnp.maximum(ids, 0)
    return jnp.zeros((vocab, grads.shape[1]), grads.dtype).at[safe].add(
        jnp.where(valid, grads, 0.0))


def fused_lookup_ref(table: jax.Array, rows: jax.Array, slots: jax.Array,
                     means: jax.Array) -> jax.Array:
    """Oracle for the fused multi-group lookup kernel.

    table (R, Dm); rows (B, S) absolute fused row ids (-1 invalid);
    slots (S,) i32 output slot per descriptor column; means (K,) i32 mean
    flags -> (B, K, Dm) combined slot vectors.
    """
    K = means.shape[0]
    valid = rows >= 0
    vecs = jnp.take(table, jnp.maximum(rows, 0), axis=0).astype(jnp.float32)
    vecs = jnp.where(valid[..., None], vecs, 0.0)          # (B, S, Dm)
    onehot = jax.nn.one_hot(slots, K, dtype=jnp.float32)   # (S, K)
    out = jnp.einsum("bsd,sk->bkd", vecs, onehot)
    cnt = jnp.einsum("bs,sk->bk", valid.astype(jnp.float32), onehot)
    denom = jnp.where(means[None, :] > 0, jnp.maximum(cnt, 1.0), 1.0)
    return (out / denom[..., None]).astype(table.dtype)


def fused_scatter_ref(gout: jax.Array, rows: jax.Array, slots: jax.Array,
                      vocab: int) -> jax.Array:
    """Oracle for the fused multi-group gradient scatter.

    gout (B, K, Dm) slot grads (pre-scaled for mean combiners) -> (R, Dm).
    """
    B, K, Dm = gout.shape
    g_desc = jnp.take(gout, slots, axis=1)                  # (B, S, Dm)
    valid = (rows >= 0)[..., None]
    g_desc = jnp.where(valid, g_desc, 0.0)
    flat = jnp.maximum(rows, 0).reshape(-1)
    return jnp.zeros((vocab, Dm), gout.dtype).at[flat].add(
        g_desc.reshape(-1, Dm))


def paged_decode_attention_ref(q, k, v, seq_lens, *,
                               window=None,
                               softcap: Optional[float] = None,
                               scale: Optional[float] = None) -> jax.Array:
    """Dense oracle (and non-TPU serving fallback) for the paged decode
    attention kernel.

    q (B, H, d); k, v (B, S, KH, d); seq_lens (B,) int32 valid rows per slot
    (query attends kv_pos < seq_lens[b]; query position is seq_lens[b]-1)
    -> (B, H, d).  ``window`` may be a python int, None, or a traced scalar
    (per-layer window schedules are scanned as data).  Slots with
    seq_len == 0 return zeros, matching the kernel.
    """
    B, H, d = q.shape
    S, KH = k.shape[1], k.shape[2]
    G = H // KH
    if scale is None:
        scale = d ** -0.5
    qr = q.reshape(B, KH, G, d).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(S, dtype=jnp.int32)[None, :]          # (1, S)
    lens = seq_lens.astype(jnp.int32)[:, None]              # (B, 1)
    allow = kpos < lens
    if window is not None:
        allow &= (lens - 1) - kpos < jnp.asarray(window, jnp.int32)
    allow_b = allow[:, None, None, :]                       # (B, 1, 1, S)
    s = jnp.where(allow_b, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m) * allow_b
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgs,bskd->bkgd", p / l, v.astype(jnp.float32))
    return o.reshape(B, H, d).astype(q.dtype)


def paged_decode_attention_bt_ref(q, k, v, seq_lens, tables, *,
                                  window=None,
                                  softcap: Optional[float] = None,
                                  scale: Optional[float] = None
                                  ) -> jax.Array:
    """Dense oracle for the block-table-indexed paged decode kernel.

    q (B, H, d); k, v (NB, bs, KH, d) physical block pool; tables (B, nb)
    int32 logical->physical block map (out-of-range entries clamp, their
    lanes sit past seq_lens and are masked) -> (B, H, d).  Gathers each
    slot's logical KV view from the pool and defers to the dense paged
    reference, so pooled and per-slot layouts share one masking contract.
    """
    NB, bs, KH, d = k.shape
    B, nb = tables.shape
    t = jnp.clip(tables.astype(jnp.int32), 0, NB - 1)
    kc = jnp.take(k, t.reshape(-1), axis=0).reshape(B, nb * bs, KH, d)
    vc = jnp.take(v, t.reshape(-1), axis=0).reshape(B, nb * bs, KH, d)
    return paged_decode_attention_ref(q, kc, vc, seq_lens, window=window,
                                      softcap=softcap, scale=scale)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """q (B, H, T, d); k, v (B, KH, S, d) -> (B, H, T, d)."""
    B, H, T, d = q.shape
    KH, S = k.shape[1], k.shape[2]
    G = H // KH
    if scale is None:
        scale = d ** -0.5
    qr = q.reshape(B, KH, G, T, d).astype(jnp.float32) * scale
    s = jnp.einsum("bkgtd,bksd->bkgts", qr, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    allow = jnp.ones((T, S), bool)
    if causal:
        allow &= kpos <= qpos
    if window is not None:
        allow &= (qpos - kpos) < window
    s = jnp.where(allow, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bksd->bkgtd", p, v.astype(jnp.float32))
    return o.reshape(B, H, T, d).astype(q.dtype)
