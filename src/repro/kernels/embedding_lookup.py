"""Pallas TPU kernel: fused embedding gather + segment combine.

This is the SparseCore Fetch-unit/scVPU analogue (paper §3.5, Figure 7):
  * the scalar-prefetched id list plays the Fetch unit's descriptor stream —
    BlockSpec index_maps consume the prefetched ids so each grid step DMAs
    exactly one embedding row HBM→VMEM (the SC's per-tile HBM channel),
  * the VMEM accumulator is the Spmem tile slice,
  * the multiply-accumulate combine is the scVPU / cross-channel reduce.

Two entry points:
  * ``gather_kernel_call``  — (V, D), (B, Vl) -> (B, Vl, D) row gather.
  * ``lookup_kernel_call``  — (V, D), (B, Vl) -> (B, D) fused gather+combine
    (sum or mean over the valency axis) without materialising (B, Vl, D) —
    the win over the XLA gather+reduce path.

Invalid ids (< 0) contribute zero.  On real TPU hardware D should be padded
to a multiple of 128 lanes; interpret mode (CPU validation) has no such
constraint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Row gather
# ---------------------------------------------------------------------------

def _gather_kernel(ids_ref, table_ref, out_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    valid = ids_ref[b, j] >= 0

    @pl.when(valid)
    def _():
        out_ref[0, 0, :] = table_ref[0, :]

    @pl.when(jnp.logical_not(valid))
    def _():
        out_ref[0, 0, :] = jnp.zeros_like(out_ref[0, 0, :])


def gather_kernel_call(table: jax.Array, ids: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """table (V, D) f32, ids (B, Vl) i32 -> (B, Vl, D) f32."""
    V, D = table.shape
    B, Vl = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Vl),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, j, ids: (jnp.maximum(ids[b, j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, j, ids: (b, j, 0)),
    )
    fn = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Vl, D), table.dtype),
        interpret=interpret,
    )
    return fn(ids, table)


# ---------------------------------------------------------------------------
# Fused gather + combine
# ---------------------------------------------------------------------------

def _lookup_kernel(ids_ref, table_ref, out_ref, acc_ref, *, n_val: int,
                   mean: bool):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = ids_ref[b, j] >= 0

    @pl.when(valid)
    def _():
        acc_ref[...] += table_ref[0, :].astype(jnp.float32)

    @pl.when(j == n_val - 1)
    def _():
        acc = acc_ref[...]
        if mean:
            count = jnp.zeros((), jnp.float32)
            for jj in range(n_val):
                count += (ids_ref[b, jj] >= 0).astype(jnp.float32)
            acc = acc / jnp.maximum(count, 1.0)
        out_ref[0, :] = acc.astype(out_ref.dtype)


def lookup_kernel_call(table: jax.Array, ids: jax.Array, *,
                       combiner: str = "sum",
                       interpret: bool = True) -> jax.Array:
    """table (V, D), ids (B, Vl) -> (B, D) combined (sum/mean over valency)."""
    V, D = table.shape
    B, Vl = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Vl),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, j, ids: (jnp.maximum(ids[b, j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, j, ids: (b, 0)),
        scratch_shapes=[pltpu.VMEM((D,), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_lookup_kernel, n_val=Vl, mean=(combiner == "mean")),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )
    return fn(ids, table)
