"""Pallas TPU kernel: fused embedding gather + segment combine.

This is the SparseCore Fetch-unit/scVPU analogue (paper §3.5, Figure 7):
  * the scalar-prefetched id list plays the Fetch unit's descriptor stream —
    BlockSpec index_maps consume the prefetched ids so each grid step DMAs
    exactly one embedding row HBM→VMEM (the SC's per-tile HBM channel),
  * the VMEM accumulator is the Spmem tile slice,
  * the multiply-accumulate combine is the scVPU / cross-channel reduce.

Three entry points:
  * ``gather_kernel_call``  — (V, D), (B, Vl) -> (B, Vl, D) row gather.
  * ``lookup_kernel_call``  — (V, D), (B, Vl) -> (B, D) fused gather+combine
    (sum or mean over the valency axis) without materialising (B, Vl, D) —
    the win over the XLA gather+reduce path.
  * ``fused_lookup_kernel_call`` — ONE launch over every table: the fused
    row space (R, Dm) is the concatenation of all width-groups (rows padded
    to a common lane width Dm) and the scalar-prefetched descriptor stream
    ``rows (B, S)`` / ``slots (S,)`` plays the SC Fetch unit's per-table
    descriptor list.  Each grid step DMAs one absolute row and accumulates
    it into the output slot of the table that owns descriptor column ``s``;
    the accumulator flushes when the slot id changes.  This amortises one
    CISC-instruction issue (one ``pallas_call``) across the whole table
    batch instead of paying it per width-group.

Invalid ids (< 0) contribute zero.  On real TPU hardware D should be padded
to a multiple of 128 lanes; interpret mode (CPU validation) has no such
constraint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Row gather
# ---------------------------------------------------------------------------

def _gather_kernel(ids_ref, table_ref, out_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    valid = ids_ref[b, j] >= 0

    @pl.when(valid)
    def _():
        out_ref[0, 0, :] = table_ref[0, :]

    @pl.when(jnp.logical_not(valid))
    def _():
        out_ref[0, 0, :] = jnp.zeros_like(out_ref[0, 0, :])


def gather_kernel_call(table: jax.Array, ids: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """table (V, D) f32, ids (B, Vl) i32 -> (B, Vl, D) f32."""
    V, D = table.shape
    B, Vl = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Vl),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, j, ids: (jnp.maximum(ids[b, j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, j, ids: (b, j, 0)),
    )
    fn = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Vl, D), table.dtype),
        interpret=interpret,
    )
    return fn(ids, table)


# ---------------------------------------------------------------------------
# Fused gather + combine
# ---------------------------------------------------------------------------

def _lookup_kernel(ids_ref, table_ref, out_ref, acc_ref, *, n_val: int,
                   mean: bool):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = ids_ref[b, j] >= 0

    @pl.when(valid)
    def _():
        acc_ref[...] += table_ref[0, :].astype(jnp.float32)

    @pl.when(j == n_val - 1)
    def _():
        acc = acc_ref[...]
        if mean:
            count = jnp.zeros((), jnp.float32)
            for jj in range(n_val):
                count += (ids_ref[b, jj] >= 0).astype(jnp.float32)
            acc = acc / jnp.maximum(count, 1.0)
        out_ref[0, :] = acc.astype(out_ref.dtype)


def lookup_kernel_call(table: jax.Array, ids: jax.Array, *,
                       combiner: str = "sum",
                       interpret: bool = True) -> jax.Array:
    """table (V, D), ids (B, Vl) -> (B, D) combined (sum/mean over valency)."""
    V, D = table.shape
    B, Vl = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Vl),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, j, ids: (jnp.maximum(ids[b, j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, j, ids: (b, 0)),
        scratch_shapes=[pltpu.VMEM((D,), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_lookup_kernel, n_val=Vl, mean=(combiner == "mean")),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )
    return fn(ids, table)


# ---------------------------------------------------------------------------
# Fused multi-group lookup (one grid over every table)
# ---------------------------------------------------------------------------

def _fused_lookup_kernel(rows_ref, slots_ref, means_ref, table_ref, out_ref,
                         acc_ref, cnt_ref, *, n_desc: int):
    b = pl.program_id(0)
    s = pl.program_id(1)
    slot = slots_ref[s]
    # descriptor columns are sorted by slot, so each output slot is a
    # contiguous run of grid steps: reset at the run head, flush at its tail
    prev_same = jnp.where(s > 0, slots_ref[jnp.maximum(s - 1, 0)] == slot,
                          False)

    @pl.when(jnp.logical_not(prev_same))
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    valid = rows_ref[b, s] >= 0

    @pl.when(valid)
    def _():
        acc_ref[...] += table_ref[0, :].astype(jnp.float32)
        cnt_ref[...] += 1.0

    last = jnp.where(s < n_desc - 1,
                     slots_ref[jnp.minimum(s + 1, n_desc - 1)] != slot, True)

    @pl.when(last)
    def _():
        acc = acc_ref[...]
        acc = jnp.where(means_ref[slot] > 0,
                        acc / jnp.maximum(cnt_ref[0], 1.0), acc)
        out_ref[0, 0, :] = acc.astype(out_ref.dtype)


def _fused_lookup_kernel_q(rows_ref, slots_ref, means_ref, table_ref,
                           scale_ref, out_ref, acc_ref, cnt_ref, *,
                           n_desc: int, tile: int):
    """int8-table variant: dequantise the gathered row in VMEM before the
    accumulate.  ``table_ref`` block is (1, Dm) int8, ``scale_ref`` block is
    (1, nt) f32 with ``nt * tile == Dm`` (QTensor per-row tile scales)."""
    b = pl.program_id(0)
    s = pl.program_id(1)
    slot = slots_ref[s]
    prev_same = jnp.where(s > 0, slots_ref[jnp.maximum(s - 1, 0)] == slot,
                          False)

    @pl.when(jnp.logical_not(prev_same))
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    valid = rows_ref[b, s] >= 0

    @pl.when(valid)
    def _():
        q = table_ref[0, :].astype(jnp.float32).reshape(-1, tile)
        row = (q * scale_ref[0, :][:, None]).reshape(-1)
        acc_ref[...] += row
        cnt_ref[...] += 1.0

    last = jnp.where(s < n_desc - 1,
                     slots_ref[jnp.minimum(s + 1, n_desc - 1)] != slot, True)

    @pl.when(last)
    def _():
        acc = acc_ref[...]
        acc = jnp.where(means_ref[slot] > 0,
                        acc / jnp.maximum(cnt_ref[0], 1.0), acc)
        out_ref[0, 0, :] = acc.astype(out_ref.dtype)


def fused_lookup_kernel_call(table: jax.Array, rows: jax.Array,
                             slots: jax.Array, means: jax.Array, *,
                             scales: jax.Array = None,
                             interpret: bool = True) -> jax.Array:
    """One launch over every table of a fused row space.

    table (R, Dm); rows (B, S) absolute fused row ids (-1 invalid);
    slots (S,) i32 non-decreasing output-slot id per descriptor column;
    means (K,) i32, 1 where slot k mean-combines -> (B, K, Dm) combined.

    int8 tables (inference serving): pass ``table`` as int8 with per-row
    tile-wise fp32 ``scales (R, nt)`` (``models/quant.QTensor`` layout,
    ``nt = Dm // tile``).  Each grid step then DMAs a 1-byte row plus its
    scale row and dequantises inside the accumulate — the HBM row stream
    shrinks ~4x while the combine math stays fp32.
    """
    R, Dm = table.shape
    B, S = rows.shape
    K = means.shape[0]
    quantized = scales is not None
    in_specs = [
        pl.BlockSpec((1, Dm),
                     lambda b, s, rows, slots, means:
                     (jnp.maximum(rows[b, s], 0), 0)),
    ]
    operands = [table]
    kern = functools.partial(_fused_lookup_kernel, n_desc=S)
    out_dtype = table.dtype
    if quantized:
        nt = scales.shape[1]
        in_specs.append(
            pl.BlockSpec((1, nt),
                         lambda b, s, rows, slots, means:
                         (jnp.maximum(rows[b, s], 0), 0)))
        operands.append(scales)
        kern = functools.partial(_fused_lookup_kernel_q, n_desc=S,
                                 tile=Dm // nt)
        out_dtype = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, S),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, Dm),
                               lambda b, s, rows, slots, means:
                               (b, slots[s], 0)),
        scratch_shapes=[pltpu.VMEM((Dm,), jnp.float32),
                        pltpu.VMEM((1,), jnp.float32)],
    )
    fn = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Dm), out_dtype),
        interpret=interpret,
    )
    return fn(rows, slots, means, *operands)
