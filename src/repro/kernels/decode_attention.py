"""Pallas TPU kernel: paged decode attention (single query per slot).

The serving decode hot spot: every slot holds ONE fresh query token and a KV
cache whose *valid* length differs per slot (continuous batching admits and
retires requests independently).  A dense decode attention scans all
``max_len`` cache rows for every slot; this kernel gathers only each slot's
valid prefix — a per-slot ``seq_lens`` vector rides in scalar-prefetch SMEM
and KV blocks entirely past a slot's length are skipped with ``pl.when``, so
a freshly admitted slot costs ``ceil(len/bk)`` block reads no matter how long
the compile-time cache envelope is.

Semantics are shared with ``flash_attention``: flash-style online softmax
over KV blocks, GQA by per-head index mapping (no KV duplication), sliding
windows, and gemma2-style logit soft-capping.  ``ref.paged_decode_attention_
ref`` is the dense XLA oracle and serving fallback for non-TPU backends.

Tiling: grid (B, H, nk); the single query row (1, d) stays resident; k/v
blocks (bk, d) stream through VMEM; m/l/acc live in VMEM scratch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

NEG_INF = -1e30


def _decode_body(sl_ref, q_ref, load_kv, o_ref,
                 m_ref, l_ref, acc_ref, *,
                 scale: float, window: Optional[int],
                 softcap: Optional[float], bk: int, nk: int):
    """Shared online-softmax body; ``load_kv()`` yields this grid step's
    (bk, d) k and v tiles — raw VMEM loads on the full-width path, an
    int8-row dequant (1-byte rows + a per-row scale broadcast) on the
    quantized path."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sl = sl_ref[b]                                   # valid rows for slot b
    k0 = j * bk
    # block-level skip: anything in [k0, k0+bk) visible to the query row?
    reachable = k0 < sl
    if window is not None:
        # query position is sl-1; the window keeps kv_pos > qpos - window
        reachable = jnp.logical_and(
            reachable, (sl - 1) - (k0 + bk - 1) < window)

    @pl.when(reachable)
    def _():
        k, v = load_kv()                             # (bk, d) each
        q = q_ref[0].astype(jnp.float32) * scale     # (1, d)
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)      # (1, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        allow = kpos < sl
        if window is not None:
            allow = jnp.logical_and(allow, (sl - 1) - kpos < window)
        s = jnp.where(allow, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None]) * allow
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _decode_kernel(sl_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, **kw):
    _decode_body(sl_ref, q_ref,
                 lambda: (k_ref[0, :, 0], v_ref[0, :, 0]),
                 o_ref, m_ref, l_ref, acc_ref, **kw)


def _decode_kernel_q(sl_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
                     m_ref, l_ref, acc_ref, **kw):
    """int8-KV variant: k/v tiles arrive as int8 rows + per-row fp32 scales
    (models/quant.quantize_kv layout) and dequantise in VMEM right after the
    DMA — the HBM stream is 1 byte/element."""
    def load_kv():
        k = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0][:, None]
        v = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0][:, None]
        return k, v
    _decode_body(sl_ref, q_ref, load_kv, o_ref, m_ref, l_ref, acc_ref, **kw)


def paged_decode_attention_kernel_call(
        q: jax.Array, k: jax.Array, v: jax.Array, seq_lens: jax.Array, *,
        window: Optional[int] = None,
        softcap: Optional[float] = None,
        scale: Optional[float] = None,
        bk: int = 128,
        k_scale: Optional[jax.Array] = None,
        v_scale: Optional[jax.Array] = None,
        interpret: Optional[bool] = None) -> jax.Array:
    """q (B, H, d); k, v (B, S, KH, d); seq_lens (B,) int32 -> (B, H, d).

    ``seq_lens[b]`` counts the valid cache rows of slot b INCLUDING the
    just-written current token (the query attends to kv_pos < seq_lens[b]).
    GQA handled by per-head index mapping (H % KH == 0).  The cache length S
    is padded to a multiple of ``bk``; padded rows sit past every seq_len and
    are never touched.

    int8 KV: pass ``k``/``v`` as int8 with per-row fp32 ``k_scale``/
    ``v_scale`` (B, S, KH) — ``models/quant.quantize_kv`` layout.  Rows
    stream through VMEM as 1-byte lanes and dequantise in-kernel.
    """
    B, H, d = q.shape
    S, KH = k.shape[1], k.shape[2]
    G = H // KH
    quantized = k_scale is not None
    if scale is None:
        scale = d ** -0.5
    bk = min(bk, S)
    if S % bk:
        pad = bk - S % bk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if quantized:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
        S += pad
    nk = S // bk
    seq_lens = seq_lens.astype(jnp.int32)

    kv_spec = pl.BlockSpec((1, bk, 1, d), lambda b, h, j, sl: (b, j, h // G, 0))
    sc_spec = pl.BlockSpec((1, bk, 1), lambda b, h, j, sl: (b, j, h // G))
    if quantized:
        kern = functools.partial(
            _decode_kernel_q, scale=scale, window=window, softcap=softcap,
            bk=bk, nk=nk)
        in_specs = [
            pl.BlockSpec((1, 1, d), lambda b, h, j, sl: (b, h, 0)),
            kv_spec, sc_spec, kv_spec, sc_spec,
        ]
        operands = (q, k, k_scale, v, v_scale)
    else:
        kern = functools.partial(
            _decode_kernel, scale=scale, window=window, softcap=softcap,
            bk=bk, nk=nk)
        in_specs = [
            pl.BlockSpec((1, 1, d), lambda b, h, j, sl: (b, h, 0)),
            kv_spec, kv_spec,
        ]
        operands = (q, k, v)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, d), lambda b, h, j, sl: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, d), q.dtype),
        interpret=resolve_interpret(interpret),
    )
    return fn(seq_lens, *operands)


# ---------------------------------------------------------------------------
# Block-table-indexed variant (pooled prefix-shared KV)
# ---------------------------------------------------------------------------
# Same kernel body — it only ever reasons about LOGICAL positions (seq_lens,
# block index j) — but the KV lives in a shared physical block pool and each
# slot carries an indirection table.  The table rides in scalar-prefetch SMEM
# next to ``seq_lens`` and the k/v BlockSpec index maps translate logical
# block j of slot b to pool block ``tables[b, j]``; the existing block-skip
# (``j * bk < seq_lens[b]``) keeps invalid table tail entries unread.


def _decode_kernel_bt(sl_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                      m_ref, l_ref, acc_ref, **kw):
    # the table is consumed by the index maps; the math is position-based
    del bt_ref
    _decode_kernel(sl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, **kw)


def _decode_kernel_bt_q(sl_ref, bt_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                        o_ref, m_ref, l_ref, acc_ref, **kw):
    del bt_ref
    _decode_kernel_q(sl_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
                     m_ref, l_ref, acc_ref, **kw)


def paged_decode_attention_bt_kernel_call(
        q: jax.Array, k: jax.Array, v: jax.Array, seq_lens: jax.Array,
        tables: jax.Array, *,
        window: Optional[int] = None,
        softcap: Optional[float] = None,
        scale: Optional[float] = None,
        k_scale: Optional[jax.Array] = None,
        v_scale: Optional[jax.Array] = None,
        interpret: Optional[bool] = None) -> jax.Array:
    """q (B, H, d); k, v (NB, bs, KH, d) physical block pool;
    seq_lens (B,) int32; tables (B, nb) int32 logical->physical block map
    -> (B, H, d).

    ``seq_lens[b]`` counts valid LOGICAL rows (< nb * bs) including the
    just-written token; lanes past it are masked, so garbage in partially
    written or stale pool blocks never contributes.  The kernel block size
    equals the pool block size ``bs`` (one grid step streams one physical
    block).

    int8 KV: int8 ``k``/``v`` pools + per-row fp32 ``k_scale``/``v_scale``
    (NB, bs, KH); the indirection tables address scale blocks and value
    blocks identically."""
    B, H, d = q.shape
    NB, bs, KH = k.shape[0], k.shape[1], k.shape[2]
    nk = tables.shape[1]
    G = H // KH
    quantized = k_scale is not None
    if scale is None:
        scale = d ** -0.5
    seq_lens = seq_lens.astype(jnp.int32)
    # OOB sentinel entries (unadmitted slots) clamp to a real block: the
    # pipeline still fetches whatever the index map names, and seq_lens=0
    # masks the compute — mirrors the reference's clamped gather
    tables = jnp.clip(tables.astype(jnp.int32), 0, NB - 1)

    kv_spec = pl.BlockSpec((1, bs, 1, d),
                           lambda b, h, j, sl, bt: (bt[b, j], 0, h // G, 0))
    sc_spec = pl.BlockSpec((1, bs, 1),
                           lambda b, h, j, sl, bt: (bt[b, j], 0, h // G))
    if quantized:
        kern = functools.partial(
            _decode_kernel_bt_q, scale=scale, window=window,
            softcap=softcap, bk=bs, nk=nk)
        in_specs = [
            pl.BlockSpec((1, 1, d), lambda b, h, j, sl, bt: (b, h, 0)),
            kv_spec, sc_spec, kv_spec, sc_spec,
        ]
        operands = (q, k, k_scale, v, v_scale)
    else:
        kern = functools.partial(
            _decode_kernel_bt, scale=scale, window=window, softcap=softcap,
            bk=bs, nk=nk)
        in_specs = [
            pl.BlockSpec((1, 1, d), lambda b, h, j, sl, bt: (b, h, 0)),
            kv_spec, kv_spec,
        ]
        operands = (q, k, v)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, d), lambda b, h, j, sl, bt: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, d), q.dtype),
        interpret=resolve_interpret(interpret),
    )
    return fn(seq_lens, tables, *operands)
