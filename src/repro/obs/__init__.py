"""Unified telemetry: span tracing, metrics, Perfetto export, flight recorder.

One `Telemetry` object is the handle every subsystem takes:

    obs = Telemetry(tracing=True, clock=VirtualClock())
    with obs.span("serve.decode", track="replica:0"):
        ...
    obs.metrics.counter("fleet.drops", reason="stranded").inc()
    obs.event("machine.fail", cat="failure", block=3)
    obs.postmortem("slice_lost", job="train-0")

Cost model (the tentpole's contract):

  * **tracing** is opt-in (`tracing=False` default → the shared
    `NOOP_TRACER`; `obs.span(...)` returns one reusable null context,
    `complete`/`begin`/`end` are no-ops) — zero-cost when disabled;
  * **metrics** and the **flight recorder** are always on — an `inc` is
    one int add, a flight record one deque append — cheap enough that
    drop accounting and postmortems never depend on a debug flag.

`Telemetry.event` feeds the flight ring unconditionally and forwards to
the tracer only when tracing is enabled, so the last-N window behind a
postmortem is populated even in the default configuration.

`NULL_OBS` is a module-level default Telemetry (wall clock, tracing off)
for code paths constructed without an explicit handle.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

from .flight import DEFAULT_CAPACITY, FlightRecorder
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, Series)
from .perfetto import from_chrome_trace, to_chrome_trace, write_chrome_trace
from .trace import (DEFAULT_TRACK, NOOP_TRACER, Event, NoopTracer, Span,
                    Tracer, VirtualClock)

__all__ = [
    "Telemetry", "NULL_OBS",
    "Tracer", "NoopTracer", "NOOP_TRACER", "Span", "Event", "VirtualClock",
    "DEFAULT_TRACK",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "Series",
    "FlightRecorder",
    "to_chrome_trace", "write_chrome_trace", "from_chrome_trace",
]


class Telemetry:
    """The one handle: tracer + metrics registry + flight recorder.

    Args:
      tracing: record spans/events in a real `Tracer` (else the shared
        no-op tracer — the zero-cost default).
      clock: injectable time source for the tracer and flight records; a
        `VirtualClock` for fleet virtual time, or wall
        `time.perf_counter` when None.
      flight_capacity: depth of the always-on flight ring.
    """

    def __init__(self, tracing: bool = False, clock=None,
                 flight_capacity: int = DEFAULT_CAPACITY):
        self.clock = clock if clock is not None else time.perf_counter
        self.metrics = MetricsRegistry()
        self.recorder = FlightRecorder(capacity=flight_capacity)
        if tracing:
            # the tracer mirrors finished spans/events into the flight ring
            self.tracer: NoopTracer = Tracer(self.clock,
                                             recorder=self.recorder)
        else:
            self.tracer = NOOP_TRACER

    @property
    def tracing(self) -> bool:
        return self.tracer.enabled

    # -- recording (delegates; hot paths may grab .tracer/.metrics direct) -----

    def span(self, name: str, cat: str = "", track: Optional[str] = None,
             **args):
        return self.tracer.span(name, cat, track, **args)

    def complete(self, name: str, t0: float, t1: float, cat: str = "",
                 track: Optional[str] = None, **args):
        return self.tracer.complete(name, t0, t1, cat, track, **args)

    def event(self, name: str, cat: str = "", track: Optional[str] = None,
              t: Optional[float] = None, **args) -> None:
        """Instant mark: always into the flight ring, into the tracer
        only when tracing — incidents are recorded even when disabled.
        (The enabled tracer mirrors into the ring itself, so each event
        lands there exactly once either way.)"""
        if t is None:
            t = self.clock()
        if self.tracer.enabled:
            self.tracer.event(name, cat, track, t=t, **args)
        else:
            self.recorder.record("event", name, t,
                                 track=track or DEFAULT_TRACK, **args)

    def postmortem(self, reason: str, t: Optional[float] = None,
                   **detail) -> Optional[Dict[str, Any]]:
        if t is None:
            t = self.clock()
        return self.recorder.postmortem(reason, t=t, **detail)

    # -- export ----------------------------------------------------------------

    def chrome_trace(self, *, process_name: str = "repro") -> Dict[str, Any]:
        return to_chrome_trace(self.tracer, process_name=process_name,
                               metrics=self.metrics.dump())

    def write_trace(self, path: str, *, process_name: str = "repro") -> None:
        write_chrome_trace(self.tracer, path, process_name=process_name,
                           metrics=self.metrics.dump())

    def dump_metrics(self) -> Dict[str, Any]:
        return self.metrics.dump()


NULL_OBS = Telemetry(tracing=False)
