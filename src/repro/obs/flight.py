"""Failure flight recorder: a bounded ring of recent telemetry.

Always on.  Every span/event flowing through a `Telemetry` lands here as
one small dict appended to a `collections.deque(maxlen=N)` — negligible
cost, so the recorder never needs a disable switch.  When something bad
happens (a slice goes LOST, a train session is preempted, a request is
dropped) the instrumented layer calls `postmortem(...)`, which snapshots
the last N records *leading up to* the trigger into a retained report.
That turns "a failed drill requires print-debugging through virtual
time" into "read the postmortem": the record of what happened right
before the incident is already captured by the time the incident fires.
"""
from __future__ import annotations

import collections
import itertools
import json
from typing import Any, Dict, List, Optional

DEFAULT_CAPACITY = 512


class FlightRecorder:
    """Bounded ring buffer of telemetry records plus retained postmortems.

    Args:
      capacity: ring depth (records beyond it age out oldest-first).
      max_postmortems: retained incident snapshots; further triggers
        still count in ``postmortems_dropped`` so a flood of incidents
        can't eat unbounded memory but is never silently miscounted.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 max_postmortems: int = 32):
        self.capacity = capacity
        self.ring: collections.deque = collections.deque(maxlen=capacity)
        self.total_records = 0
        self.postmortems: List[Dict[str, Any]] = []
        self.max_postmortems = max_postmortems
        self.postmortems_dropped = 0
        self._seq = itertools.count()

    # -- write side ------------------------------------------------------------

    def record(self, kind: str, name: str, t: Optional[float],
               **fields) -> None:
        """Append one record; O(1), drops the oldest when full."""
        rec = {"seq": next(self._seq), "kind": kind, "name": name, "t": t}
        if fields:
            rec.update(fields)
        self.ring.append(rec)
        self.total_records += 1

    def postmortem(self, reason: str, t: Optional[float] = None,
                   **detail) -> Optional[Dict[str, Any]]:
        """Snapshot the ring into a retained incident report."""
        if len(self.postmortems) >= self.max_postmortems:
            self.postmortems_dropped += 1
            return None
        pm = {
            "reason": reason,
            "t": t,
            "detail": dict(detail),
            "window": list(self.ring),       # copy: the ring keeps moving
            "records_seen": self.total_records,
        }
        self.postmortems.append(pm)
        return pm

    # -- read side -------------------------------------------------------------

    def snapshot(self) -> List[Dict[str, Any]]:
        """The current ring contents, oldest first."""
        return list(self.ring)

    def last(self, n: int) -> List[Dict[str, Any]]:
        """The most recent ``n`` records, oldest first."""
        if n <= 0:
            return []
        return list(self.ring)[-n:]

    def dump_postmortems(self, path: str) -> None:
        """Write retained postmortems as a JSON file."""
        with open(path, "w") as f:
            json.dump({
                "postmortems": self.postmortems,
                "postmortems_dropped": self.postmortems_dropped,
                "capacity": self.capacity,
                "records_seen": self.total_records,
            }, f, indent=1, default=str)
