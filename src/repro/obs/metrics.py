"""Metrics registry: one API every subsystem reports through.

Four metric kinds, all label-aware and always cheap enough to leave on:

  * `Counter`   — monotonically increasing int (``inc``), e.g. drops.
  * `Gauge`     — last-written float (``set``), e.g. block slowdown.
  * `Histogram` — bounded-reservoir distribution (``observe``), e.g.
    per-chunk latency; summarises to count/sum/min/max/percentiles.
  * `Series`    — append-only list of sample dicts (``append``), the
    structured per-step log surface `Trainer.metrics_log` is a view of.

A `MetricsRegistry` hands metrics out get-or-create keyed on
``(name, sorted(labels))``, so two callers asking for the same labelled
metric share one instrument, and `dump()` flattens everything into the
``{"name{k=v,...}": value}`` dict the exporters and
`scripts/render_results.py` consume.

Instruments are plain Python (an ``inc`` is one int add) — the registry
is *always on*; only span tracing (`obs.trace`) has a no-op mode.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_name(name: str, key: LabelKey) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter.  ``inc`` is the only mutator."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-value-wins float."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Distribution with exact count/sum/min/max and a bounded reservoir
    for percentiles (the first ``reservoir`` observations are kept; a
    long-lived serving process must not grow a per-chunk latency list
    without bound).  ``saturated`` flags when percentiles became a
    prefix-sample rather than the full population — no silent truncation.
    """

    __slots__ = ("count", "total", "min", "max", "_values", "_cap")

    def __init__(self, reservoir: int = 4096):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._values: List[float] = []
        self._cap = reservoir

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._values) < self._cap:
            self._values.append(v)

    def percentile(self, q: float) -> float:
        if not self._values:
            return 0.0
        return float(np.percentile(np.asarray(self._values), q))

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "p50": 0.0, "p95": 0.0, "saturated": False}
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "saturated": self.count > len(self._values),
        }


class Series:
    """Append-only sample log (list of dicts), optionally bounded.

    The thin-view surface: `Trainer.metrics_log` and friends stay plain
    Python lists to their readers while the data lives in the registry.
    """

    __slots__ = ("samples", "_cap", "dropped")

    def __init__(self, cap: Optional[int] = None):
        self.samples: List[Dict[str, Any]] = []
        self._cap = cap
        self.dropped = 0

    def append(self, sample: Dict[str, Any]) -> None:
        if self._cap is not None and len(self.samples) >= self._cap:
            # drop the OLDEST half in one move (amortised O(1)); the
            # dropped counter keeps the truncation visible
            keep = self._cap // 2
            self.dropped += len(self.samples) - keep
            del self.samples[:len(self.samples) - keep]
        self.samples.append(sample)

    def __len__(self) -> int:
        return len(self.samples)

    def __iter__(self):
        return iter(self.samples)

    def __getitem__(self, i):
        return self.samples[i]


class MetricsRegistry:
    """Get-or-create registry of labelled metrics.

        reg = MetricsRegistry()
        reg.counter("fleet.drops", reason="wait_queue_full").inc()
        reg.gauge("machine.block_slowdown", block=3).set(2.0)
        reg.histogram("serve.chunk_s").observe(0.011)
        reg.dump()   # {"fleet.drops{reason=wait_queue_full}": 1, ...}
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, str, LabelKey], Any] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, Any], factory):
        key = (kind, name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = factory()
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, reservoir: int = 4096,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(reservoir))

    def series(self, name: str, cap: Optional[int] = None,
               **labels) -> Series:
        return self._get("series", name, labels, lambda: Series(cap))

    # -- read side -------------------------------------------------------------

    def value(self, name: str, **labels) -> Any:
        """Current value of a counter/gauge by (name, labels); 0 when the
        metric was never created (reading must not create instruments)."""
        for kind in ("counter", "gauge"):
            m = self._metrics.get((kind, name, _label_key(labels)))
            if m is not None:
                return m.value
        return 0

    def sum(self, name: str) -> float:
        """Sum of a counter/gauge across ALL label sets of ``name``."""
        total = 0.0
        for (kind, n, _), m in self._metrics.items():
            if n == name and kind in ("counter", "gauge"):
                total += m.value
        return total

    def labels_of(self, name: str) -> List[Dict[str, str]]:
        """Every label set ``name`` has been created with."""
        return [dict(key) for (kind, n, key) in self._metrics
                if n == name]

    def items(self) -> Iterable[Tuple[str, str, LabelKey, Any]]:
        for (kind, name, key), m in sorted(self._metrics.items()):
            yield kind, name, key, m

    def dump(self) -> Dict[str, Any]:
        """Flat ``{rendered_name: value}`` dict — counters/gauges as
        scalars, histograms as summary dicts, series as sample counts
        (the samples themselves stay behind the instrument; a flat dump
        must stay flat)."""
        out: Dict[str, Any] = {}
        for kind, name, key, m in self.items():
            rname = _render_name(name, key)
            if kind in ("counter", "gauge"):
                out[rname] = m.value
            elif kind == "histogram":
                out[rname] = m.summary()
            else:                               # series
                out[rname] = {"samples": len(m), "dropped": m.dropped}
        return out
