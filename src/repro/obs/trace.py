"""Span tracer with an injectable clock.

One `Tracer` records nested `Span`s (duration) and instant events onto
named *tracks* (one lane per replica/slice/subsystem in the Perfetto
export).  Three recording styles cover every call site:

  * ``with tracer.span("serve.decode", track="replica:0"):`` — scoped
    work timed by the tracer's clock (nesting tracked per-track via a
    span stack, so children carry their parent's id);
  * ``tracer.begin(...)`` / ``tracer.end(handle)`` — long-lived
    lifecycles that don't fit a ``with`` block (a slice's
    allocate→free span lives across many calls);
  * ``tracer.complete(name, t0, t1, ...)`` — fully explicit timestamps,
    the natural form for virtual-time event loops that know exactly when
    a chunk started and ended on the fleet clock.

The clock is *injected*: wall time by default, or a `VirtualClock` the
fleet event loop advances — so fleet virtual time and wall time both
trace deterministically through the same API.

`NoopTracer` (module singleton `NOOP_TRACER`) is the zero-cost default:
``span`` returns one shared reusable null context, ``event`` is a pass —
no allocation, no clock read, no branch beyond the method dispatch.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional

DEFAULT_TRACK = "main"


class VirtualClock:
    """A clock somebody else advances (the fleet event loop): reading it
    costs one attribute load, advancing it is monotonic by construction."""

    __slots__ = ("now",)

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def advance(self, t: float) -> None:
        """Move the clock forward to ``t`` (backward moves are ignored —
        a virtual clock never rewinds)."""
        if t > self.now:
            self.now = t

    def __call__(self) -> float:
        return self.now


@dataclasses.dataclass
class Span:
    """One completed (or still-open) traced operation."""
    sid: int
    name: str
    cat: str
    track: str
    t0: float
    t1: Optional[float] = None          # None while open
    parent: Optional[int] = None        # sid of the enclosing span
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def dur(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0


@dataclasses.dataclass
class Event:
    """One instant mark (a failure, a swap, a scale decision)."""
    name: str
    cat: str
    track: str
    t: float
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)


class _SpanCtx:
    """Reusable-ish context manager returned by `Tracer.span`."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, *exc) -> None:
        self._tracer.end(self._span)


class _NullCtx:
    """Shared no-op context (reentrant, reusable)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> None:
        return None


_NULL_CTX = _NullCtx()


class NoopTracer:
    """The disabled tracer: every method is a constant-cost no-op, so
    instrumented code pays nothing when tracing is off (the bitwise
    non-interference contract tests/test_observability.py pins)."""

    enabled = False
    spans: List[Span] = []              # class-level: always empty
    events: List[Event] = []
    dropped_spans = 0
    dropped_events = 0

    def span(self, name, cat="", track=None, **args):
        return _NULL_CTX

    def begin(self, name, cat="", track=None, t=None, **args):
        return None

    def end(self, span, t=None) -> None:
        return None

    def complete(self, name, t0, t1, cat="", track=None, **args):
        return None

    def event(self, name, cat="", track=None, t=None, **args):
        return None


NOOP_TRACER = NoopTracer()


class Tracer(NoopTracer):
    """Recording tracer.

    Args:
      clock: zero-arg callable returning the current time in seconds
        (wall `time.perf_counter` by default, or a `VirtualClock`).
      recorder: optional `obs.flight.FlightRecorder`; finished spans and
        instant events are mirrored into its ring.
      max_spans / max_events: retention bounds.  Past them, *new* records
        are counted in ``dropped_spans``/``dropped_events`` instead of
        stored — the exporter surfaces the counts, so a truncated trace
        never silently poses as complete.
    """

    enabled = True

    def __init__(self, clock=None, *, recorder=None,
                 max_spans: int = 200_000, max_events: int = 200_000):
        self.clock = clock if clock is not None else time.perf_counter
        self.recorder = recorder
        self.max_spans = max_spans
        self.max_events = max_events
        self.spans: List[Span] = []
        self.events: List[Event] = []
        self.dropped_spans = 0
        self.dropped_events = 0
        self._ids = itertools.count()
        self._open: Dict[str, List[Span]] = {}    # track -> span stack

    # -- spans -----------------------------------------------------------------

    def begin(self, name: str, cat: str = "", track: Optional[str] = None,
              t: Optional[float] = None, **args) -> Span:
        track = track or DEFAULT_TRACK
        stack = self._open.setdefault(track, [])
        span = Span(sid=next(self._ids), name=name, cat=cat, track=track,
                    t0=self.clock() if t is None else t,
                    parent=stack[-1].sid if stack else None, args=args)
        stack.append(span)
        return span

    def end(self, span: Optional[Span], t: Optional[float] = None) -> None:
        if span is None:
            return
        span.t1 = self.clock() if t is None else t
        stack = self._open.get(span.track, [])
        if span in stack:
            # close any children left open (crash / early return inside)
            while stack and stack[-1] is not span:
                dangling = stack.pop()
                dangling.t1 = span.t1
                self._store(dangling)
            stack.pop()
        self._store(span)

    def span(self, name: str, cat: str = "", track: Optional[str] = None,
             **args) -> _SpanCtx:
        return _SpanCtx(self, self.begin(name, cat, track, **args))

    def complete(self, name: str, t0: float, t1: float, cat: str = "",
                 track: Optional[str] = None, **args) -> Span:
        """Record an already-finished span with explicit timestamps (no
        stack interaction — virtual-time loops emit these out of order)."""
        span = Span(sid=next(self._ids), name=name, cat=cat,
                    track=track or DEFAULT_TRACK, t0=t0, t1=t1, args=args)
        self._store(span)
        return span

    def _store(self, span: Span) -> None:
        if len(self.spans) >= self.max_spans:
            self.dropped_spans += 1
            return
        self.spans.append(span)
        if self.recorder is not None:
            self.recorder.record("span", span.name, span.t1,
                                 track=span.track, dur=span.dur,
                                 **span.args)

    # -- instants --------------------------------------------------------------

    def event(self, name: str, cat: str = "", track: Optional[str] = None,
              t: Optional[float] = None, **args) -> Optional[Event]:
        ev = Event(name=name, cat=cat, track=track or DEFAULT_TRACK,
                   t=self.clock() if t is None else t, args=args)
        if len(self.events) >= self.max_events:
            self.dropped_events += 1
            return None
        self.events.append(ev)
        if self.recorder is not None:
            self.recorder.record("event", ev.name, ev.t, track=ev.track,
                                 **ev.args)
        return ev

    # -- read side -------------------------------------------------------------

    def open_spans(self) -> List[Span]:
        """Spans begun but not yet ended (live lifecycles)."""
        return [s for stack in self._open.values() for s in stack]

    def find(self, name: str) -> List[Span]:
        """Finished spans with this exact name, in record order."""
        return [s for s in self.spans if s.name == name]

    def find_events(self, name: Optional[str] = None,
                    cat: Optional[str] = None) -> List[Event]:
        """Instant events filtered by name and/or category, time-ordered."""
        evs = [e for e in self.events
               if (name is None or e.name == name)
               and (cat is None or e.cat == cat)]
        return sorted(evs, key=lambda e: e.t)
