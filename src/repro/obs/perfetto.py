"""Chrome-trace / Perfetto JSON export (and re-import).

Emits the Trace Event Format that both ``chrome://tracing`` and
https://ui.perfetto.dev open directly:

  * one *lane* per tracer track (``pid`` is the process label, each
    track becomes a ``tid`` named via ``"M"`` metadata events);
  * finished spans → ``"X"`` complete events (``ts``/``dur`` in µs);
  * instant marks (failures, swaps, preemptions, scale decisions) →
    ``"i"`` instant events with thread scope.

Timestamps are seconds in the tracer (virtual or wall) and microseconds
on the wire, per the format spec.  `from_chrome_trace` parses an
exported file back into plain span/event dicts — the schema round-trip
tests pin that nothing is lost in translation.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

_S_TO_US = 1e6


def _track_order(tracks) -> Dict[str, int]:
    """Stable track → tid assignment: sorted names, tid from 1."""
    return {name: i + 1 for i, name in enumerate(sorted(tracks))}


def to_chrome_trace(tracer, *, process_name: str = "repro",
                    metrics: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
    """Render a `Tracer`'s spans/events as a Chrome-trace JSON object."""
    pid = 1
    tracks = {s.track for s in tracer.spans} | {e.track for e in tracer.events}
    tids = _track_order(tracks)

    te: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": pid,
        "args": {"name": process_name},
    }]
    for track, tid in tids.items():
        te.append({"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                   "args": {"name": track}})

    for s in tracer.spans:
        te.append({
            "name": s.name, "cat": s.cat or "span", "ph": "X",
            "pid": pid, "tid": tids[s.track],
            "ts": s.t0 * _S_TO_US, "dur": max(0.0, s.dur) * _S_TO_US,
            "args": dict(s.args),
        })
    for e in tracer.events:
        te.append({
            "name": e.name, "cat": e.cat or "event", "ph": "i", "s": "t",
            "pid": pid, "tid": tids[e.track],
            "ts": e.t * _S_TO_US,
            "args": dict(e.args),
        })

    out: Dict[str, Any] = {
        "traceEvents": te,
        "displayTimeUnit": "ms",
        "otherData": {
            "dropped_spans": tracer.dropped_spans,
            "dropped_events": tracer.dropped_events,
        },
    }
    if metrics is not None:
        out["otherData"]["metrics"] = metrics
    return out


def write_chrome_trace(tracer, path: str, **kw) -> None:
    """`to_chrome_trace` straight to a file Perfetto can open."""
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer, **kw), f, default=str)


def from_chrome_trace(obj) -> Dict[str, Any]:
    """Parse Chrome-trace JSON (object, JSON text, or file path) back to
    ``{"spans": [...], "events": [...], "tracks": {tid: name}, ...}``
    with timestamps restored to seconds."""
    if isinstance(obj, str):
        if obj.lstrip().startswith(("{", "[")):
            obj = json.loads(obj)
        else:
            with open(obj) as f:
                obj = json.load(f)
    te = obj["traceEvents"] if isinstance(obj, dict) else obj

    tracks: Dict[int, str] = {}
    spans: List[Dict[str, Any]] = []
    events: List[Dict[str, Any]] = []
    for ev in te:
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "thread_name":
                tracks[ev.get("tid", 0)] = ev["args"]["name"]
            continue
        rec = {
            "name": ev["name"], "cat": ev.get("cat", ""),
            "tid": ev.get("tid", 0),
            "t0": ev["ts"] / _S_TO_US,
            "args": ev.get("args", {}),
        }
        if ph == "X":
            rec["dur"] = ev.get("dur", 0.0) / _S_TO_US
            spans.append(rec)
        elif ph == "i":
            events.append(rec)
    for rec in spans + events:
        rec["track"] = tracks.get(rec.pop("tid"), "main")

    out = {"spans": spans, "events": events,
           "tracks": {str(k): v for k, v in tracks.items()}}
    if isinstance(obj, dict):
        out["otherData"] = obj.get("otherData", {})
    return out
