"""Topology × parallelism co-optimization search (paper §4, Table 3).

"We can also use search to tailor the TPU v4 topology to the DNN model."

Given a model's communication profile, enumerate
  slice geometry (4i×4j×4k)  ×  partition spec [pipeline, data, model1, model2]
  ×  activation/weight partitioning (1D/2D)
with each parallel degree mapped onto torus dimensions, and rank configs by a
step-time estimate built on the collective cost model.  Reproduces Table 3's
findings: for the 512-chip LLM the search moves a novice's 4×8×16 / 16×32
model-parallel config to the 8×8×8 cube, and for GPT-3 pre-training it
prefers deeper pipeline + data parallelism over the expert's 8×8 tensor grid.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.costmodel import CollectiveCostModel, HardwareParams, TPU_V4
from repro.core.topology import SliceTopology, geometries_for, is_twistable


@dataclass(frozen=True)
class ModelProfile:
    """Communication-relevant summary of one training step (per replica)."""
    name: str
    params: float                    # trainable parameters
    layers: int
    d_model: int
    seq_len: int
    global_batch: int                # sequences
    bytes_per_param: int = 2         # bf16 weights/grads on the wire
    bytes_per_act: int = 2
    flops_per_token: Optional[float] = None   # default 6*params

    @property
    def tokens(self) -> float:
        return self.global_batch * self.seq_len

    @property
    def step_flops(self) -> float:
        per_tok = self.flops_per_token or 6.0 * self.params
        return per_tok * self.tokens


@dataclass(frozen=True)
class ParallelSpec:
    pipeline: int
    data: int
    model1: int
    model2: int
    act_partition: str = "2d"        # "1d" | "2d"
    weight_partition: str = "2d"

    @property
    def total(self) -> int:
        return self.pipeline * self.data * self.model1 * self.model2

    def label(self) -> str:
        return (f"[{self.pipeline},{self.data},{self.model1},{self.model2}] "
                f"{self.act_partition.upper()}/{self.weight_partition.upper()}")


@dataclass
class Evaluation:
    geometry: Tuple[int, int, int]
    spec: ParallelSpec
    step_time: float
    terms: Dict[str, float]

    @property
    def throughput(self) -> float:
        return 1.0 / self.step_time


# ---------------------------------------------------------------------------
# Step-time estimate
# ---------------------------------------------------------------------------

def _dim_assignments(dims: Tuple[int, int, int], spec: ParallelSpec
                     ) -> Optional[List[Dict[str, List[int]]]]:
    """Map each parallel degree onto whole torus dimensions (paper §2.7:
    'users map data parallelism along one dimension of the 3D torus and the
    two model parallel parameters on the other dimensions').

    Returns a list of axis->dims maps whose products match the spec, or None.
    """
    degrees = {"pipeline": spec.pipeline, "data": spec.data,
               "model1": spec.model1, "model2": spec.model2}
    out = []
    axes = [0, 1, 2]
    # assign each torus dim (possibly split) to a role greedily over perms
    for perm in itertools.permutations(axes):
        roles: Dict[str, List[int]] = {k: [] for k in degrees}
        sizes = dict(degrees)
        ok = True
        for ax in perm:
            d = dims[ax]
            placed = False
            for role in ("model1", "model2", "data", "pipeline"):
                if sizes[role] % d == 0 and sizes[role] >= d and d > 1:
                    roles[role].append(ax)
                    sizes[role] //= d
                    placed = True
                    break
            if not placed and d > 1:
                ok = False
                break
        if ok and all(v == 1 for v in sizes.values()):
            if not any(r == roles for r in out):
                out.append(roles)
    return out or None


def estimate_step_time(profile: ModelProfile,
                       dims: Tuple[int, int, int],
                       spec: ParallelSpec, *,
                       hw: HardwareParams = TPU_V4,
                       twisted: bool = False,
                       mfu: float = 0.55,
                       num_microbatches: Optional[int] = None
                       ) -> Optional[Evaluation]:
    """Analytic per-step time for one (geometry, partition spec) choice."""
    n = dims[0] * dims[1] * dims[2]
    if spec.total != n:
        return None
    assigns = _dim_assignments(dims, spec)
    if not assigns:
        return None
    topo = SliceTopology(dims, twisted=twisted)
    cm = CollectiveCostModel(hw)
    m = spec.model1 * spec.model2
    pp, dp = spec.pipeline, spec.data
    mb = num_microbatches or max(1, 2 * pp)

    best: Optional[Evaluation] = None
    for roles in assigns:
        # ---- compute
        flops_per_chip = profile.step_flops / n
        t_comp = flops_per_chip / (hw.peak_flops_bf16 * mfu)

        # ---- data-parallel gradient all-reduce (over the dp dims)
        grad_bytes = profile.params * profile.bytes_per_param / (m * pp)
        t_dp = cm.all_reduce(topo, grad_bytes, roles["data"] or None) \
            if dp > 1 else 0.0

        # ---- tensor-parallel activation collectives per layer
        layers_local = profile.layers / pp
        act_bytes = (profile.tokens / (dp * pp) * profile.d_model
                     * profile.bytes_per_act)
        t_tp = 0.0
        if m > 1:
            if spec.act_partition == "1d":
                # megatron-style: 2 all-reduces per layer fwd + 2 bwd over
                # the full model group
                mdl_dims = roles["model1"] + roles["model2"]
                t_tp = 4 * layers_local * cm.all_reduce(
                    topo, act_bytes / 1.0, mdl_dims or None)
            else:
                # 2D (GSPMD): all-gather over model1 + reduce-scatter over
                # model2, activations already split over the grid
                t_m1 = 4 * layers_local * cm.all_gather(
                    topo, act_bytes / max(spec.model2, 1),
                    roles["model1"] or None)
                t_m2 = 4 * layers_local * cm.reduce_scatter(
                    topo, act_bytes / max(spec.model1, 1),
                    roles["model2"] or None)
                t_tp = t_m1 + t_m2
            if spec.weight_partition == "2d" and dp > 1:
                # 2D weights add an all-gather of weight shards per layer
                w_bytes = (profile.params * profile.bytes_per_param
                           / (m * pp * dp))
                t_tp += cm.all_gather(topo, w_bytes, roles["data"] or None)

        # ---- pipeline p2p + bubble
        t_pp = 0.0
        bubble = 1.0
        if pp > 1:
            stage_act = (profile.tokens / (dp * mb) * profile.d_model
                         * profile.bytes_per_act)
            t_pp = 2.0 * mb * cm.p2p(stage_act)
            bubble = 1.0 + (pp - 1) / mb

        step = (t_comp + t_tp) * bubble + t_dp + t_pp
        ev = Evaluation(dims, spec, step,
                        {"compute": t_comp, "tp": t_tp, "dp": t_dp,
                         "pp": t_pp, "bubble": bubble})
        if best is None or ev.step_time < best.step_time:
            best = ev
    return best


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------

def _factor_pairs(n: int) -> List[Tuple[int, int]]:
    out = []
    for a in range(1, n + 1):
        if n % a == 0:
            out.append((a, n // a))
    return out


def search(profile: ModelProfile, num_chips: int, *,
           hw: HardwareParams = TPU_V4,
           max_pipeline: int = 16,
           allow_twist: bool = True,
           top_k: int = 5,
           geometries: Optional[Sequence[Tuple[int, int, int]]] = None,
           twisted: Optional[bool] = None) -> List[Evaluation]:
    """Enumerate geometries × partition specs; return the top_k by step time.

    ``geometries`` restricts the search to the given slice shapes (the
    `Slice.dryrun` path: "what is the best partitioning on the slice I
    already hold?"); ``twisted`` forces the twist state instead of trying
    both where legal.
    """
    results: List[Evaluation] = []
    if geometries is None:
        geoms = geometries_for(num_chips)
    else:
        geoms = [tuple(g) for g in geometries
                 if g[0] * g[1] * g[2] == num_chips]
    for dims in geoms:
        if twisted is not None:
            if twisted and not is_twistable(dims):
                continue
            twists = [twisted]
        else:
            twists = [False]
            if allow_twist and is_twistable(dims):
                twists.append(True)
        for pp in [p for p in (1, 2, 4, 8, 16, 32) if p <= max_pipeline]:
            if num_chips % pp:
                continue
            rest = num_chips // pp
            for dp, mtot in _factor_pairs(rest):
                if profile.global_batch % (dp * pp):
                    continue
                for m1, m2 in _factor_pairs(mtot):
                    for ap, wp in (("1d", "1d"), ("1d", "2d"),
                                   ("2d", "2d")):
                        spec = ParallelSpec(pp, dp, m1, m2, ap, wp)
                        for tw in twists:
                            ev = estimate_step_time(
                                profile, dims, spec, hw=hw, twisted=tw)
                            if ev is not None:
                                results.append(ev)
    results.sort(key=lambda e: e.step_time)
    return results[:top_k]
