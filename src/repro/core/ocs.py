"""Palomar-style Optical Circuit Switch fabric model (paper §2.1-2.2, §2.10).

The physical plant of one 4096-chip supercomputer:
  * 64 racks, each one 4×4×4 block (64 chips, 16 CPU hosts, electrical mesh
    inside),
  * 16 optical link-pairs per face dimension per block (6 faces × 16 links,
    circulators halve ports: 48 in/out pairs per block),
  * 48 OCSes of 136 ports (128 usable + 8 spares); pair k of every block
    lands on OCS k, so OCS k switches the dimension-k wraparound/inter-block
    links of the whole machine.

``OCSFabric.configure_slice`` programs the circuits for a block-level slice
(regular or twisted torus) and validates the 1:1 port constraint — this is
the software analogue of the "reprogramming of routing in the OCS" that makes
twisting free (§2.8).  ``reconfigure_around_failure`` swaps a spare block in
(§2.3) and reports how many circuits move (a millisecond-scale operation).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

BLOCK_EDGE = 4                  # chips per block edge (4^3 = 64 chips)
LINKS_PER_FACE = 16             # 4x4 chip faces
PAIRS_PER_BLOCK = 48            # 6 faces * 16 links / 2 (circulators)
OCS_PORTS = 136                 # 128 usable + 8 spares
OCS_USABLE_PORTS = 128
NUM_OCS = 48
SWITCH_TIME_S = 10e-3           # MEMS mirrors switch in milliseconds
# ACOS-style per-switch-array programming cost: each OCS serializes the
# re-programming of its own circuits (control-plane writes + mirror
# settling per circuit), while the 48 arrays work in parallel — so the
# reconfiguration tail grows with ceil(moved / arrays), not with the raw
# circuit count.  The MEMS switch time is paid once on top.
OCS_PROGRAM_S_PER_CIRCUIT = 1e-3


def reconfig_time(circuits_moved: int, arrays: int = NUM_OCS) -> float:
    """Seconds to re-program ``circuits_moved`` circuits across ``arrays``
    parallel switch arrays: one MEMS settle plus the per-array serialized
    programming of its share of the moves.  Zero moves cost zero — an
    identity reconfiguration never blacks the slice out."""
    if circuits_moved <= 0:
        return 0.0
    per_array = math.ceil(circuits_moved / max(1, arrays))
    return SWITCH_TIME_S + per_array * OCS_PROGRAM_S_PER_CIRCUIT


@dataclass(frozen=True)
class Circuit:
    """One OCS circuit: block A's '+' port pair k <-> block B's '-' pair k."""
    ocs: int
    dim: int
    pair: int                   # 0..15 within the face
    block_plus: int
    block_minus: int


@dataclass
class BlockSliceConfig:
    """A slice as a 3D grid of blocks with its torus circuits."""
    grid: Dict[Tuple[int, int, int], int]    # block-grid coord -> block id
    dims_blocks: Tuple[int, int, int]
    twisted: bool
    circuits: List[Circuit]


class OCSFabric:
    """Port accounting + circuit programming for one supercomputer."""

    def __init__(self, num_blocks: int = 64):
        self.num_blocks = num_blocks
        # ocs -> set of used (block, polarity) ports
        self._used: List[Dict[Tuple[int, str], Circuit]] = [
            dict() for _ in range(NUM_OCS)]

    # -- wiring rule ----------------------------------------------------------

    @staticmethod
    def ocs_for(dim: int, pair: int) -> int:
        """Pair (dim, i) of every block connects to the same OCS (§2.2)."""
        return dim * LINKS_PER_FACE + pair

    # -- circuit programming ----------------------------------------------------

    def configure_slice(self, blocks: Sequence[int],
                        dims_blocks: Tuple[int, int, int],
                        twisted: bool = False) -> BlockSliceConfig:
        """Program torus circuits for `blocks` arranged as dims_blocks.

        Blocks may come from anywhere in the machine (§2.5 scheduling
        benefit) — the OCS makes placement irrelevant.
        """
        a, b, c = dims_blocks
        assert a * b * c == len(blocks), (dims_blocks, len(blocks))
        grid = {}
        it = iter(blocks)
        for x, y, z in itertools.product(range(a), range(b), range(c)):
            grid[(x, y, z)] = next(it)

        dims = dims_blocks
        nshort = min(dims)
        tshort = dims.index(nshort)
        circuits: List[Circuit] = []
        for (x, y, z), blk in grid.items():
            coord = (x, y, z)
            for dim in range(3):
                size = dims[dim]
                if size == 1:
                    # self-wrap: the +/- faces of the same block connect
                    pass
                nxt = list(coord)
                nxt[dim] = (nxt[dim] + 1) % size
                wrapped = coord[dim] == size - 1
                if wrapped and twisted and dim == tshort:
                    for other in range(3):
                        if other != dim and dims[other] > nshort:
                            nxt[other] = (nxt[other] + nshort) % dims[other]
                nbr = grid[tuple(nxt)]
                for pair in range(LINKS_PER_FACE):
                    circuits.append(Circuit(
                        ocs=self.ocs_for(dim, pair), dim=dim, pair=pair,
                        block_plus=blk, block_minus=nbr))
        self._claim(circuits)
        return BlockSliceConfig(grid=grid, dims_blocks=dims_blocks,
                                twisted=twisted, circuits=circuits)

    def _claim(self, circuits: Sequence[Circuit]) -> None:
        for c in circuits:
            used = self._used[c.ocs]
            kp, km = (c.block_plus, "+"), (c.block_minus, "-")
            if kp in used or km in used:
                raise ValueError(
                    f"OCS {c.ocs} port conflict: {kp if kp in used else km}")
            if len(used) + 2 > 2 * OCS_USABLE_PORTS:
                raise ValueError(f"OCS {c.ocs} out of ports")
            used[kp] = c
            used[km] = c

    def release(self, cfg: BlockSliceConfig) -> None:
        for c in cfg.circuits:
            self._used[c.ocs].pop((c.block_plus, "+"), None)
            self._used[c.ocs].pop((c.block_minus, "-"), None)

    # -- failure handling ---------------------------------------------------------

    def reconfigure_around_failure(self, cfg: BlockSliceConfig,
                                   failed_block: int,
                                   spare_block: int) -> Tuple[int, float]:
        """Swap a failed block for a spare (§2.3: 'the OCS acts like a
        plugboard to skip failed units').  Returns (#circuits moved, seconds).
        """
        moved = 0
        self.release(cfg)
        for pos, blk in cfg.grid.items():
            if blk == failed_block:
                cfg.grid[pos] = spare_block
        new_circuits = []
        for c in cfg.circuits:
            bp = spare_block if c.block_plus == failed_block else c.block_plus
            bm = spare_block if c.block_minus == failed_block else c.block_minus
            if (bp, bm) != (c.block_plus, c.block_minus):
                moved += 1
            new_circuits.append(Circuit(c.ocs, c.dim, c.pair, bp, bm))
        cfg.circuits = new_circuits
        self._claim(new_circuits)
        # arrays reprogram in parallel; each serializes its own moves
        return moved, reconfig_time(moved)

    # -- twist-as-reconfiguration --------------------------------------------------

    def retwist(self, cfg: BlockSliceConfig, twisted: bool
                ) -> Tuple[BlockSliceConfig, int]:
        """Re-program the same blocks as a (un)twisted torus; returns the new
        config and the number of circuits that changed (§2.8: 'the only
        change is in the routing tables')."""
        old = {(c.ocs, c.block_plus): c.block_minus for c in cfg.circuits}
        self.release(cfg)
        blocks = [cfg.grid[k] for k in sorted(cfg.grid)]
        new = self.configure_slice(blocks, cfg.dims_blocks, twisted=twisted)
        changed = sum(
            1 for c in new.circuits
            if old.get((c.ocs, c.block_plus)) != c.block_minus)
        return new, changed


# ---------------------------------------------------------------------------
# Cost / power accounting (§2.10, §7.3)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FabricCost:
    """Rough capital/power accounting used by benchmarks/fig_cost.py.

    Defaults are order-of-magnitude public numbers (chip cost includes the
    tray/host/rack share; transceivers at hyperscale volume pricing): the
    assertion target is the paper's <5% cost / <3% power claim and the IB
    comparison of §7.3.
    """
    chip_cost: float = 15_000.0          # per TPU incl. tray/host/rack share
    ocs_cost: float = 30_000.0           # per 136-port Palomar OCS
    transceiver_cost: float = 250.0      # per optical link end (volume)
    fiber_cost: float = 100.0            # per link
    chip_power_w: float = 170.0          # paper Table 4 mean
    ocs_power_w: float = 100.0           # holding MEMS mirrors
    transceiver_power_w: float = 2.5
    ib_switch_cost: float = 16_500.0     # Mellanox QM8790 (paper §7.3)
    ib_switch_power_w: float = 350.0
    ib_nic_cost: float = 1_000.0

    def ocs_fabric_cost(self, num_chips: int = 4096) -> Dict[str, float]:
        blocks = num_chips // 64
        links = blocks * PAIRS_PER_BLOCK          # optical link pairs
        cost = (NUM_OCS * self.ocs_cost
                + 2 * links * self.transceiver_cost
                + links * self.fiber_cost)
        power = (NUM_OCS * self.ocs_power_w
                 + 2 * links * self.transceiver_power_w)
        total_cost = cost + num_chips * self.chip_cost
        total_power = power + num_chips * self.chip_power_w
        return {
            "interconnect_cost": cost,
            "interconnect_power_w": power,
            "cost_fraction": cost / total_cost,
            "power_fraction": power / total_power,
        }

    def ib_fabric_cost(self, num_chips: int = 4096) -> Dict[str, float]:
        """3-level fat tree per Nvidia guidance (§7.3): 568 switches for 4096."""
        switches = round(num_chips * 568 / 4096)
        cost = switches * self.ib_switch_cost + num_chips * self.ib_nic_cost
        power = switches * self.ib_switch_power_w
        total_cost = cost + num_chips * self.chip_cost
        total_power = power + num_chips * self.chip_power_w
        return {
            "interconnect_cost": cost,
            "interconnect_power_w": power,
            "cost_fraction": cost / total_cost,
            "power_fraction": power / total_power,
        }
