"""3D torus / twisted-torus slice topologies (paper §2).

A TPU v4 slice is a 3D torus of shape (a, b, c) chips built from 4³ blocks
joined by OCS circuits; the OCS can "rewire" wraparound links in milliseconds,
which enables the *twisted torus* variants of Camarero-Martinez-Beivide [8]
for k×k×2k / k×2k×2k geometries (paper §2.8, Figure 5).

This module is plain numpy (no jax): it models the physical link graph and is
consumed by the collective cost model, the goodput simulation, the scheduler,
and the autotopo search.

The twist rule (validated against Figure 6): wrapping around the *shortest*
dimension (size n) advances the coordinate of every *longer* dimension by n
(mod its size).  For n×n×2n this shifts only the long dimension (the classic
Camarero k×k×2k lattice); for n×2n×2n it shifts both long dimensions.  With
ideal multipath shortest-path routing this reproduces all-to-all throughput
gains of 1.52× (4×4×8) and 1.39× (4×8×8) vs the paper's measured 1.63×/1.31×
— within ±15% (benchmarks/fig6_twisted_alltoall.py asserts this).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

Coord = Tuple[int, int, int]


def is_twistable(dims: Sequence[int]) -> bool:
    """n×n×2n or n×2n×2n with n >= 4 (paper §2.9)."""
    a, b, c = sorted(dims)
    if a < 4:
        return False
    return (a == b and c == 2 * a) or (b == c and b == 2 * a)


@dataclass(frozen=True)
class SliceTopology:
    dims: Tuple[int, int, int]
    twisted: bool = False
    wraparound: bool = True          # <4^3 slices are meshes (paper §2.9)

    def __post_init__(self):
        if self.twisted:
            assert is_twistable(self.dims), (
                f"{self.dims} is not twistable (need n*n*2n or n*2n*2n)")

    # -- basic properties ---------------------------------------------------

    @property
    def num_chips(self) -> int:
        a, b, c = self.dims
        return a * b * c

    @property
    def num_blocks(self) -> int:
        return self.num_chips // 64

    def nodes(self) -> List[Coord]:
        a, b, c = self.dims
        return [(x, y, z) for x in range(a) for y in range(b)
                for z in range(c)]

    def node_index(self, n: Coord) -> int:
        a, b, c = self.dims
        return (n[0] * b + n[1]) * c + n[2]

    # -- link graph -----------------------------------------------------------

    def neighbors(self, n: Coord) -> List[Coord]:
        """The 6 (or fewer, for meshes) ICI neighbours of a chip."""
        a, b, c = self.dims
        dims = self.dims
        out: List[Coord] = []
        # twist role: wrapping the shortest dim advances every longer dim
        tshort = int(np.argmin(dims)) if self.twisted else None
        nshort = min(dims)
        for ax in range(3):
            size = dims[ax]
            if size == 1:
                continue
            for step in (1, -1):
                m = list(n)
                m[ax] += step
                wrapped = m[ax] < 0 or m[ax] >= size
                if wrapped:
                    if not self.wraparound or size <= 2:
                        if size <= 2 and step == -1:
                            continue  # avoid double link for size-2 dims
                        if not self.wraparound:
                            continue
                    m[ax] %= size
                    if self.twisted and ax == tshort:
                        shift = nshort * step
                        for other in range(3):
                            if other != ax and dims[other] > nshort:
                                m[other] = (m[other] + shift) % dims[other]
                out.append(tuple(m))
        return out

    def edges(self) -> List[Tuple[int, int]]:
        """Undirected edge list over node indices."""
        es = set()
        for n in self.nodes():
            i = self.node_index(n)
            for m in self.neighbors(n):
                j = self.node_index(m)
                es.add((min(i, j), max(i, j)))
        return sorted(es)

    def adjacency(self) -> List[List[int]]:
        adj: List[List[int]] = [[] for _ in range(self.num_chips)]
        for i, j in self.edges():
            adj[i].append(j)
            adj[j].append(i)
        return adj

    # -- metrics --------------------------------------------------------------

    def bisection_links(self) -> int:
        """Links crossing the best canonical balanced cut.

        Checks the three axis-aligned half cuts (the standard torus bisection
        planes); the minimum is the bisection for these topologies.
        """
        best = None
        nodes = self.nodes()
        for ax in range(3):
            size = self.dims[ax]
            if size < 2:
                continue
            half = size // 2
            left = {self.node_index(n) for n in nodes if n[ax] < half}
            cut = 0
            for n in nodes:
                i = self.node_index(n)
                for m in self.neighbors(n):
                    j = self.node_index(m)
                    if i < j and ((i in left) != (j in left)):
                        cut += 1
            best = cut if best is None else min(best, cut)
        return best or 0

    def diameter_and_avg_hops(self) -> Tuple[int, float]:
        adj = self.adjacency()
        N = self.num_chips
        diam = 0
        total = 0
        for s in range(N):
            dist = _bfs(adj, s)
            diam = max(diam, int(dist.max()))
            total += int(dist.sum())
        return diam, total / (N * (N - 1))

    def link_loads_alltoall(self) -> Dict[Tuple[int, int], float]:
        """Per-directed-link load for uniform all-to-all with ideal
        (fractional) shortest-path multipath routing.

        Load on edge (u, v) = expected number of unit messages traversing it
        when every ordered pair exchanges one unit.  max(load) bounds
        all-to-all time: T = max_load * message_bytes / link_bw.
        """
        adj = self.adjacency()
        N = self.num_chips
        loads: Dict[Tuple[int, int], float] = {}
        for s in range(N):
            for e, l in _spdag_loads(adj, s).items():
                loads[e] = loads.get(e, 0.0) + l
        return loads

    def alltoall_max_load(self) -> float:
        loads = self.link_loads_alltoall()
        return max(loads.values()) if loads else 0.0

    def describe(self) -> str:
        t = "_T" if self.twisted else ""
        a, b, c = self.dims
        return f"{a}x{b}x{c}{t}"


def _bfs(adj: List[List[int]], s: int) -> np.ndarray:
    N = len(adj)
    dist = np.full(N, -1, np.int32)
    dist[s] = 0
    frontier = [s]
    d = 0
    while frontier:
        nxt = []
        d += 1
        for u in frontier:
            for v in adj[u]:
                if dist[v] < 0:
                    dist[v] = d
                    nxt.append(v)
        frontier = nxt
    return dist


def _spdag_loads(adj: List[List[int]], s: int) -> Dict[Tuple[int, int], float]:
    """Fractional shortest-path-DAG edge loads for one source.

    Every destination t receives one unit from s, split equally over all
    shortest paths (classic ideal multipath load model).
    """
    N = len(adj)
    dist = _bfs(adj, s)
    order = np.argsort(dist)                     # nodes by distance
    # number of shortest paths from s
    nsp = np.zeros(N, np.float64)
    nsp[s] = 1.0
    for u in order:
        du = dist[u]
        for v in adj[u]:
            if dist[v] == du + 1:
                nsp[v] += nsp[u]
    # accumulate flow backwards: flow into t is 1 (for t != s)
    flow = np.ones(N, np.float64)
    flow[s] = 0.0
    loads: Dict[Tuple[int, int], float] = {}
    for u in order[::-1]:
        if u == s or dist[u] <= 0:
            continue
        preds = [v for v in adj[u] if dist[v] == dist[u] - 1]
        tot = sum(nsp[v] for v in preds)
        for v in preds:
            share = flow[u] * (nsp[v] / tot)
            loads[(v, u)] = loads.get((v, u), 0.0) + share
            flow[v] += share
    return loads


# ---------------------------------------------------------------------------
# Slice geometry enumeration (scheduler + autotopo)
# ---------------------------------------------------------------------------

def geometries_for(num_chips: int, *, min_dim: int = 4
                   ) -> List[Tuple[int, int, int]]:
    """All 4i×4j×4k (i<=j<=k) geometries with the given chip count."""
    out = []
    n = num_chips
    for a in range(min_dim, n + 1, min_dim):
        if n % a:
            continue
        for b in range(a, n // a + 1, min_dim):
            if (n // a) % b:
                continue
            c = n // (a * b)
            if c >= b and c % min_dim == 0:
                out.append((a, b, c))
    return out
