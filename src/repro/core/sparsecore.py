"""SparseCore execution/timing model (paper §3, Figures 8-10; §4 Figure 10).

Models one embedding training step as the SC dataflow pipeline:

  Fetch (HBM gather) -> scVPU combine -> ICI all-to-all -> Flush (HBM update)

and compares placements:
  * ``sc``   — embeddings in TPU HBM with SparseCores (the paper's design),
  * ``cpu``  — embeddings in host CPU memory (Fig 9 "Emb on CPU": 4 TPUs
    share one host's DRAM bandwidth, data-center network in the loop).

The same model evaluates TPU v3 (2 SCs, 2D torus) vs v4 (4 SCs, 3D torus) for
Figures 8/12, and drives the PA-NAS SC/TC balance search of Figure 10.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.configs.base import DLRMConfig, ModelConfig
from repro.core.costmodel import (CollectiveCostModel, HardwareParams,
                                  TPU_V3, TPU_V4)
from repro.core.topology import SliceTopology


@dataclass(frozen=True)
class HostParams:
    """Host-placement path constants (Fig 9's 'Emb on CPU' bars)."""
    dram_bw: float = 250e9          # usable bytes/s per host (2S Skylake)
    chips_per_host: int = 4         # TPU v4 ratio (§3.5: amplifies Amdahl)
    dcn_bw: float = 50e9            # bytes/s per host (2x200G NICs)
    dcn_tail_factor: float = 1.3    # tail latency/striding penalty (§3.5)


@dataclass(frozen=True)
class SCParams:
    tiles: int = 16                 # compute tiles per SC (Fig 7)
    simd_lanes: int = 8             # scVPU width
    spmem_bytes: int = int(2.5 * 2**20)
    instr_overhead_s: float = 2e-6  # CISC instruction issue per table batch
    bytes_per_param: int = 4


def embedding_traffic(dlrm: DLRMConfig, batch_per_chip: float, *,
                      dedup_factor: float = 0.7,
                      bytes_per_param: int = 4) -> Dict[str, float]:
    """Per-chip, per-step traffic of the embedding stack.

    dedup_factor: fraction of lookups that remain after dedup (§3.4).
    """
    rows = 0.0
    bytes_ = 0.0
    for t in dlrm.tables:
        r = batch_per_chip * t.avg_valency * dedup_factor
        rows += r
        bytes_ += r * t.dim * bytes_per_param
    return {"rows": rows, "gather_bytes": bytes_,
            "tables": float(len(dlrm.tables))}


def num_width_groups(dlrm: DLRMConfig) -> int:
    """Distinct table widths = fused descriptor-stream launches per step."""
    return len({t.dim for t in dlrm.tables})


def sc_step_time(dlrm: DLRMConfig, global_batch: int,
                 topo: SliceTopology, hw: HardwareParams = TPU_V4, *,
                 sc: SCParams = SCParams(), dedup_factor: float = 0.7,
                 fused_issue: bool = False, pipelined: bool = True,
                 cache_hit_rate: float = 0.0) -> Dict[str, float]:
    """Embedding step time with SparseCores (seconds, per phase + total).

    ``fused_issue``: the pipelined executor's fused multi-group launch — one
    CISC instruction issue per width-group instead of per table.
    ``pipelined``: stages overlap (the slowest governs); False serialises
    Fetch/scVPU/ICI, the pre-SparseCore dataflow.
    ``cache_hit_rate``: fraction of deduplicated lookups served by the
    replicated hot-id cache, which never enter the all-to-all.
    """
    n = topo.num_chips
    bpc = global_batch / n
    tr = embedding_traffic(dlrm, bpc, dedup_factor=dedup_factor,
                           bytes_per_param=sc.bytes_per_param)
    cm = CollectiveCostModel(hw)
    # Fetch fwd + Flush bwd (read, write grad-updated rows: 3x traffic)
    hbm = 3.0 * tr["gather_bytes"] / hw.hbm_bw
    # scVPU: one MAC per element through combine + grad apply
    vpu_ops = 3.0 * tr["gather_bytes"] / sc.bytes_per_param
    vpu_rate = (hw.sparsecores_per_chip * sc.tiles * sc.simd_lanes
                * hw.clock_hz)
    vpu = vpu_ops / vpu_rate
    # model-parallel tables: ids out + vectors back, fwd and bwd (§3.4);
    # cache hits are served from the replicated hot rows, never exchanged
    a2a_bytes = (2.0 * tr["gather_bytes"] * (1.0 - 1.0 / n)
                 * (1.0 - cache_hit_rate))
    ici = cm.all_to_all(topo, a2a_bytes)
    # CISC issue streams parallelise across the chip's SparseCores; the
    # fused descriptor stream amortises one issue across a whole width-group
    issues = float(num_width_groups(dlrm)) if fused_issue else tr["tables"]
    fixed = issues * sc.instr_overhead_s * (4.0 / hw.sparsecores_per_chip)
    # dataflow pipeline: phases overlap; the slowest stage governs
    stages = (max(hbm, vpu, ici) if pipelined else hbm + vpu + ici)
    total = stages + fixed
    return {"hbm": hbm, "vpu": vpu, "ici": ici, "fixed": fixed,
            "total": total}


def cpu_step_time(dlrm: DLRMConfig, global_batch: int,
                  topo: SliceTopology, host: HostParams = HostParams(), *,
                  dedup_factor: float = 1.0, bytes_per_param: int = 4
                  ) -> Dict[str, float]:
    """Embedding step with tables in host CPU memory (no SC, no dedup HW)."""
    n = topo.num_chips
    bpc = global_batch / n
    tr = embedding_traffic(dlrm, bpc, dedup_factor=dedup_factor,
                           bytes_per_param=bytes_per_param)
    per_host_bytes = tr["gather_bytes"] * host.chips_per_host
    dram = 3.0 * per_host_bytes / host.dram_bw
    dcn = (2.0 * per_host_bytes / host.dcn_bw) * host.dcn_tail_factor
    total = max(dram, dcn)          # host pipeline overlaps DRAM and DCN
    return {"dram": dram, "dcn": dcn, "total": total}


def tc_step_time(dense_params: float, global_batch: int, n_chips: int,
                 hw: HardwareParams = TPU_V4, *,
                 efficiency: float = 0.45) -> float:
    """Dense-side (TensorCore) step: fwd+bwd = 6 FLOPs/param/sample."""
    flops = 6.0 * dense_params * (global_batch / n_chips)
    return flops / (hw.peak_flops_bf16 * efficiency)


def dlrm_step_time(cfg: ModelConfig, global_batch: int, topo: SliceTopology,
                   hw: HardwareParams = TPU_V4, *, placement: str = "sc",
                   dense_params: float = 100e6,
                   dedup_factor: float = 0.7, **sc_kwargs
                   ) -> Dict[str, float]:
    """End-to-end DLRM step: max(SparseTime, DenseTime) (Fig 10 caption)."""
    if placement == "sc":
        sparse = sc_step_time(cfg.dlrm, global_batch, topo, hw,
                              dedup_factor=dedup_factor, **sc_kwargs)["total"]
    else:
        sparse = cpu_step_time(cfg.dlrm, global_batch, topo)["total"]
    dense = tc_step_time(dense_params, global_batch, topo.num_chips, hw)
    return {"sparse": sparse, "dense": dense,
            "total": max(sparse, dense)}


# ---------------------------------------------------------------------------
# PA-NAS SC/TC load balancing (§4, Figure 10)
# ---------------------------------------------------------------------------

def pa_nas_balance(sc_time: float, tc_time: float, *,
                   quality_elasticity: Tuple[float, float] = (1.0, 1.0),
                   grid: int = 200) -> Dict[str, float]:
    """Search embedding-vs-dense capacity scaling for Pareto-optimal balance.

    Model: scaling sparse capacity by s and dense capacity by d multiplies
    the respective compute times by s and d.  Quality is held (to first
    order) by s^a * d^b >= 1 with (a, b) = quality_elasticity — shrinking one
    side must be paid for by growing the other (PA-NAS's Pareto constraint).
    Step time = max(sc*s, tc*d); returns the best (s, d) and the gain.
    """
    a, b = quality_elasticity
    base = max(sc_time, tc_time)
    best = {"s": 1.0, "d": 1.0, "step": base, "gain": 1.0}
    for i in range(1, grid + 1):
        s = 0.25 + 1.75 * i / grid
        d = s ** (-a / b)                       # quality-neutral trade
        step = max(sc_time * s, tc_time * d)
        if step < best["step"]:
            best = {"s": s, "d": d, "step": step, "gain": base / step}
    return best
