"""Collective cost model over slice topologies (paper §2.6-2.8, §7.3).

Times are analytic lower-bound estimates from link-level routing:
  * all-reduce — multi-ring over every torus dimension (the standard
    torus reduction; wraparound doubles ring bandwidth, paper §2.6),
  * all-to-all — max-link-load under ideal multipath shortest-path routing
    (topology.link_loads_alltoall), the quantity the twisted torus improves,
  * all-gather / reduce-scatter — ring over the mapped dimensions,
  * p2p — neighbour hop (pipeline parallelism).

Hardware presets: TPU v4 (the paper's machine), TPU v5e (the roofline
runtime target per the grading spec), and a projected v5p-class point for
the heterogeneous-fleet model.

This module also owns the **Figure-12 per-app roofline model** (shared with
`benchmarks/fig12_v4_vs_v3.py`) and the **generation registry**: each
`Generation` tags a `HardwareParams` preset with its fig12-path performance
factor (geomean app speedup vs TPU v3) plus power/price economics, the
scoring inputs of the multi-machine fleet placer (`repro.cluster.registry`).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from repro.core.topology import SliceTopology


@dataclass(frozen=True)
class HardwareParams:
    name: str
    peak_flops_bf16: float          # per chip
    hbm_bw: float                   # bytes/s per chip
    hbm_gib: float                  # per chip
    link_bw: float                  # bytes/s per direction per ICI link
    links_per_chip: int
    clock_hz: float
    sparsecores_per_chip: int = 4
    vmem_bytes: int = 2 * 16 * 2**20
    cmem_bytes: int = 128 * 2**20


TPU_V4 = HardwareParams(
    name="tpu_v4", peak_flops_bf16=275e12, hbm_bw=1200e9, hbm_gib=32,
    link_bw=50e9, links_per_chip=6, clock_hz=1.05e9, sparsecores_per_chip=4)

TPU_V3 = HardwareParams(
    name="tpu_v3", peak_flops_bf16=123e12, hbm_bw=900e9, hbm_gib=32,
    link_bw=70e9, links_per_chip=4, clock_hz=0.94e9, sparsecores_per_chip=2,
    cmem_bytes=0)

# Grading-spec constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
TPU_V5E = HardwareParams(
    name="tpu_v5e", peak_flops_bf16=197e12, hbm_bw=819e9, hbm_gib=16,
    link_bw=50e9, links_per_chip=4, clock_hz=1.0e9, sparsecores_per_chip=4,
    cmem_bytes=0)

# Projected v5p-class point for the heterogeneous fleet (public v5p specs:
# 459 TFLOP/s bf16, 2765 GB/s HBM, 95 GiB/chip; CMEM dropped in favor of
# raw HBM bandwidth, so RNN1's CMEM outlier does not recur).
TPU_V5P = HardwareParams(
    name="tpu_v5p", peak_flops_bf16=459e12, hbm_bw=2765e9, hbm_gib=95,
    link_bw=100e9, links_per_chip=6, clock_hz=1.75e9,
    sparsecores_per_chip=4, cmem_bytes=0)


# ---------------------------------------------------------------------------
# Figure-12 per-app roofline model (shared with benchmarks/fig12_v4_vs_v3.py)
# ---------------------------------------------------------------------------

CMEM_BW_MULT = 3.0          # CMEM vs HBM effective bandwidth

# (name, operational intensity flops/byte, CMEM-resident fraction) for the
# paper's six production-app classes; RNN1's small weights/batch are
# CMEM-resident, producing the 3.3x outlier of Fig 12.
FIG12_APPS: Tuple[Tuple[str, float, float], ...] = (
    ("CNN0", 250.0, 0.1),
    ("CNN1", 150.0, 0.1),
    ("BERT0", 120.0, 0.15),
    ("BERT1", 100.0, 0.15),
    ("RNN0", 20.0, 0.3),
    ("RNN1", 12.0, 0.85),
)


def app_time_per_flop(hw: HardwareParams, oi: float, cmem_frac: float = 0.0,
                      *, cmem: bool = False) -> float:
    """Roofline seconds/flop for an app of operational intensity ``oi``:
    ``max(1/peak, 1/(oi * bw_eff))``, where CMEM (when present and enabled)
    raises the effective bandwidth for the ``cmem_frac`` of the working set
    it holds."""
    bw = hw.hbm_bw
    if cmem and hw.cmem_bytes > 0:
        bw = bw * (1.0 - cmem_frac) + bw * CMEM_BW_MULT * cmem_frac
    return max(1.0 / hw.peak_flops_bf16, 1.0 / (oi * bw))


def generation_speedup(hw: HardwareParams,
                       baseline: HardwareParams = TPU_V3) -> float:
    """Geomean speedup of ``hw`` over ``baseline`` across the Fig-12
    production-app mix (CMEM credited on whichever side has it).  This IS
    the measurement path of `benchmarks/fig12_v4_vs_v3.py`; the pinned
    `Generation.perf_factor` literals must round-trip through it (enforced
    by tests/test_hetfleet.py)."""
    logs = []
    for _name, oi, cf in FIG12_APPS:
        tb = app_time_per_flop(baseline, oi, cf, cmem=True)
        th = app_time_per_flop(hw, oi, cf, cmem=True)
        logs.append(math.log(tb / th))
    return math.exp(sum(logs) / len(logs))


# ---------------------------------------------------------------------------
# Generation registry: perf + economics per machine generation
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Generation:
    """One machine generation: hardware preset + fleet economics.

    ``perf_factor`` is the fig12-path geomean app speedup vs TPU v3
    (`generation_speedup`), pinned as a literal so drift in the shared
    roofline model is caught by the regression test.  ``watts_per_chip``
    follows the paper's §8 measured-power discussion (v4 at ~2.7x the
    perf/Watt of v3); ``dollars_per_chip_hour`` is a relative price point —
    old generations are cheap, which is exactly why batch/training work
    drains there while latency-SLO serving pays for fast silicon."""
    name: str
    hw: HardwareParams
    perf_factor: float              # fig12 geomean app speedup vs TPU_V3
    watts_per_chip: float
    dollars_per_chip_hour: float

    @property
    def perf_per_watt(self) -> float:
        """Relative app throughput per Watt (v3 = 1/283)."""
        return self.perf_factor / self.watts_per_chip

    @property
    def perf_per_dollar(self) -> float:
        """Relative app throughput per $/chip-hour — the training/batch
        placement score (old cheap silicon wins)."""
        return self.perf_factor / self.dollars_per_chip_hour

    def perf_per_watt_vs(self, other: "Generation") -> float:
        """Perf/Watt ratio vs another generation (v4 vs v3 ≈ 2.7x, §8)."""
        return self.perf_per_watt / other.perf_per_watt


# perf_factor literals are the measured generation_speedup() values (4dp);
# tests/test_hetfleet.py fails if either side drifts.
GEN_V3 = Generation("tpu_v3", TPU_V3, perf_factor=1.0,
                    watts_per_chip=283.0, dollars_per_chip_hour=0.55)
GEN_V4 = Generation("tpu_v4", TPU_V4, perf_factor=2.1193,
                    watts_per_chip=220.0, dollars_per_chip_hour=1.20)
GEN_V5P = Generation("tpu_v5p", TPU_V5P, perf_factor=3.2230,
                     watts_per_chip=350.0, dollars_per_chip_hour=2.20)

GENERATIONS: Dict[str, Generation] = {
    g.name: g for g in (GEN_V3, GEN_V4, GEN_V5P)}


@functools.lru_cache(maxsize=256)
def _a2a_max_load(dims: Tuple[int, int, int], twisted: bool,
                  wraparound: bool) -> float:
    topo = SliceTopology(dims, twisted=twisted, wraparound=wraparound)
    return topo.alltoall_max_load()


@functools.lru_cache(maxsize=256)
def _bisection(dims: Tuple[int, int, int], twisted: bool,
               wraparound: bool) -> int:
    topo = SliceTopology(dims, twisted=twisted, wraparound=wraparound)
    return topo.bisection_links()


class CollectiveCostModel:
    def __init__(self, hw: HardwareParams = TPU_V4):
        self.hw = hw

    # -- ring helpers ---------------------------------------------------------

    def _rings(self, topo: SliceTopology,
               dims_subset: Optional[Sequence[int]] = None) -> int:
        """Concurrent directed rings available over the given torus dims."""
        rings = 0
        for ax in range(3):
            size = topo.dims[ax]
            if dims_subset is not None and ax not in dims_subset:
                continue
            if size < 2:
                continue
            rings += 2 if (topo.wraparound and size > 2) else 1
        return max(rings, 1)

    def _group_size(self, topo: SliceTopology,
                    dims_subset: Optional[Sequence[int]]) -> int:
        if dims_subset is None:
            return topo.num_chips
        n = 1
        for ax in dims_subset:
            n *= topo.dims[ax]
        return n

    # -- collectives ----------------------------------------------------------

    def all_reduce(self, topo: SliceTopology, bytes_per_chip: float,
                   dims_subset: Optional[Sequence[int]] = None) -> float:
        """Ring all-reduce of `bytes_per_chip` over the mapped dims."""
        n = self._group_size(topo, dims_subset)
        if n <= 1:
            return 0.0
        rings = self._rings(topo, dims_subset)
        return 2.0 * bytes_per_chip * (n - 1) / n / (rings * self.hw.link_bw)

    def all_gather(self, topo: SliceTopology, bytes_per_chip_out: float,
                   dims_subset: Optional[Sequence[int]] = None) -> float:
        n = self._group_size(topo, dims_subset)
        if n <= 1:
            return 0.0
        rings = self._rings(topo, dims_subset)
        return bytes_per_chip_out * (n - 1) / n / (rings * self.hw.link_bw)

    reduce_scatter = all_gather

    def all_to_all(self, topo: SliceTopology,
                   bytes_per_chip: float) -> float:
        """Uniform all-to-all where each chip exchanges `bytes_per_chip`
        total with the N-1 others (the SparseCore / MoE pattern)."""
        n = topo.num_chips
        if n <= 1:
            return 0.0
        per_pair = bytes_per_chip / (n - 1)
        max_load = _a2a_max_load(topo.dims, topo.twisted, topo.wraparound)
        return max_load * per_pair / self.hw.link_bw

    def all_to_all_bisection_bound(self, topo: SliceTopology,
                                   bytes_per_chip: float) -> float:
        """Sanity bound: half the traffic crosses the bisection."""
        n = topo.num_chips
        cut = _bisection(topo.dims, topo.twisted, topo.wraparound)
        if cut == 0:
            return 0.0
        total = bytes_per_chip * n
        return (total / 2.0) / (2 * cut * self.hw.link_bw)

    def p2p(self, bytes_: float, hops: int = 1) -> float:
        return hops * bytes_ / self.hw.link_bw

    # -- reconfiguration --------------------------------------------------------

    def reconfig_time(self, circuits_moved: int,
                      arrays: Optional[int] = None) -> float:
        """Seconds of slice blackout to re-program ``circuits_moved`` OCS
        circuits (spare swap, straggler swap, re-twist): the ACOS-style
        per-switch-array model — arrays reconfigure in parallel, each
        serializes its own circuit programming, plus one MEMS settle.
        This is the price a repair decision trades against steady-state
        gain (a straggler swap only pays off if the recovered step time
        amortizes the blackout)."""
        from repro.core.ocs import NUM_OCS, reconfig_time
        return reconfig_time(circuits_moved,
                             NUM_OCS if arrays is None else arrays)

    # -- compute / memory -------------------------------------------------------

    def compute_time(self, flops_per_chip: float,
                     efficiency: float = 1.0) -> float:
        return flops_per_chip / (self.hw.peak_flops_bf16 * efficiency)

    def hbm_time(self, bytes_per_chip: float) -> float:
        return bytes_per_chip / self.hw.hbm_bw
