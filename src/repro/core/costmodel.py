"""Collective cost model over slice topologies (paper §2.6-2.8, §7.3).

Times are analytic lower-bound estimates from link-level routing:
  * all-reduce — multi-ring over every torus dimension (the standard
    torus reduction; wraparound doubles ring bandwidth, paper §2.6),
  * all-to-all — max-link-load under ideal multipath shortest-path routing
    (topology.link_loads_alltoall), the quantity the twisted torus improves,
  * all-gather / reduce-scatter — ring over the mapped dimensions,
  * p2p — neighbour hop (pipeline parallelism).

Hardware presets: TPU v4 (the paper's machine) and TPU v5e (the roofline
runtime target per the grading spec).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from repro.core.topology import SliceTopology


@dataclass(frozen=True)
class HardwareParams:
    name: str
    peak_flops_bf16: float          # per chip
    hbm_bw: float                   # bytes/s per chip
    hbm_gib: float                  # per chip
    link_bw: float                  # bytes/s per direction per ICI link
    links_per_chip: int
    clock_hz: float
    sparsecores_per_chip: int = 4
    vmem_bytes: int = 2 * 16 * 2**20
    cmem_bytes: int = 128 * 2**20


TPU_V4 = HardwareParams(
    name="tpu_v4", peak_flops_bf16=275e12, hbm_bw=1200e9, hbm_gib=32,
    link_bw=50e9, links_per_chip=6, clock_hz=1.05e9, sparsecores_per_chip=4)

TPU_V3 = HardwareParams(
    name="tpu_v3", peak_flops_bf16=123e12, hbm_bw=900e9, hbm_gib=32,
    link_bw=70e9, links_per_chip=4, clock_hz=0.94e9, sparsecores_per_chip=2,
    cmem_bytes=0)

# Grading-spec constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
TPU_V5E = HardwareParams(
    name="tpu_v5e", peak_flops_bf16=197e12, hbm_bw=819e9, hbm_gib=16,
    link_bw=50e9, links_per_chip=4, clock_hz=1.0e9, sparsecores_per_chip=4,
    cmem_bytes=0)


@functools.lru_cache(maxsize=256)
def _a2a_max_load(dims: Tuple[int, int, int], twisted: bool,
                  wraparound: bool) -> float:
    topo = SliceTopology(dims, twisted=twisted, wraparound=wraparound)
    return topo.alltoall_max_load()


@functools.lru_cache(maxsize=256)
def _bisection(dims: Tuple[int, int, int], twisted: bool,
               wraparound: bool) -> int:
    topo = SliceTopology(dims, twisted=twisted, wraparound=wraparound)
    return topo.bisection_links()


class CollectiveCostModel:
    def __init__(self, hw: HardwareParams = TPU_V4):
        self.hw = hw

    # -- ring helpers ---------------------------------------------------------

    def _rings(self, topo: SliceTopology,
               dims_subset: Optional[Sequence[int]] = None) -> int:
        """Concurrent directed rings available over the given torus dims."""
        rings = 0
        for ax in range(3):
            size = topo.dims[ax]
            if dims_subset is not None and ax not in dims_subset:
                continue
            if size < 2:
                continue
            rings += 2 if (topo.wraparound and size > 2) else 1
        return max(rings, 1)

    def _group_size(self, topo: SliceTopology,
                    dims_subset: Optional[Sequence[int]]) -> int:
        if dims_subset is None:
            return topo.num_chips
        n = 1
        for ax in dims_subset:
            n *= topo.dims[ax]
        return n

    # -- collectives ----------------------------------------------------------

    def all_reduce(self, topo: SliceTopology, bytes_per_chip: float,
                   dims_subset: Optional[Sequence[int]] = None) -> float:
        """Ring all-reduce of `bytes_per_chip` over the mapped dims."""
        n = self._group_size(topo, dims_subset)
        if n <= 1:
            return 0.0
        rings = self._rings(topo, dims_subset)
        return 2.0 * bytes_per_chip * (n - 1) / n / (rings * self.hw.link_bw)

    def all_gather(self, topo: SliceTopology, bytes_per_chip_out: float,
                   dims_subset: Optional[Sequence[int]] = None) -> float:
        n = self._group_size(topo, dims_subset)
        if n <= 1:
            return 0.0
        rings = self._rings(topo, dims_subset)
        return bytes_per_chip_out * (n - 1) / n / (rings * self.hw.link_bw)

    reduce_scatter = all_gather

    def all_to_all(self, topo: SliceTopology,
                   bytes_per_chip: float) -> float:
        """Uniform all-to-all where each chip exchanges `bytes_per_chip`
        total with the N-1 others (the SparseCore / MoE pattern)."""
        n = topo.num_chips
        if n <= 1:
            return 0.0
        per_pair = bytes_per_chip / (n - 1)
        max_load = _a2a_max_load(topo.dims, topo.twisted, topo.wraparound)
        return max_load * per_pair / self.hw.link_bw

    def all_to_all_bisection_bound(self, topo: SliceTopology,
                                   bytes_per_chip: float) -> float:
        """Sanity bound: half the traffic crosses the bisection."""
        n = topo.num_chips
        cut = _bisection(topo.dims, topo.twisted, topo.wraparound)
        if cut == 0:
            return 0.0
        total = bytes_per_chip * n
        return (total / 2.0) / (2 * cut * self.hw.link_bw)

    def p2p(self, bytes_: float, hops: int = 1) -> float:
        return hops * bytes_ / self.hw.link_bw

    # -- reconfiguration --------------------------------------------------------

    def reconfig_time(self, circuits_moved: int,
                      arrays: Optional[int] = None) -> float:
        """Seconds of slice blackout to re-program ``circuits_moved`` OCS
        circuits (spare swap, straggler swap, re-twist): the ACOS-style
        per-switch-array model — arrays reconfigure in parallel, each
        serializes its own circuit programming, plus one MEMS settle.
        This is the price a repair decision trades against steady-state
        gain (a straggler swap only pays off if the recovered step time
        amortizes the blackout)."""
        from repro.core.ocs import NUM_OCS, reconfig_time
        return reconfig_time(circuits_moved,
                             NUM_OCS if arrays is None else arrays)

    # -- compute / memory -------------------------------------------------------

    def compute_time(self, flops_per_chip: float,
                     efficiency: float = 1.0) -> float:
        return flops_per_chip / (self.hw.peak_flops_bf16 * efficiency)

    def hbm_time(self, bytes_per_chip: float) -> float:
        return bytes_per_chip / self.hw.hbm_bw
