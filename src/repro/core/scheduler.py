"""Slice scheduler over OCS-connected 4³ blocks (paper §2.3, §2.5).

"For TPU v4, [the scheduler] can pick four 4³ blocks from anywhere in the
supercomputer.  Slices don't even need to be a power of 2."

Responsibilities:
  * allocate/free slices of any 4i×4j×4k geometry from ANY healthy free
    blocks (OCS mode) or from contiguous regions (static mode, for the Fig 4
    comparison),
  * block-failure handling: swap in a spare and reprogram circuits (§2.3),
  * straggler mitigation: the same swap mechanism replaces a slow block —
    an OCS capability (ms switch time) that static cabling cannot offer,
  * priorities + preemption support: every job carries a priority, and
    `preemption_victims` picks the cheapest set of lower-priority jobs whose
    blocks would let a higher-priority request fit — the mechanism behind
    "a serving burst evicts background training" (§2.3's availability story
    turned into scheduling policy).  The scheduler only *selects* victims;
    actually stopping them is cooperative and lives in the cluster layer
    (checkpoint, free, re-queue).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.ocs import BlockSliceConfig, OCSFabric
from repro.core.topology import SliceTopology, is_twistable

MACHINE_BLOCK_DIMS = (4, 4, 4)


def _shrink_reconfig_time(circuits_moved: int) -> float:
    """Blackout of reprogramming a shrunk slice's circuits (ACOS model)."""
    from repro.core.ocs import reconfig_time
    return reconfig_time(circuits_moved)


@dataclass
class Job:
    """One placed slice: its chip geometry, owned blocks, OCS circuit
    configuration, and scheduling priority (higher preempts lower)."""
    job_id: int
    dims_chips: Tuple[int, int, int]
    twisted: bool
    blocks: List[int]
    config: BlockSliceConfig
    priority: int = 0

    @property
    def topology(self) -> SliceTopology:
        """Link-level topology for the job's geometry/twist."""
        return SliceTopology(self.dims_chips, twisted=self.twisted)


class SliceScheduler:
    """Block-level slice scheduler over one OCS machine.

    Args:
      num_blocks: machine size in 4^3-chip blocks.
      contiguous: static-cabling mode — slices must be contiguous regions
        and failures cannot be patched with spares (the Fig-4 baseline).
    """

    def __init__(self, num_blocks: int = 64, *, contiguous: bool = False):
        self.fabric = OCSFabric(num_blocks)
        self.num_blocks = num_blocks
        self.contiguous = contiguous       # static-cabling mode (no OCS)
        self.healthy: Set[int] = set(range(num_blocks))
        self.free: Set[int] = set(range(num_blocks))
        self.jobs: Dict[int, Job] = {}
        self.events: List[str] = []
        # block -> step-time multiplier (>= 1.0; absent = nominal).  A slow
        # block is healthy — it answers, it just drags every synchronous
        # step (§2.3's "stragglers" as distinct from failures) — so it
        # stays allocatable, but spare selection avoids it.
        self.slowdown: Dict[int, float] = {}
        self._next = 0

    # -- allocation -----------------------------------------------------------

    def allocate(self, dims_chips: Tuple[int, int, int], *,
                 twisted: bool = False, priority: int = 0) -> Optional[Job]:
        """Place a slice of ``dims_chips`` (each dim a multiple of 4) from
        any healthy free blocks.  Returns the `Job` or None if it cannot be
        placed at current capacity (see `preemption_victims` for what could
        be evicted to make room)."""
        a, b, c = dims_chips
        assert a % 4 == b % 4 == c % 4 == 0, "slices are built from 4^3 blocks"
        if twisted and not is_twistable(dims_chips):
            raise ValueError(f"{dims_chips} not twistable")
        dims_blocks = (a // 4, b // 4, c // 4)
        need = dims_blocks[0] * dims_blocks[1] * dims_blocks[2]
        avail = self.free & self.healthy
        if self.contiguous:
            blocks = self._find_contiguous(dims_blocks, avail)
        else:
            blocks = sorted(avail)[:need] if len(avail) >= need else None
        if blocks is None or len(blocks) < need:
            return None
        cfg = self.fabric.configure_slice(blocks, dims_blocks,
                                          twisted=twisted)
        job = Job(self._next, dims_chips, twisted, list(blocks), cfg,
                  priority=priority)
        self._next += 1
        self.free -= set(blocks)
        self.jobs[job.job_id] = job
        self.events.append(f"alloc job{job.job_id} {dims_chips} "
                           f"blocks={blocks} prio={priority}")
        return job

    def blocks_needed(self, dims_chips: Tuple[int, int, int]) -> int:
        """Block count of a chip geometry (each dim a multiple of 4)."""
        a, b, c = dims_chips
        return (a // 4) * (b // 4) * (c // 4)

    def preemption_victims(self, dims_chips: Tuple[int, int, int],
                           priority: int) -> Optional[List[Job]]:
        """Cheapest set of strictly-lower-priority jobs whose release would
        let a ``priority`` request for ``dims_chips`` fit.

        Victims are chosen lowest-priority-first, then fewest-blocks-first
        (evict as little work as possible), newest-first on ties.  Returns
        None when even evicting every lower-priority job would not free
        enough healthy blocks (OCS mode only — contiguous/static machines
        cannot re-carve around tenants, so preemption is not offered)."""
        if self.contiguous:
            return None
        need = self.blocks_needed(dims_chips)
        have = len(self.free & self.healthy)
        if have >= need:
            return []
        cands = sorted((j for j in self.jobs.values()
                        if j.priority < priority),
                       key=lambda j: (j.priority, len(j.blocks), -j.job_id))
        victims: List[Job] = []
        for j in cands:
            if have >= need:
                break
            victims.append(j)
            have += sum(1 for b in j.blocks if b in self.healthy)
        return victims if have >= need else None

    def _find_contiguous(self, dims_blocks, avail) -> Optional[List[int]]:
        A, B, C = MACHINE_BLOCK_DIMS

        def bid(x, y, z):
            return (x * B + y) * C + z

        for orient in set(itertools.permutations(dims_blocks)):
            ga, gb, gc = orient
            for ox, oy, oz in itertools.product(range(A), range(B), range(C)):
                ids = [bid((ox + dx) % A, (oy + dy) % B, (oz + dz) % C)
                       for dx in range(ga) for dy in range(gb)
                       for dz in range(gc)]
                if all(i in avail for i in ids):
                    return ids
        return None

    def release(self, job_id: int) -> None:
        """Free a job's blocks and OCS circuits back to the machine."""
        job = self.jobs.pop(job_id)
        self.fabric.release(job.config)
        self.free |= set(job.blocks)
        self.events.append(f"release job{job_id}")

    def shrink(self, job_id: int,
               new_dims: Tuple[int, int, int]) -> Tuple[List[int], int, float]:
        """Re-carve a job IN PLACE to the strictly-smaller ``new_dims``,
        handing the surplus blocks back to the free pool (§2.5 partial
        shrink: the tenant keeps running on fewer blocks instead of being
        fully evicted).  The job keeps its ``need`` fastest owned blocks
        (lowest slowdown, lowest id on ties) and the OCS circuits are
        reprogrammed to the smaller torus — one reconfiguration blackout,
        not a release + re-allocate.

        Returns ``(released_blocks, circuits_moved, switch_seconds)``.
        OCS mode only: a static-cabled machine cannot re-carve a contiguous
        region around a live tenant."""
        if self.contiguous:
            raise ValueError("shrink requires OCS wiring (contiguous mode "
                             "cannot re-carve around a live job)")
        job = self.jobs[job_id]
        a, b, c = new_dims
        assert a % 4 == b % 4 == c % 4 == 0, "slices are built from 4^3 blocks"
        need = self.blocks_needed(new_dims)
        assert 0 < need < len(job.blocks), \
            f"shrink must strictly reduce: {need} vs {len(job.blocks)} blocks"
        keep = sorted(job.blocks,
                      key=lambda blk: (self.slowdown_of(blk), blk))[:need]
        keep_set = set(keep)
        released = [blk for blk in job.blocks if blk not in keep_set]
        # a twist that the smaller geometry cannot express is dropped
        twisted = job.twisted and is_twistable(new_dims)
        self.fabric.release(job.config)
        dims_blocks = (a // 4, b // 4, c // 4)
        cfg = self.fabric.configure_slice(keep, dims_blocks, twisted=twisted)
        job.blocks = list(keep)
        job.dims_chips = (a, b, c)
        job.twisted = twisted
        job.config = cfg
        self.free |= set(released)
        moved = len(cfg.circuits)
        secs = _shrink_reconfig_time(moved)
        self.events.append(
            f"shrink job{job_id} -> {new_dims} released={released} "
            f"({moved} circuits, {secs * 1e3:.0f}ms)")
        return released, moved, secs

    # -- failures / stragglers ----------------------------------------------------

    def set_slowdown(self, block: int, factor: float) -> None:
        """Mark ``block`` as running ``factor``x slower than nominal (1.0
        clears the mark).  Pure telemetry state: sessions model their
        synchronous step time off it, the detector reads it back, and
        spare selection prefers fast blocks."""
        assert factor > 0.0, factor
        if factor <= 1.0:
            self.slowdown.pop(block, None)
        else:
            self.slowdown[block] = float(factor)
        self.events.append(f"slowdown block{block} x{factor:g}")

    def slowdown_of(self, block: int) -> float:
        """Current step-time multiplier of ``block`` (1.0 = nominal)."""
        return self.slowdown.get(block, 1.0)

    def _best_spare(self) -> Optional[int]:
        """Fastest healthy free block (ties to the lowest id — keeps the
        no-slowdown behavior identical to the historical sorted()[0])."""
        spares = self.free & self.healthy
        if not spares:
            return None
        return min(spares, key=lambda b: (self.slowdown_of(b), b))

    def fail_block(self, block: int) -> Optional[Tuple[int, int, float]]:
        """Mark a block failed.  If a job owned it, swap in a spare.

        Returns (job_id, circuits_moved, switch_seconds) or None.
        """
        self.healthy.discard(block)
        self.free.discard(block)
        owner = next((j for j in self.jobs.values() if block in j.blocks),
                     None)
        if owner is None:
            self.events.append(f"fail block{block} (idle)")
            return None
        if self.contiguous:
            # static cabling: the whole job dies and must wait for repair
            self.events.append(f"fail block{block}: job{owner.job_id} DOWN")
            self.release(owner.job_id)
            return (owner.job_id, 0, float("inf"))
        spare = self._best_spare()
        if spare is None:
            self.events.append(f"fail block{block}: no spares, "
                               f"job{owner.job_id} DOWN")
            self.release(owner.job_id)
            return (owner.job_id, 0, float("inf"))
        self.free.discard(spare)
        moved, secs = self.fabric.reconfigure_around_failure(
            owner.config, block, spare)
        owner.blocks[owner.blocks.index(block)] = spare
        self.events.append(
            f"fail block{block}: job{owner.job_id} re-routed to block{spare} "
            f"({moved} circuits, {secs * 1e3:.0f}ms)")
        return (owner.job_id, moved, secs)

    def repair_block(self, block: int) -> None:
        """Mark a failed block healthy again (free unless still mapped)."""
        self.healthy.add(block)
        if not any(block in j.blocks for j in self.jobs.values()):
            self.free.add(block)

    def swap_straggler(self, job_id: int, slow_block: int
                       ) -> Optional[Tuple[int, float]]:
        """Straggler mitigation: replace a slow (but healthy) block with
        the FASTEST spare.  Refuses (None) when no spare exists or every
        spare is at least as slow as the block being evicted — swapping
        sideways would pay the reconfiguration blackout for nothing."""
        job = self.jobs[job_id]
        spare = self._best_spare()
        if spare is None:
            self.events.append(
                f"straggler: job{job_id} block{slow_block} kept (no spare)")
            return None
        if (self.slowdown_of(slow_block) > 1.0
                and self.slowdown_of(spare) >= self.slowdown_of(slow_block)):
            self.events.append(
                f"straggler: job{job_id} block{slow_block} kept "
                f"(no faster spare)")
            return None
        self.free.discard(spare)
        moved, secs = self.fabric.reconfigure_around_failure(
            job.config, slow_block, spare)
        job.blocks[job.blocks.index(slow_block)] = spare
        self.free.add(slow_block)
        self.events.append(
            f"straggler: job{job_id} block{slow_block}->{spare}")
        return (moved, secs)

    # -- metrics ----------------------------------------------------------------

    def utilization(self) -> float:
        """Fraction of blocks owned by live jobs."""
        used = sum(len(j.blocks) for j in self.jobs.values())
        return used / self.num_blocks
