"""Goodput vs CPU-host availability, with and without OCS (paper Figure 4).

Machine model: 4096 chips = 64 blocks (4×4×4 block grid); each block has 16
CPU hosts (4 chips/host); a block is schedulable only if all 16 hosts are up.

  * With OCS: a slice of k blocks can use ANY k healthy blocks — goodput is
    floor(healthy / k) * k / 64 in expectation (matches the Fig 4 caption
    arithmetic: at 99.0%-99.5% availability a 3K-chip slice gets 75%).
  * Without OCS (static cabling): slices must be CONTIGUOUS axis-aligned
    sub-grids of the fixed 4×4×4 block torus with every block healthy —
    availability must reach 99.9% before large slices schedule at all.

Alongside this *scheduled* goodput, `served_goodput` answers the fleet
question (repro.fleet): what fraction of offered serving traffic gets
delivered when each schedulable slice hosts a replica and failures re-route
load onto the survivors' headroom.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

HOSTS_PER_BLOCK = 16
MACHINE_BLOCK_DIMS = (4, 4, 4)      # 64 blocks = 4096 chips
NUM_BLOCKS = 64


def block_alive_prob(host_availability: float) -> float:
    return host_availability ** HOSTS_PER_BLOCK


def _block_geometry(slice_blocks: int) -> Tuple[int, int, int]:
    """Most compact block-grid geometry that fits the machine."""
    best = None
    for a in range(1, 5):
        for b in range(a, 5):
            for c in range(b, 5):
                if a * b * c == slice_blocks:
                    cand = (a, b, c)
                    if best is None or sum(cand) < sum(best):
                        best = cand
    if best is None:
        raise ValueError(f"no contiguous geometry for {slice_blocks} blocks")
    return best


def _usable_fractions_ocs(slice_chips: int, host_availability: float, *,
                          trials: int, seed: int) -> np.ndarray:
    """Per-trial machine fraction schedulable as k-block slices (OCS)."""
    k = max(1, slice_chips // 64)
    p = block_alive_prob(host_availability)
    rng = np.random.default_rng(seed)
    healthy = rng.binomial(NUM_BLOCKS, p, size=trials)
    return (healthy // k) * k / NUM_BLOCKS


def _usable_fractions_static(slice_chips: int, host_availability: float, *,
                             trials: int, seed: int) -> np.ndarray:
    """Per-trial schedulable fraction under static cabling: slices must be
    contiguous axis-aligned healthy sub-grids (greedy packing, wrapping)."""
    k = max(1, slice_chips // 64)
    geom = _block_geometry(k)
    p = block_alive_prob(host_availability)
    rng = np.random.default_rng(seed)
    A, B, C = MACHINE_BLOCK_DIMS
    positions = list(itertools.product(range(A), range(B), range(C)))
    orients = set(itertools.permutations(geom))
    out = np.zeros(trials)
    for i in range(trials):
        alive = rng.random((A, B, C)) < p
        free = alive.copy()
        placed = 0
        for (ox, oy, oz) in positions:
            done = False
            for (ga, gb, gc) in orients:
                coords = [((ox + dx) % A, (oy + dy) % B, (oz + dz) % C)
                          for dx in range(ga) for dy in range(gb)
                          for dz in range(gc)]
                if all(free[c] for c in coords):
                    for c in coords:
                        free[c] = False
                    placed += 1
                    done = True
                    break
            if done and (placed + 1) * k > NUM_BLOCKS:
                break
        out[i] = placed * k / NUM_BLOCKS
    return out


def goodput_ocs(slice_chips: int, host_availability: float, *,
                trials: int = 2000, seed: int = 0) -> float:
    """Expected fraction of the machine doing useful work (OCS-connected)."""
    return float(_usable_fractions_ocs(
        slice_chips, host_availability, trials=trials, seed=seed).mean())


def goodput_static(slice_chips: int, host_availability: float, *,
                   trials: int = 2000, seed: int = 0) -> float:
    """Expected machine fraction when slices need contiguous healthy
    sub-grids of the fixed torus (greedy packing, axis-aligned, wrapping)."""
    return float(_usable_fractions_static(
        slice_chips, host_availability, trials=trials, seed=seed).mean())


def served_goodput(slice_chips: int, host_availability: float,
                   demand_fraction: float, *, mode: str = "ocs",
                   trials: int = 2000, seed: int = 0) -> float:
    """Fleet-level SERVED goodput: the expected fraction of *offered traffic*
    a serving fleet delivers, when every schedulable k-block slice hosts one
    replica and demand equals ``demand_fraction`` of the full machine's
    serving capacity.

    Scheduled goodput (`goodput_ocs`/`goodput_static`) asks "how much of the
    machine can do useful work"; served goodput asks the fleet question —
    "how much of what users ask for gets served".  They differ because
    demand below capacity hides failures (a lost replica's traffic re-routes
    to survivors with headroom, per §2.3 swap-a-spare + the fleet's
    failure-driven re-routing) until the healthy fleet saturates:

        served_i = min(usable_i, demand) / demand        per trial i

    At demand 1.0 this degenerates to scheduled goodput; at low demand the
    OCS fleet serves 100% through substantial block loss while static
    cabling starts shedding as soon as contiguity breaks."""
    assert 0.0 < demand_fraction <= 1.0, demand_fraction
    frac = {"ocs": _usable_fractions_ocs,
            "static": _usable_fractions_static}[mode]
    usable = frac(slice_chips, host_availability, trials=trials, seed=seed)
    return float(np.minimum(usable, demand_fraction).mean()
                 / demand_fraction)


def goodput_curve(availabilities: Sequence[float],
                  slice_sizes: Sequence[int], *,
                  trials: int = 1000) -> Dict[str, List[float]]:
    """Data for the Fig 4 plot: goodput per (availability, slice, ocs?)."""
    out: Dict[str, List[float]] = {"slice_chips": list(slice_sizes)}
    for av in availabilities:
        out[f"ocs_{av}"] = [goodput_ocs(s, av, trials=trials)
                            for s in slice_sizes]
        out[f"static_{av}"] = [goodput_static(s, av, trials=max(trials // 4, 100))
                               for s in slice_sizes]
    return out
