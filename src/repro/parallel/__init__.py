"""`repro.parallel` — sharding specs, parallel contexts, overlap schedules."""
from repro.parallel.context import (LOCAL, ParallelContext, activate,
                                    active_ctx, hint, shard_map)
from repro.parallel.overlap import (overlapped_matmul_ag,
                                    overlapped_matmul_rs, software_pipeline)
from repro.parallel.pipeline import bubble_fraction, pipeline_apply
from repro.parallel.sharding import (batch_specs_sharding,
                                     cache_specs_sharding, make_context,
                                     param_specs)

__all__ = [
    "LOCAL", "ParallelContext", "activate", "active_ctx",
    "batch_specs_sharding", "bubble_fraction", "cache_specs_sharding",
    "hint", "make_context", "overlapped_matmul_ag", "overlapped_matmul_rs",
    "param_specs", "pipeline_apply", "shard_map", "software_pipeline",
]
