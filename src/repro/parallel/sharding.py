"""Sharding rules: PartitionSpec pytrees for params, batches and caches.

Path-name rules with shape-aware divisibility fallbacks, so a single rule set
covers every assigned architecture on the fixed production mesh:

  * TP (model axis): attention heads / FFN hidden / experts / vocab — falling
    back to row-parallel (input-dim) sharding when a head count doesn't divide
    the axis (qwen2's 28 heads, hymba's 25, any GQA kv < 16);
  * FSDP (data (+pod) axes): one dimension of every weight (ZeRO-3 storage);
  * batch: (pod, data) axes; decode KV caches shard heads when divisible,
    otherwise the sequence axis.

Each leaf gets an ordered list of candidate specs; the first one whose named
axes all divide the corresponding dimensions wins, else it replicates.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.parallel.context import ParallelContext

P = jax.sharding.PartitionSpec


def _divides(shape: Tuple[int, ...], spec: P, mesh) -> bool:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= axis_sizes.get(a, 1)
        if dim % total != 0:
            return False
    return True


def _pick(shape: Tuple[int, ...], candidates: List[P], mesh) -> P:
    for spec in candidates:
        if len(spec) > len(shape):
            continue
        if _divides(shape, spec, mesh):
            return spec
    return P()


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, params_shape, ctx: ParallelContext):
    """PartitionSpec pytree matching the (eval_shape'd) params pytree."""
    mesh = ctx.mesh
    fs = tuple(ctx.fsdp_axes) or None
    md = ctx.model_axis if ctx.model_axis_size > 1 else None

    def rules(path: str, shape: Tuple[int, ...]) -> List[P]:
        stacked = len(shape) >= 1 and "layers/" in path
        L = (None,) if stacked else ()
        # ---------------- embeddings / head
        if path.endswith("embed") or re.search(r"tables/", path):
            return [P(md, fs), P(md, None), P(None, fs), P()]
        if path.endswith("head"):
            return [P(fs, md), P(None, md), P(fs, None), P()]
        if "vision_proj" in path:
            return [P(None, fs), P()]
        # ---------------- attention
        if re.search(r"(attn|xattn)/wq$", path):
            return [P(*L, fs, md, None), P(*L, md, None, None),
                    P(*L, fs, None, None), P()]
        if re.search(r"(attn|xattn)/w[kv]$", path):
            return [P(*L, fs, md, None), P(*L, md, None, None),
                    P(*L, fs, None, None), P()]
        if re.search(r"(attn|xattn)/wo$", path):
            return [P(*L, md, fs), P(*L, None, fs), P(*L, md, None), P()]
        if re.search(r"(attn|xattn)/b[qkv]$", path):
            return [P(*L, md, None), P()]
        # ---------------- dense mlp
        if re.search(r"mlp/w[gui]$", path):
            return [P(*L, fs, md), P(*L, None, md), P(*L, fs, None), P()]
        if re.search(r"mlp/wo$", path):
            return [P(*L, md, fs), P(*L, None, fs), P()]
        # ---------------- moe
        if path.endswith("moe/router"):
            return [P(*L, fs, None), P()]
        if re.search(r"moe/w[gui]$", path):
            return [P(*L, md, fs, None), P(*L, md, None, None), P()]
        if re.search(r"moe/wo$", path):
            return [P(*L, md, None, fs), P(*L, md, None, None), P()]
        if "moe/shared" in path:
            if path.endswith("wo"):
                return [P(*L, md, fs), P(*L, None, fs), P()]
            return [P(*L, fs, md), P(*L, None, md), P()]
        # ---------------- ssm
        if path.endswith("ssm/in_proj"):
            return [P(*L, fs, md), P(*L, md, None), P(*L, fs, None), P()]
        if path.endswith("ssm/out_proj"):
            return [P(*L, md, fs), P(*L, None, fs), P()]
        if path.endswith("ssm/conv_w"):
            return [P(*L, None, fs), P()]
        if re.search(r"ssm/(conv_b|norm_w)$", path):
            return [P(*L, fs), P()]
        # ---------------- dlrm towers
        if re.search(r"(bottom|top)/\d+/w$", path):
            return [P(fs, md), P(None, md), P(fs, None), P()]
        # ---------------- norms, scalars, everything else
        if len(shape) >= 2 and shape[-1] >= 1024:
            return [P(*((None,) * (len(shape) - 1)), fs), P()]
        return [P()]

    def assign(path, leaf):
        shape = tuple(leaf.shape)
        return _pick(shape, rules(_path_str(path), shape), mesh)

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def batch_specs_sharding(cfg: ModelConfig, shape: ShapeConfig,
                         batch_shape: Dict[str, Any], ctx: ParallelContext):
    """Input batch shardings: batch dim over (pod, data)."""
    b = tuple(ctx.batch_axes) or None

    def assign(path, leaf):
        nd = len(leaf.shape)
        if leaf.shape and leaf.shape[0] % max(
                1, int(np.prod([ctx.axis_size(a) for a in (b or ())]))) == 0:
            return P(b, *([None] * (nd - 1)))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(assign, batch_shape)


def cache_specs_sharding(cfg: ModelConfig, shape: ShapeConfig,
                         cache_shape, ctx: ParallelContext, *,
                         seq_shard: bool = False):
    """Decode-cache shardings.

    Baseline: batch over (pod, data); KV heads over model when divisible,
    else replicate (recorded as a §Perf hillclimb target).
    seq_shard=True: shard the KV sequence axis over the model axis instead
    (the flash-decode sequence-parallel layout).
    """
    b = tuple(ctx.batch_axes) or None
    md = ctx.model_axis if ctx.model_axis_size > 1 else None
    bsz = int(np.prod([ctx.axis_size(a) for a in (b or ())])) or 1

    def assign(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if re.search(r"(prefix_)?x?[kv]$", p) and nd == 5:
            L, B, S, KH, HD = leaf.shape
            bspec = b if B % bsz == 0 else None
            if seq_shard and md and S % ctx.model_axis_size == 0:
                return P(None, bspec, md, None, None)
            if md and KH % ctx.model_axis_size == 0:
                return P(None, bspec, None, md, None)
            if md and S % ctx.model_axis_size == 0 and bspec is None:
                # batch=1 long-context: spread the sequence instead
                return P(None, None, md, None, None)
            return P(None, bspec, None, None, None)
        if re.search(r"(prefix_)?x?[kv]$", p) and nd == 4:  # unrolled prefix
            B, S, KH, HD = leaf.shape
            bspec = b if B % bsz == 0 else None
            if md and KH % ctx.model_axis_size == 0:
                return P(bspec, None, md, None)
            return P(bspec, None, None, None)
        if p.endswith("ssm") and nd == 5:
            L, B, H, Pd, N = leaf.shape
            bspec = b if B % bsz == 0 else None
            return P(None, bspec, None, None, None)
        if p.endswith("conv") and nd == 4:
            B = leaf.shape[1]
            bspec = b if B % bsz == 0 else None
            return P(None, bspec, None, None)
        if nd >= 1 and leaf.shape[0] % bsz == 0 and leaf.shape[0] >= bsz > 1:
            return P(b, *([None] * (nd - 1)))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def make_context(mesh, pcfg: ParallelConfig) -> ParallelContext:
    names = mesh.axis_names
    return ParallelContext(
        mesh=mesh,
        pod_axis=pcfg.pod_axis if (pcfg.pod_axis in names) else None,
        data_axis=pcfg.data_axis if pcfg.data_axis in names else None,
        model_axis=pcfg.model_axis if pcfg.model_axis in names else None,
        fsdp=pcfg.fsdp,
        bf16_fsdp_gather=pcfg.bf16_fsdp_gather,
        emb_wire_bf16=pcfg.emb_wire_bf16,
        emb_capacity_factor=pcfg.emb_capacity_factor,
        emb_method=pcfg.emb_method,
        emb_pipeline=pcfg.emb_pipeline,
    )
