"""Pipeline parallelism via shard_map + collective_permute (DESIGN.md §5).

Maps pipeline stages onto a mesh axis (the "pod" axis on the multi-pod mesh —
Table 3's GPT-3 best pick used pipeline=16 across the slice).  GPipe-style
schedule: M microbatches flow through S stages; stage s runs layer block s;
activations hop to the next stage with ``lax.ppermute``.

The whole schedule is one shard_map program: a scan over (M + S - 1) ticks
where every stage computes its resident microbatch then shifts activations —
the standard JAX SPMD pipeline pattern.  Bubble fraction = (S-1)/(M+S-1).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.context import shard_map

P = jax.sharding.PartitionSpec


def pipeline_apply(layer_fn: Callable, params_stacked, x, *, mesh,
                   stage_axis: str, microbatches: int):
    """Run a layer stack split into |stage_axis| pipeline stages.

    layer_fn(stage_params, x) -> x: applies one stage's layer block.
    params_stacked: pytree with leading dim = num_stages (sharded over
    stage_axis).  x: (B, ...) with B % microbatches == 0.
    Returns y with the same shape as x.
    """
    S = mesh.shape[stage_axis]
    M = microbatches
    B = x.shape[0]
    assert B % M == 0, (B, M)

    def local(params_local, x_local):
        # x_local: full batch on every stage (replicated over stage_axis);
        # only stage 0's input matters — others consume permuted activations.
        stage = jax.lax.axis_index(stage_axis)
        params_l = jax.tree.map(lambda p: p[0], params_local)
        mb = x_local.reshape((M, B // M) + x_local.shape[1:])
        ticks = M + S - 1

        def tick(carry, t):
            buf, out = carry                      # buf: (B//M, ...) resident
            # stage 0 loads microbatch t (if in range)
            load = jnp.where(t < M, t, M - 1)
            incoming = mb[load]
            buf = jnp.where(stage == 0, incoming, buf)
            y = layer_fn(params_l, buf)
            # last stage stores its finished microbatch (t - (S-1))
            store = t - (S - 1)
            ok = (stage == S - 1) & (store >= 0) & (store < M)
            out = jax.lax.cond(
                ok,
                lambda o: jax.lax.dynamic_update_slice(
                    o, y[None], (jnp.maximum(store, 0),) + (0,) * y.ndim),
                lambda o: o, out)
            # shift activations to the next stage
            y = jax.lax.ppermute(
                y, stage_axis, [(i, (i + 1) % S) for i in range(S)])
            return (y, out), None

        buf0 = jnp.zeros_like(mb[0])
        out0 = jnp.zeros_like(mb)
        (buf, out), _ = jax.lax.scan(
            tick, (buf0, out0), jnp.arange(ticks))
        # only the last stage holds the result; broadcast it
        out = jax.lax.psum(
            jnp.where(stage == S - 1, out, jnp.zeros_like(out)), stage_axis)
        return out.reshape(x_local.shape)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(), check_vma=False)
    return fn(params_stacked, x)


def bubble_fraction(num_stages: int, microbatches: int) -> float:
    return (num_stages - 1) / (microbatches + num_stages - 1)
