"""Gradient compression for the data-parallel exchange.

Two layers:

  * ``compressed_allreduce`` — the REAL collective, meant to be called
    inside a ``shard_map`` over the data axes (launch/steps.py wraps the
    whole grad computation so each shard holds its local contribution):

      - ``int8``: agree on a shared per-tensor scale (one ``pmax`` float),
        quantise locally, ``psum`` the int8 payload (widened to int32 so the
        cross-device sum is exact), dequantise once — the classic
        quantised all-reduce, ~4x fewer payload bytes than fp32;
      - ``topk``: each shard keeps exactly ``k = frac * n`` largest-|g|
        entries and exchanges a (value, index) list — metered at
        ``k * 8`` bytes; this CPU container emulates the sparse exchange
        with a dense ``psum`` (same numerics, wire bytes are *accounting*).

  * ``compress_grads`` — the single-device numerics roundtrip (quantise ->
    dequantise in place).  Used when there is no mesh to exchange over, so
    the ``grad_compression`` knob has identical *numerics* from every entry
    point even where there are no wire bytes to save.

Small tensors (``size < MIN_WIRE_SIZE``) and scalars pass through at full
width in both layers: a scale/index header would cost more than it saves.
Error feedback is intentionally omitted at this layer; the trainer can layer
it on via its metrics hook.

``wire_bytes`` is the shared accounting: per-device payload bytes for one
gradient exchange, plus the (tiny, reported separately) scale/header
overhead — the convention gradient-compression papers quote ratios in.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

# below this many elements a tensor is exchanged at full width
MIN_WIRE_SIZE = 64
TOPK_FRAC = 0.1
SCHEMES = ("none", "int8", "topk")


def _wired(g) -> bool:
    return g.ndim > 0 and g.size >= MIN_WIRE_SIZE


def _int8_roundtrip(g):
    if not _wired(g):
        return g
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def _topk_roundtrip(g, frac: float = TOPK_FRAC):
    if not _wired(g):
        return g
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    # exact-k: keep by top-k *indices*, not by threshold comparison — a
    # ``>= thresh`` mask keeps every element tied at the threshold, so
    # constant-magnitude tensors would keep ~100% instead of ``frac``
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(g.shape)


def compress_grads(grads, scheme: str):
    """In-place quantise->dequantise numerics (no exchange). Dtype-preserving."""
    if scheme == "int8":
        return jax.tree.map(_int8_roundtrip, grads)
    if scheme == "topk":
        return jax.tree.map(_topk_roundtrip, grads)
    raise ValueError(scheme)


# ---------------------------------------------------------------------------
# The real collective (call inside shard_map over the data axes)
# ---------------------------------------------------------------------------

def _psum(x, axes):
    for a in axes:
        x = jax.lax.psum(x, a)
    return x


def _pmax(x, axes):
    for a in axes:
        x = jax.lax.pmax(x, a)
    return x


def _nshards(axes) -> jax.Array:
    n = jnp.ones((), jnp.float32)
    for a in axes:
        n = n * _psum(jnp.ones((), jnp.float32), (a,))
    return n


def _int8_allreduce_mean(g, axes, n):
    gf = g.astype(jnp.float32)
    # one fp32 on the wire: agree on a shared scale so the int8 payloads sum
    scale = _pmax(jnp.max(jnp.abs(gf)), axes)
    scale = jnp.maximum(scale, 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    total = _psum(q.astype(jnp.int32), axes)          # exact int sum
    return (total.astype(jnp.float32) * scale / n).astype(g.dtype)


def _topk_allreduce_mean(g, axes, n, frac):
    sparse = _topk_roundtrip(g, frac)                 # exact-k local payload
    return (_psum(sparse.astype(jnp.float32), axes) / n).astype(g.dtype)


def compressed_allreduce(grads, scheme: str, axes: Tuple[str, ...],
                         *, frac: float = TOPK_FRAC):
    """Mean-reduce a gradient tree across mapped ``axes`` with compressed
    payloads.  MUST run inside shard_map (axes are lax axis names); each
    caller holds its local (per-shard) gradients."""
    if scheme not in SCHEMES:
        raise ValueError(scheme)
    axes = tuple(axes)
    n = _nshards(axes)

    def one(g):
        if scheme == "none" or not _wired(g):
            return (_psum(g.astype(jnp.float32), axes) / n).astype(g.dtype)
        if scheme == "int8":
            return _int8_allreduce_mean(g, axes, n)
        return _topk_allreduce_mean(g, axes, n, frac)

    return jax.tree.map(one, grads)


# ---------------------------------------------------------------------------
# Wire accounting
# ---------------------------------------------------------------------------

def wire_bytes(tree, scheme: str, *, frac: float = TOPK_FRAC
               ) -> Dict[str, int]:
    """Per-device payload bytes for ONE gradient exchange (static, from
    shapes).  ``wire_bytes`` is the tensor payload; scale / shared-max
    headers are metered separately as ``wire_overhead_bytes`` (4 bytes per
    compressed tensor).  ``wire_bytes_full`` is the uncompressed payload."""
    if scheme not in SCHEMES:
        raise ValueError(scheme)
    payload = overhead = full = 0
    for leaf in jax.tree.leaves(tree):
        n = int(leaf.size)
        b = int(jnp.dtype(leaf.dtype).itemsize)
        full += n * b
        if leaf.ndim == 0 or n < MIN_WIRE_SIZE or scheme == "none":
            payload += n * b
        elif scheme == "int8":
            payload += n            # 1 byte/element
            overhead += 4           # shared fp32 scale
        else:                       # topk: (value, int32 index) pairs
            k = max(1, int(n * frac))
            payload += k * (b + 4)
    return {"wire_bytes": payload, "wire_overhead_bytes": overhead,
            "wire_bytes_full": full}
