"""Gradient compression around the data-parallel all-reduce.

Two schemes (both applied *before* the optimizer, after grads are already
psum-reduced by XLA — on real multi-host runs these wrap the collective via
shard_map; here they also serve as drop-in numerics for the same effect):

  * int8  — per-tensor scale quantisation (8x wire reduction),
  * topk  — keep the largest 10% magnitudes per tensor (sparsified).

Error feedback is intentionally omitted at this layer; the trainer can layer
it on via its metrics hook.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _int8_roundtrip(g):
    if g.ndim == 0:
        return g
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(g, frac: float = 0.1):
    if g.ndim == 0 or g.size < 64:
        return g
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_grads(grads, scheme: str):
    if scheme == "int8":
        return jax.tree.map(_int8_roundtrip, grads)
    if scheme == "topk":
        return jax.tree.map(_topk_roundtrip, grads)
    raise ValueError(scheme)
