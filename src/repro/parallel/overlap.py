"""Compute/communication overlap by matmul decomposition (ASPLOS'23 [59],
cited by the paper §7.10: "effective compute-communication overlap").

``overlapped_matmul_ag``: y = all_gather(x) @ w, decomposed into |axis|
chunks: at every step each shard multiplies the chunk it currently holds
while ``lax.ppermute`` rotates the next chunk in — the collective rides under
the MXU work instead of serialising before it.

``overlapped_matmul_rs``: y = reduce_scatter(x @ w) with the same rotation on
the output side.

``software_pipeline``: the generic two-stage double-buffer the SparseCore
embedding executor uses — stage A (id all-to-all) of item k+1 is issued
before stage B (gather + combine) of item k consumes its buffer, so the
collective rides under the previous group's compute.

Used by the §Perf hillclimb for TP layers; correctness is tested against the
naive gather-then-matmul in tests/test_overlap.py.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.parallel.context import lax_axis_size

P = jax.sharding.PartitionSpec


def software_pipeline(stage_a: Callable, stage_b: Callable,
                      items: Sequence) -> List:
    """Run ``[stage_b(stage_a(x), x) for x in items]`` software-pipelined.

    Double-buffered issue order: stage A of item k+1 is emitted *before*
    stage B of item k, so when stage A ends in a collective (the embedding
    id all-to-all) and stage B is compute (owner gather + combine), the
    compiler can overlap item k+1's communication with item k's compute.
    Pure reordering — results are identical to the sequential loop.
    """
    items = list(items)
    if not items:
        return []
    out = []
    buf = stage_a(items[0])
    for k, item in enumerate(items):
        nxt = stage_a(items[k + 1]) if k + 1 < len(items) else None
        out.append(stage_b(buf, item))
        buf = nxt
    return out


def overlapped_matmul_ag(x_shard, w, axis: str):
    """x_shard: (m_local, k); w: (k, n) local weight shard of a matmul whose
    LHS is row-sharded over `axis`.  Computes all_gather(x) @ w with the
    gather decomposed into size-1 ring hops (runs inside shard_map)."""
    s = lax_axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m_l = x_shard.shape[0]
    perm_fwd = [(i, (i + 1) % s) for i in range(s)]

    def step(carry, t):
        chunk, acc = carry
        # the chunk currently held came from shard (idx - t) mod s
        src = (idx - t) % s
        part = chunk @ w                      # compute current chunk
        acc = jax.lax.dynamic_update_slice(
            acc, part, (src * m_l, jnp.zeros((), jnp.int32)))
        chunk = jax.lax.ppermute(chunk, axis, perm_fwd)  # prefetch next
        return (chunk, acc), None

    acc0 = jnp.zeros((m_l * s, w.shape[1]), x_shard.dtype)
    (chunk, acc), _ = jax.lax.scan(
        step, (x_shard, acc0), jnp.arange(s))
    return acc


def overlapped_matmul_rs(x, w_shard, axis: str):
    """reduce_scatter(x @ w, axis) with rotation: x (m, k_local) row-major
    activations, w_shard (k_local, n): each step computes one output block
    and passes the partial around the ring (ring reduce-scatter fused with
    the matmul)."""
    s = lax_axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m = x.shape[0]
    assert m % s == 0
    m_b = m // s
    perm = [(i, (i + 1) % s) for i in range(s)]

    def step(carry, t):
        acc = carry                            # (m_b, n) partial in flight
        # block this shard contributes at step t: after the remaining
        # (s - t) ring hops the partial lands on the block's owner
        blk = (idx - t) % s
        xb = jax.lax.dynamic_slice(
            x, (blk * m_b, jnp.zeros((), jnp.int32)), (m_b, x.shape[1]))
        acc = acc + xb @ w_shard
        acc = jax.lax.ppermute(acc, axis, perm)
        return acc, None

    acc0 = jnp.zeros((m_b, w_shard.shape[1]), x.dtype)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(s))
    # after s hops the accumulated block lands on its owner
    return acc
