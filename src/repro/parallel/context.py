"""ParallelContext — the minimal bridge between model code and the mesh.

Model code is pure JAX; the few places that need explicit collectives
(MoE expert-parallel all-to-all, sparse-embedding exchange, flash-decode
merge) read axis names from this context.  ``ctx=None`` (or a context whose
axes are absent/size-1) degenerates to purely local computation, which is how
single-device smoke tests run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax


@dataclass(frozen=True)
class ParallelContext:
    mesh: Optional[jax.sharding.Mesh] = None
    pod_axis: Optional[str] = None
    data_axis: Optional[str] = "data"
    model_axis: Optional[str] = "model"
    fsdp: bool = True
    # serve-time: shard the KV cache/sequence over the model axis (flash-decode)
    sequence_parallel_kv: bool = True
    # cast FSDP weight gathers to bf16 before the collective (§Perf)
    bf16_fsdp_gather: bool = False
    # SparseCore engine knobs (§Perf): bf16 embedding vectors on the wire,
    # all-to-all send capacity factor, and method override
    emb_wire_bf16: bool = False
    emb_capacity_factor: float = 2.0
    emb_method: str = "auto"
    # pipelined multi-group executor: fuse same-width groups into one
    # descriptor-stream launch and software-pipeline the per-group id/vector
    # exchanges (False = legacy one-launch-per-group dataflow)
    emb_pipeline: bool = True
    # serve fast path (§serve): decode attention backend — "auto" picks the
    # Pallas paged kernel on TPU and the dense XLA reference elsewhere;
    # "paged"/"dense" force one side.  decode_kv_block is the paged kernel's
    # KV block size (rows streamed per VMEM tile).
    decode_attn: str = "auto"
    decode_kv_block: int = 128

    def axis_size(self, name: Optional[str]) -> int:
        if name is None or self.mesh is None:
            return 1
        if name not in self.mesh.axis_names:
            return 1
        return self.mesh.shape[name]

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        """Mesh axes the global batch is sharded over."""
        return tuple(a for a in (self.pod_axis, self.data_axis)
                     if a is not None and self.axis_size(a) > 1)

    @property
    def fsdp_axes(self) -> Tuple[str, ...]:
        return self.batch_axes if self.fsdp else ()

    @property
    def model_axis_size(self) -> int:
        return self.axis_size(self.model_axis)

    @property
    def has_mesh(self) -> bool:
        return self.mesh is not None and any(
            s > 1 for s in self.mesh.shape.values())

    def spec(self, *axes) -> jax.sharding.PartitionSpec:
        """PartitionSpec helper that drops axes absent from the mesh."""
        def ok(a):
            if a is None:
                return None
            if isinstance(a, tuple):
                kept = tuple(x for x in a if self.axis_size(x) > 1)
                return kept if kept else None
            return a if self.axis_size(a) > 1 else None
        return jax.sharding.PartitionSpec(*(ok(a) for a in axes))


LOCAL = ParallelContext(mesh=None, pod_axis=None, data_axis=None,
                        model_axis=None, fsdp=False)


def lax_axis_size(axis) -> int:
    """Static size of a mapped axis inside shard_map: ``jax.lax.axis_size``
    where it exists; on 0.4.x recover it from an all_gather's trace-time
    shape (the gathered value is unused, so XLA dead-code-eliminates it)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    import jax.numpy as jnp
    return jax.lax.all_gather(jnp.zeros((1,), jnp.float32), axis).shape[0]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """Version-portable shard_map: ``jax.shard_map`` (new API, check_vma) or
    ``jax.experimental.shard_map`` (0.4.x, check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


# ---------------------------------------------------------------------------
# Trace-time activation sharding hints
# ---------------------------------------------------------------------------
# Model code calls hint(x, "batch", None, "model") at layout-critical points;
# the names resolve against the active ParallelContext (set by the step
# builders around tracing).  Without an active context this is the identity,
# so single-device smoke tests are unaffected.

import contextlib
import contextvars

_ACTIVE: contextvars.ContextVar[Optional[ParallelContext]] = \
    contextvars.ContextVar("repro_parallel_ctx", default=None)


@contextlib.contextmanager
def activate(ctx: Optional[ParallelContext]):
    tok = _ACTIVE.set(ctx if (ctx is not None and ctx.has_mesh) else None)
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def active_ctx() -> Optional[ParallelContext]:
    return _ACTIVE.get()


def hint(x, *roles):
    """Apply a sharding constraint by role names.

    Roles: "batch" -> ctx.batch_axes, "model"/"heads" -> ctx.model_axis,
    "both" -> batch+model combined, None -> unsharded.  Any role whose axes
    don't divide the corresponding dim resolves to None.
    """
    ctx = _ACTIVE.get()
    if ctx is None or ctx.mesh is None:
        return x
    entries = []
    for dim, role in zip(x.shape, roles):
        if role is None:
            entries.append(None)
            continue
        if role == "batch":
            axes = tuple(ctx.batch_axes)
        elif role in ("model", "heads", "seq"):
            axes = (ctx.model_axis,) if ctx.model_axis_size > 1 else ()
        elif role == "both":
            axes = tuple(ctx.batch_axes)
            if ctx.model_axis_size > 1:
                axes = axes + (ctx.model_axis,)
        else:
            raise ValueError(role)
        size = 1
        for a in axes:
            size *= ctx.axis_size(a)
        if not axes or size <= 1 or dim % size != 0:
            entries.append(None)
        else:
            entries.append(axes if len(axes) > 1 else axes[0])
    spec = jax.sharding.PartitionSpec(*entries)
    return jax.lax.with_sharding_constraint(x, spec)
