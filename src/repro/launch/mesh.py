"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run is the
only entry point that forces 512 host devices (see launch/dryrun.py's first
two lines).

Mesh-to-torus mapping: the logical ("data", "model") axes are laid out so the
"model" axis maps onto one face of the physical 3D torus slice (densest
collectives on the shortest paths) and "data"/"pod" span the remaining dims —
the §2.7 guidance made concrete by ``mesh_to_slice``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.core.topology import SliceTopology


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Version-portable ``jax.make_mesh``.

    Newer jax wants explicit ``axis_types`` (Auto); 0.4.x has no AxisType and
    no ``axis_types`` kwarg.  Everything downstream only needs a plain mesh
    with named axes, so fall back silently.
    """
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def mesh_scope(mesh):
    """Context manager activating `mesh`: ``jax.set_mesh`` where it exists,
    the legacy ``with mesh:`` trace context otherwise."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(shape: Tuple[int, ...] = (1, 1),
                    axes: Tuple[str, ...] = ("data", "model")):
    """Mesh over however many devices exist (tests/smoke)."""
    return make_mesh(shape, axes)


def mesh_to_slice(multi_pod: bool = False,
                  twisted: bool = False) -> SliceTopology:
    """The physical torus slice a production mesh runs on.

    Single pod: 256 chips as the 8×8×4 slice (the model axis maps to the
    8×8 faces).  Multi-pod: 512 chips as 8×8×8 — twistable per §2.8? No:
    twisting needs n×n×2n; 512 = 4×8×16_T would twist, 8×8×8 is the
    max-bisection cube (§2.8).  ``twisted`` selects 4×8×16_T where legal.
    """
    if multi_pod:
        dims = (4, 8, 16) if twisted else (8, 8, 8)
    else:
        dims = (4, 4, 16) if twisted else (4, 8, 8)
    return SliceTopology(dims, twisted=twisted)
