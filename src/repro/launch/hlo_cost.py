"""Loop-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` visits while-loop bodies ONCE, so any scan-based
model (layers, microbatches, attention chunks) is undercounted by the trip
counts.  This parser rebuilds per-computation costs from ``compiled.as_text()``
and multiplies through the call graph:

  * FLOPs   — 2*M*N*K for every dot (operand shapes resolved through each
    computation's symbol table); convolutions via window size,
  * HBM bytes — operand + output bytes of top-level (post-fusion) ops —
    fusion-internal computations are excluded (they live in registers/VMEM),
  * collective bytes — output shape bytes × on-wire multiplier per kind.

Trip counts come from the ``known_trip_count`` backend configs XLA emits for
lax.scan loops; computations reachable from a while body inherit the product
of enclosing trip counts.
"""
from __future__ import annotations

import collections
import re
from typing import Dict, List, Optional, Set, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_WIRE = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR = re.compile(r"^\s*(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_KIND = re.compile(r"^(\([^=]*\)|\S+)\s+([a-z][a-z0-9\-]*)\(")

# ops whose operands/outputs represent real HBM traffic at the top level
_HBM_OPS = {
    "fusion", "dot", "convolution", "copy", "all-gather", "all-reduce",
    "reduce-scatter", "all-to-all", "collective-permute", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "sort", "reduce",
    "transpose", "broadcast", "concatenate", "pad", "slice", "reverse",
    "all-gather-start", "all-reduce-start", "bitcast-convert", "select",
    "convert", "cholesky", "triangular-solve", "rng",
}
# internal-call edge kinds (their computations are fusion bodies, not HBM)
_INTERNAL_ATTRS = ("calls", "to_apply", "called_computations")


def _dims_of(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",") if d.strip()]


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    return [(t, _dims_of(d)) for t, d in _SHAPE_TOKEN.findall(text)
            if t in _DTYPE_BYTES]


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for t, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[t]
    return total


class _Op:
    __slots__ = ("name", "kind", "out_shapes", "operands", "line")

    def __init__(self, name, kind, out_shapes, operands, line):
        self.name = name
        self.kind = kind
        self.out_shapes = out_shapes
        self.operands = operands
        self.line = line


class HloCost:
    def __init__(self, hlo: str):
        self.comps: Dict[str, List[_Op]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo)
        self.mults, self.internal = self._call_graph()

    # -- parsing ---------------------------------------------------------------

    def _parse(self, hlo: str) -> None:
        cur: Optional[str] = None
        for line in hlo.splitlines():
            line = re.sub(r"/\*.*?\*/", "", line)   # strip /*index=N*/ etc.
            hm = _COMP_HDR.match(line)
            if hm:
                cur = hm.group(2)
                self.comps[cur] = []
                if hm.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, rhs = dm.group(1), dm.group(2)
            km = _OP_KIND.match(rhs)
            if not km:
                continue
            out_str, kind = km.group(1), km.group(2)
            out_shapes = _shapes_in(out_str)
            # operand names inside the first (...) group
            paren = rhs[km.end() - 1:]
            depth = 0
            args = ""
            for ch in paren:
                if ch == "(":
                    depth += 1
                    if depth == 1:
                        continue
                if ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                if depth >= 1:
                    args += ch
            operands = re.findall(r"%([\w.\-]+)", args)
            self.comps[cur].append(_Op(name, kind, out_shapes, operands,
                                       rhs))

    # -- call graph ----------------------------------------------------------------

    def _call_graph(self) -> Tuple[Dict[str, int], Set[str]]:
        edges: Dict[str, List[Tuple[str, int, bool]]] = \
            collections.defaultdict(list)
        for cname, ops in self.comps.items():
            for op in ops:
                trip = 1
                tm = re.search(r'known_trip_count[^0-9]*?(\d+)', op.line)
                if tm:
                    trip = int(tm.group(1))
                for attr in ("body", "condition") + _INTERNAL_ATTRS + \
                        ("branch_computations",):
                    for am in re.finditer(
                            attr + r"=\{?%?([\w.\-]+(?:, ?%[\w.\-]+)*)\}?",
                            op.line):
                        for callee in re.findall(r"[\w.\-]+", am.group(1)):
                            if callee not in self.comps:
                                continue
                            mult = trip if attr == "body" else 1
                            internal = attr in _INTERNAL_ATTRS
                            edges[cname].append((callee, mult, internal))
        root = self.entry or next(iter(self.comps), None)
        mults: Dict[str, int] = collections.defaultdict(int)
        internal: Set[str] = set()

        seen_stack: List[str] = []

        def walk(name: str, mult: int, depth: int):
            if depth > 64 or name in seen_stack:
                return
            mults[name] += mult
            seen_stack.append(name)
            for callee, m, is_int in edges.get(name, []):
                if is_int:
                    internal.add(callee)
                walk(callee, mult * m, depth + 1)
            seen_stack.pop()

        if root:
            walk(root, 1, 0)
        return dict(mults), internal

    # -- symbol table helpers ----------------------------------------------------------

    def _shape_map(self, cname: str) -> Dict[str, List[Tuple[str, List[int]]]]:
        return {op.name: op.out_shapes for op in self.comps[cname]}

    # -- costs ----------------------------------------------------------------------

    def dot_flops(self) -> float:
        total = 0.0
        for cname, ops in self.comps.items():
            mult = self.mults.get(cname, 0)
            if mult == 0:
                continue
            smap = self._shape_map(cname)
            for op in ops:
                if op.kind == "dot":
                    total += mult * self._dot_flops(op, smap)
                elif op.kind == "convolution":
                    total += mult * self._conv_flops(op)
        return total

    def _dot_flops(self, op: _Op, smap) -> float:
        out_elems = 1
        for t, dims in op.out_shapes:
            for d in dims:
                out_elems *= d
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        k = 1
        if cm and op.operands:
            lhs_shapes = smap.get(op.operands[0], [])
            if lhs_shapes:
                dims = lhs_shapes[0][1]
                for idx in _dims_of(cm.group(1)):
                    if idx < len(dims):
                        k *= dims[idx]
        return 2.0 * out_elems * k

    def _conv_flops(self, op: _Op) -> float:
        out_elems = 1
        for t, dims in op.out_shapes:
            for d in dims:
                out_elems *= d
        k = 1
        wm = re.search(r"window=\{size=([0-9x]+)", op.line)
        if wm:
            for d in wm.group(1).split("x"):
                k *= int(d)
        return 2.0 * out_elems * k

    def _fusion_callee(self, op: _Op) -> Optional[str]:
        m = re.search(r"calls=%?([\w.\-]+)", op.line)
        return m.group(1) if m else None

    def _slice_aware_bytes(self, op: _Op, smap) -> float:
        """Operand+output bytes, charging only the touched slice when a
        fusion merely dynamic-slices / dynamic-update-slices a big buffer
        (the scan-carry pattern: stacked weights, KV caches, grad
        accumulators)."""
        callee = self._fusion_callee(op) if op.kind == "fusion" else None
        param_usage: Dict[int, float] = {}
        out_override: Optional[float] = None
        if callee and callee in self.comps:
            cops = self.comps[callee]
            csmap = {o.name: o.out_shapes for o in cops}
            pname_to_idx = {}
            for o in cops:
                pm = re.search(r"\bparameter\((\d+)\)", o.line)
                if pm:
                    pname_to_idx[o.name] = int(pm.group(1))
            consumers: Dict[str, List[_Op]] = collections.defaultdict(list)
            for o in cops:
                for src in o.operands:
                    consumers[src].append(o)
            _PASS = {"bitcast", "reshape", "copy", "transpose"}

            def terminal_consumers(name, depth=0):
                """Consumers, looking through layout-only pass-through ops."""
                out = []
                for c in consumers.get(name, []):
                    if c.kind in _PASS and depth < 6:
                        out.extend(terminal_consumers(c.name, depth + 1))
                    else:
                        out.append((name, c))
                return out

            for pn, idx in pname_to_idx.items():
                cons = terminal_consumers(pn)
                if cons and all(c.kind == "dynamic-slice" and
                                c.operands and c.operands[0] == via
                                for via, c in cons):
                    param_usage[idx] = sum(
                        _bytes_of(c.out_shapes) for _, c in cons)
                elif cons and all(c.kind == "dynamic-update-slice" and
                                  c.operands and c.operands[0] == via
                                  for via, c in cons):
                    # in-place buffer: traffic = the written update region
                    param_usage[idx] = sum(
                        _bytes_of(csmap.get(c.operands[1], []))
                        for _, c in cons if len(c.operands) > 1)
            root = cops[-1] if cops else None
            for o in cops:
                if o.line.startswith("ROOT") or " ROOT " in o.line:
                    root = o
            if root is not None and root.kind == "dynamic-update-slice" \
                    and len(root.operands) > 1:
                out_override = _bytes_of(csmap.get(root.operands[1], []))
        total = (out_override if out_override is not None
                 else _bytes_of(op.out_shapes))
        for i, o in enumerate(op.operands):
            if i in param_usage:
                total += param_usage[i]
            else:
                total += _bytes_of(smap.get(o, []))
        return total

    def hbm_bytes(self) -> float:
        total = 0.0
        for cname, ops in self.comps.items():
            mult = self.mults.get(cname, 0)
            if mult == 0 or cname in self.internal:
                continue
            smap = self._shape_map(cname)
            for op in ops:
                if op.kind not in _HBM_OPS:
                    continue
                if op.kind == "dynamic-slice":
                    b = 2.0 * _bytes_of(op.out_shapes)
                elif op.kind == "dynamic-update-slice":
                    upd = (_bytes_of(smap.get(op.operands[1], []))
                           if len(op.operands) > 1 else 0.0)
                    b = 2.0 * upd
                else:
                    b = self._slice_aware_bytes(op, smap)
                total += mult * b
        return total

    def collective_bytes(self) -> Dict[str, float]:
        out = {k: 0.0 for k in COLLECTIVE_WIRE}
        for cname, ops in self.comps.items():
            mult = self.mults.get(cname, 0)
            if mult == 0:
                continue
            for op in ops:
                kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
                if kind in COLLECTIVE_WIRE:
                    out[kind] += (mult * COLLECTIVE_WIRE[kind]
                                  * _bytes_of(op.out_shapes))
        return out

    def summary(self) -> Dict[str, float]:
        coll = self.collective_bytes()
        return {
            "flops": self.dot_flops(),
            "hbm_bytes": self.hbm_bytes(),
            "collective_bytes": sum(coll.values()),
            **{f"coll_{k}": v for k, v in coll.items()},
        }
