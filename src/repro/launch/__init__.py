"""`repro.launch` — meshes, dry-run lowering, rooflines, HLO cost reads.

`dryrun`/`serve`/`train` stay module imports (they are CLI entry points
with heavy import-time work); the mesh helpers and analysis classes are the
programmatic surface.
"""
from repro.launch.hlo_cost import HloCost
from repro.launch.mesh import (make_local_mesh, make_mesh,
                               make_production_mesh, mesh_scope,
                               mesh_to_slice)
from repro.launch.roofline import Roofline, collective_bytes_from_hlo

__all__ = [
    "HloCost", "Roofline", "collective_bytes_from_hlo", "make_local_mesh",
    "make_mesh", "make_production_mesh", "mesh_scope", "mesh_to_slice",
]
