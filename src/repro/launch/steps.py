"""Train / serve step builders: the jit'd programs the dry-run lowers and the
trainer/server execute.

``make_train_step``: microbatched (gradient-accumulation) train step with
remat, optimizer update, and MoE aux losses.  Microbatching is what keeps the
(tokens × vocab) logits tensor bounded at 32k-seq × 256k-vocab scale.
``make_prefill_step`` / ``make_decode_step``: the serving programs.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, OptimizerConfig, ParallelConfig,
                                ShapeConfig)
from repro.models import api
from repro.optim import adam as OPT
from repro.parallel import sharding as SH
from repro.parallel.context import LOCAL, ParallelContext, activate

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def _xent(logits, labels):
    """Token cross-entropy; logits fp32 (B, T, V), labels (B, T)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - ll).mean()


def _xent_chunked(cfg, params, x, labels, chunk: int):
    """Sequence-chunked cross-entropy: the (B, T, V) logits tensor never
    materialises — logits exist only per (B, chunk, V/tp) slice (§Perf).
    x: final hidden states (B, T, D); labels (B, T)."""
    from repro.models.transformer import unembed
    B, T, D = x.shape
    chunk = min(chunk, T)
    if T % chunk:
        chunk = T  # fall back (shapes in this repo are powers of two)
    nc = T // chunk
    xc = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        xb, lb = xs
        logits = unembed(cfg, params, xb)           # (B, chunk, V) fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return acc + (lse - ll).sum(), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return tot / (B * T)


def loss_fn(cfg: ModelConfig, params, batch, ctx: ParallelContext,
            *, remat: str = "none", xent_chunk: int = 0,
            attn_impl: str = "blocked"
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    with activate(ctx):
        return _loss_fn(cfg, params, batch, ctx, remat=remat,
                        xent_chunk=xent_chunk, attn_impl=attn_impl)


def _loss_fn(cfg: ModelConfig, params, batch, ctx: ParallelContext,
             *, remat: str = "none", xent_chunk: int = 0,
             attn_impl: str = "blocked"):
    if cfg.family == "dlrm":
        from repro.models import dlrm as DL
        loss, aux = DL.loss_fn(cfg, params, batch, ctx)
        return loss, {"loss": loss}
    labels = batch["labels"]
    fwd_batch = {k: v for k, v in batch.items() if k != "labels"}
    T = labels.shape[1]
    kw = {} if cfg.family == "audio" else {"attn_impl": attn_impl}
    if xent_chunk and cfg.family != "audio":
        x, aux = api.forward(cfg, params, fwd_batch, ctx,
                             remat=(remat != "none"), return_hidden=True,
                             **kw)
        ce = _xent_chunked(cfg, params, x[:, -T:, :], labels, xent_chunk)
    else:
        logits, aux = api.forward(cfg, params, fwd_batch, ctx,
                                  remat=(remat != "none"), **kw)
        logits = logits[:, -T:, :]        # vlm: skip the patch prefix
        ce = _xent(logits, labels)
    loss = ce + 0.01 * aux
    return loss, {"loss": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def pick_accum_steps(cfg: ModelConfig, shape: ShapeConfig,
                     ctx: ParallelContext, *,
                     logits_budget: int = 256 << 20,
                     xent_chunk: int = 0) -> int:
    """Accumulation steps so per-device microbatch logits stay bounded.

    With chunked cross-entropy the logits tensor is (B, chunk, V) instead of
    (B, T, V), so far fewer accumulation steps are needed — which divides the
    per-microbatch FSDP weight-gather traffic (§Perf)."""
    if cfg.family == "dlrm":
        return 1
    ndev = 1
    if ctx.mesh is not None:
        for s in ctx.mesh.devices.shape:
            ndev *= s
    eff_seq = min(xent_chunk, shape.seq_len) if xent_chunk else shape.seq_len
    bytes_per_sample = eff_seq * cfg.vocab_size * 4
    total = shape.global_batch * bytes_per_sample
    accum = 1
    while (total / (accum * ndev)) > logits_budget \
            and accum < shape.global_batch:
        accum *= 2
    while shape.global_batch % accum:
        accum //= 2
    return max(accum, 1)


def _pmean(x, axes):
    for a in axes:
        x = jax.lax.pmean(x, a)
    return x


def make_train_step(cfg: ModelConfig, shape: ShapeConfig,
                    pcfg: ParallelConfig, ocfg: OptimizerConfig,
                    ctx: ParallelContext, *,
                    accum_steps: Optional[int] = None) -> Callable:
    """The ONE train-step builder — `Trainer` and the dry-run both route
    through here (via `shapes_and_shardings`), so every knob on
    `ParallelConfig` — `grad_compression` included — behaves identically
    from every entry point.

    `grad_compression != "none"` on a multi-shard data-parallel mesh wraps
    the whole grad computation in a shard_map over the batch axes: each
    shard computes grads on its local batch and the exchange itself runs
    compressed (`parallel/compression.compressed_allreduce` — shared-scale
    int8 payload psum / exact-k sparse exchange).  Without a mesh (or with
    model parallelism in play, where XLA owns the fused reduction) the same
    schemes apply as a post-reduction numerics roundtrip.  Either way the
    metrics carry per-device wire-bytes accounting for one exchange.
    """
    accum = accum_steps or pick_accum_steps(cfg, shape, ctx,
                                            xent_chunk=pcfg.xent_chunk)
    scheme = pcfg.grad_compression
    from repro.parallel import compression as COMP

    def grads_of(params, batch, gctx):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, gctx, remat=pcfg.remat,
                              xent_chunk=pcfg.xent_chunk,
                              attn_impl=pcfg.attn_impl),
            has_aux=True)(params)

    def accumulated(params, batch, gctx):
        """(grads, metrics) with gradient-accumulation microstepping."""
        if accum == 1:
            (loss, metrics), grads = grads_of(params, batch, gctx)
            return grads, dict(metrics)

        mb = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, xs):
            g_acc, loss_acc = acc
            (loss, _), g = grads_of(params, xs, gctx)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / accum, g_acc, g)
            return (g_acc, loss_acc + loss / accum), None

        (grads, loss), _ = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32)), mb)
        return grads, {"loss": loss}

    # compressed DP exchange: data-parallel shards only (with model
    # parallelism XLA owns the fused backward reduction, so compression
    # falls back to the post-reduction roundtrip)
    ndp = 1
    for a in ctx.batch_axes:
        ndp *= ctx.axis_size(a)
    dp_exchange = (scheme != "none" and ndp > 1
                   and ctx.model_axis_size == 1
                   and shape.global_batch % (ndp * accum) == 0)

    def dp_step(params, batch):
        from repro.parallel.context import shard_map
        axes = tuple(ctx.batch_axes)

        def body(p, b):
            g, metrics = accumulated(p, b, LOCAL)
            g = COMP.compressed_allreduce(g, scheme, axes)
            metrics = {k: _pmean(v, axes) for k, v in metrics.items()}
            return g, metrics

        return shard_map(body, mesh=ctx.mesh,
                         in_specs=(P(), P(axes)),
                         out_specs=(P(), P()))(params, batch)

    def train_step(params, opt_state, batch):
        if dp_exchange:
            grads, metrics = dp_step(params, batch)
        else:
            grads, metrics = accumulated(params, batch, ctx)
            if scheme != "none":
                grads = COMP.compress_grads(grads, scheme)
        wb = COMP.wire_bytes(grads, scheme)
        metrics = dict(metrics,
                       wire_bytes=jnp.float32(wb["wire_bytes"]),
                       wire_bytes_full=jnp.float32(wb["wire_bytes_full"]),
                       wire_overhead_bytes=jnp.float32(
                           wb["wire_overhead_bytes"]))
        params, opt_state, om = OPT.apply(ocfg, params, grads, opt_state)
        metrics = dict(metrics, **om)
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                      ctx: ParallelContext,
                      pcfg: Optional[ParallelConfig] = None) -> Callable:
    kw = ({} if (pcfg is None or cfg.family == "audio")
          else {"attn_impl": pcfg.attn_impl})

    def prefill_step(params, batch):
        with activate(ctx):
            return api.prefill(cfg, params, batch, ctx,
                               max_len=shape.seq_len, **kw)
    return prefill_step


def make_decode_step(cfg: ModelConfig, shape: ShapeConfig,
                     ctx: ParallelContext) -> Callable:
    def decode_step(params, cache, tokens):
        with activate(ctx):
            return api.decode_step(cfg, params, cache, tokens, ctx)
    return decode_step


# ---------------------------------------------------------------------------
# Spec assembly for jit/lower
# ---------------------------------------------------------------------------

def shapes_and_shardings(cfg: ModelConfig, shape: ShapeConfig,
                         pcfg: ParallelConfig, ocfg: OptimizerConfig,
                         ctx: ParallelContext, *,
                         accum_steps: Optional[int] = None):
    """(abstract args, in_shardings, out_shardings, step_fn) for one cell."""
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda: api.init_params(cfg, key, ctx))
    pspecs = SH.param_specs(cfg, params_shape, ctx)
    batch_shape = api.batch_specs(cfg, shape)
    bspecs = SH.batch_specs_sharding(cfg, shape, batch_shape, ctx)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(
            lambda: OPT.init(ocfg, _concretize(params_shape)))
        ospecs = _opt_specs(opt_shape, pspecs)
        step = make_train_step(cfg, shape, pcfg, ocfg, ctx,
                               accum_steps=accum_steps)
        args = (params_shape, opt_shape, batch_shape)
        in_sh = (pspecs, ospecs, bspecs)
        out_sh = (pspecs, ospecs, None)
        return args, in_sh, out_sh, step
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, shape, ctx, pcfg)
        args = (params_shape, batch_shape)
        cache_shape = api.cache_specs(cfg, shape)
        cspecs = SH.cache_specs_sharding(
            cfg, shape, cache_shape, ctx,
            seq_shard=pcfg.sequence_parallel)
        in_sh = (pspecs, bspecs)
        out_sh = (None, cspecs)
        return args, in_sh, out_sh, step
    # decode
    step = make_decode_step(cfg, shape, ctx)
    batch_shape = api.batch_specs(cfg, shape)
    cache_shape = api.cache_specs(cfg, shape)
    cspecs = SH.cache_specs_sharding(cfg, shape, cache_shape, ctx)
    tokens_shape = batch_shape["tokens"]
    bsz = 1
    for a in (ctx.batch_axes or ()):
        bsz *= ctx.axis_size(a)
    ok = ctx.has_mesh and bsz > 1 and tokens_shape.shape[0] % bsz == 0
    tspec = P(tuple(ctx.batch_axes)) if ok else P(None)
    args = (params_shape, cache_shape, tokens_shape)
    in_sh = (pspecs, cspecs, tspec)
    out_sh = (None, cspecs)
    return args, in_sh, out_sh, step


def _concretize(shape_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), shape_tree)


def _opt_specs(opt_shape, pspecs):
    """Optimizer state inherits parameter specs (ZeRO via FSDP storage)."""
    def assign(path, leaf):
        # walk the matching param spec by stripping mu/nu prefixes
        return _lookup_like(path, leaf, pspecs)
    return jax.tree_util.tree_map_with_path(assign, opt_shape)


def _lookup_like(path, leaf, pspecs):
    # OptState(step, mu, nu): mu/nu mirror params; adafactor nests dicts
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(k.idx)
    if not parts:
        return P()
    head = parts[0]
    if head == "step":
        return P()
    node = pspecs
    for k in parts[1:]:
        if isinstance(node, dict) and k in node:
            node = node[k]
        elif isinstance(node, (list, tuple)) and isinstance(k, int) \
                and k < len(node):
            node = node[k]
        elif isinstance(k, str) and k in ("vr", "vc", "v"):
            # adafactor factored dims: reduce the param spec
            if isinstance(node, P):
                if k == "vr":
                    return P(*node[:-1])
                if k == "vc":
                    return P(*(list(node[:-2]) + [node[-1]])) \
                        if len(node) >= 2 else P()
                return node
            return P()
        else:
            return P()
    return node if isinstance(node, P) else P()
