"""Three-term roofline analysis from compiled dry-run artifacts (§Roofline).

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

``compiled.cost_analysis()`` supplies per-partition FLOPs and bytes;
collective bytes are NOT in cost_analysis, so we parse the optimized HLO
(``compiled.as_text()``) and sum shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, with the
standard on-wire multipliers (all-reduce moves 2x its payload, etc.).
Ops inside while-loop bodies (the layer scan) are multiplied by the trip
count when it can be recovered from the HLO constant.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

# grading-spec hardware constants (TPU v5e-class target)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(\(?[^)=]*\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _loop_trip_counts(hlo: str) -> Dict[str, int]:
    """Best-effort map: while-body computation name -> trip count."""
    trips: Dict[str, int] = {}
    # XLA prints e.g. `while(...), condition=..., body=%body.123 ...
    #   backend_config={"known_trip_count":{"n":"42"}}`
    for m in re.finditer(
            r"while\([^)]*\).*?body=%?([\w.\-]+).*?"
            r"known_trip_count[^0-9]*(\d+)", hlo):
        trips[m.group(1)] = int(m.group(2))
    return trips


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Per-device on-wire bytes by collective kind (loop-aware)."""
    trips = _loop_trip_counts(hlo)
    # split into computations to apply trip counts
    comps = re.split(r"\n(?=%?[\w.\-]+ \([\w.,%\[\] ]*\) -> )", hlo)
    # fallback: whole text as one computation with multiplier 1
    out = {k: 0.0 for k in _COLLECTIVES}
    for comp in comps:
        header = comp.split("\n", 1)[0]
        name_m = re.match(r"%?([\w.\-]+) \(", header)
        mult = 1
        if name_m:
            for body_name, n in trips.items():
                if name_m.group(1) == body_name:
                    mult = n
                    break
        for m in _OP_RE.finditer(comp):
            shape_str, kind = m.group(1), m.group(2)
            out[kind] += _COLLECTIVES[kind] * _shape_bytes(shape_str) * mult
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float               # 6*N_active*D (train) / 2*N_active*D
    bytes_per_chip_peak: float       # memory_analysis peak
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.flops_per_chip / PEAK_FLOPS
        self.memory_s = self.hbm_bytes_per_chip / HBM_BW
        self.collective_s = self.collective_bytes_per_chip / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.flops_per_chip * self.chips
        return (self.model_flops / total) if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the dominant-term-bound step achieves on the
        *useful* (MODEL_FLOPS) work."""
        if self.bound_s <= 0:
            return 0.0
        useful_per_chip = self.model_flops / self.chips
        return (useful_per_chip / PEAK_FLOPS) / self.bound_s

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "model_flops": self.model_flops,
            "bytes_per_chip_peak": self.bytes_per_chip_peak,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference forward).

    DLRM: N = dense-tower parameters (embedding lookups are gathers, not
    matmuls — their cost is the memory/collective terms, §3.4)."""
    if cfg.family == "dlrm":
        from repro.models.counting import _dlrm_dense_params
        n_active = _dlrm_dense_params(cfg)
    else:
        n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':6s} "
           f"{'compute':>10s} {'memory':>10s} {'collect':>10s} "
           f"{'bound':>11s} {'useful%':>8s} {'roof%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        dom = r["dominant"][:4]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
            f"{r['compute_s']*1e3:9.2f}m {r['memory_s']*1e3:9.2f}m "
            f"{r['collective_s']*1e3:9.2f}m {bound*1e3:7.2f}m({dom}) "
            f"{100*r['useful_flops_fraction']:7.1f}% "
            f"{100*r['roofline_fraction']:6.1f}%")
    return "\n".join(lines)
