import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init).  For every cell this driver:

  1. builds the abstract args (ShapeDtypeStructs — no allocation),
  2. jit-lowers the step function with in/out shardings on the production
     mesh ((16,16) "data","model" single-pod; (2,16,16) "pod","data","model"
     multi-pod),
  3. ``.compile()``s it,
  4. records memory_analysis / cost_analysis / per-collective HLO bytes and
     the three roofline terms into a JSON cache (results/dryrun.json).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs import (OptimizerConfig, ParallelConfig, get_config,
                           registry)
from repro.launch import roofline as RL
from repro.launch import steps as STEPS
from repro.launch.mesh import make_production_mesh, mesh_scope
from repro.parallel import sharding as SH

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             pcfg: ParallelConfig = None, ocfg: OptimizerConfig = None,
             verbose: bool = True, tag: str = "",
             pcfg_overrides: dict = None) -> dict:
    cfg = get_config(arch)
    shape = next(s for s in registry.shapes_for(arch)
                 if s.name == shape_name)
    pcfg = pcfg or ParallelConfig(
        pod_axis="pod" if mesh_kind == "multi" else None,
        **(pcfg_overrides or {}))
    ocfg = ocfg or OptimizerConfig(
        state_dtype="bfloat16" if cfg.param_count() > 2e11 else "float32")
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    ctx = SH.make_context(mesh, pcfg)

    t0 = time.time()
    with mesh_scope(mesh):
        args, in_sh, out_sh, step = STEPS.shapes_and_shardings(
            cfg, shape, pcfg, ocfg, ctx)
        in_shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), in_sh,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        out_shardings = jax.tree.map(
            lambda s: (jax.sharding.NamedSharding(mesh, s)
                       if s is not None else None), out_sh,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
            or x is None)
        jitted = jax.jit(step, in_shardings=in_shardings,
                         out_shardings=out_shardings)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    from repro.launch.hlo_cost import HloCost
    hc = HloCost(hlo).summary()
    coll = {k[5:]: v for k, v in hc.items() if k.startswith("coll_")}

    # loop-corrected per-device costs (cost_analysis counts loop bodies once)
    flops = float(hc["flops"])
    bytes_accessed = float(hc["hbm_bytes"])
    coll_bytes = float(hc["collective_bytes"])
    mf = RL.model_flops_for(cfg, shape)
    peak_mem = (getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0))
    roof = RL.Roofline(
        arch=arch, shape=shape_name,
        mesh=("2x16x16" if mesh_kind == "multi" else "16x16"),
        chips=chips, flops_per_chip=flops,
        hbm_bytes_per_chip=bytes_accessed,
        collective_bytes_per_chip=coll_bytes,
        model_flops=mf, bytes_per_chip_peak=float(peak_mem))

    rec = roof.to_dict()
    rec.update({
        "tag": tag,
        "collectives": coll,
        "raw_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "ok": True,
    })
    if verbose:
        gib = (rec["memory"]["argument_bytes"] or 0) / 2**30
        tmp = (rec["memory"]["temp_bytes"] or 0) / 2**30
        print(f"[dryrun] {arch} {shape_name} {rec['mesh']}: "
              f"args {gib:.2f} GiB/dev, temp {tmp:.2f} GiB/dev, "
              f"flops/dev {flops:.3e}, hbm {bytes_accessed:.3e} B, "
              f"coll {coll_bytes:.3e} B -> dominant={rec['dominant']} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
              flush=True)
    return rec


def _load(path: pathlib.Path) -> dict:
    if path.exists():
        return json.loads(path.read_text())
    return {}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-dlrm", action="store_true")
    ap.add_argument("--out", default=str(RESULTS / "dryrun.json"))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--xent-chunk", type=int, default=0)
    ap.add_argument("--bf16-gather", action="store_true")
    ap.add_argument("--remat", default="block")
    ap.add_argument("--no-seq-par", action="store_true")
    ap.add_argument("--attn-impl", default="blocked",
                    choices=["blocked", "qchunked"])
    ap.add_argument("--emb-wire-bf16", action="store_true")
    ap.add_argument("--emb-cf", type=float, default=2.0)
    ap.add_argument("--emb-method", default="auto",
                    choices=["auto", "a2a", "psum"])
    args = ap.parse_args(argv)
    overrides = dict(xent_chunk=args.xent_chunk,
                     bf16_fsdp_gather=args.bf16_gather, remat=args.remat,
                     sequence_parallel=not args.no_seq_par,
                     attn_impl=args.attn_impl,
                     emb_wire_bf16=args.emb_wire_bf16,
                     emb_capacity_factor=args.emb_cf,
                     emb_method=args.emb_method)

    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    cache = _load(out)

    cells = []
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    if args.all:
        archs = list(registry.ASSIGNED_ARCHS)
        if args.include_dlrm:
            archs.append("dlrm0")
        for a in archs:
            for s in registry.shapes_for(a):
                for m in meshes:
                    cells.append((a, s.name, m))
    else:
        assert args.arch and args.shape
        for m in meshes:
            cells.append((args.arch, args.shape, m))

    failures = 0
    for arch, shape, mesh_kind in cells:
        k = f"{args.tag}/{arch}/{shape}/{mesh_kind}"
        if k in cache and cache[k].get("ok") and not args.force:
            print(f"[dryrun] cached {k}", flush=True)
            continue
        try:
            cache[k] = run_cell(arch, shape, mesh_kind, tag=args.tag,
                                pcfg_overrides=overrides)
        except Exception as e:  # record failure for triage
            failures += 1
            cache[k] = {"ok": False, "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-3000:]}
            print(f"[dryrun] FAIL {k}: {type(e).__name__}: {e}", flush=True)
        out.write_text(json.dumps(cache, indent=1))
    print(f"[dryrun] done: {len(cells)} cells, {failures} failures",
          flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
