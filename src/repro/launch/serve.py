"""CLI serving driver (cluster session API, serve fast path).

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --requests 8

``--chunk`` sets the multi-step decode width (tokens advanced per device
dispatch); ``--chunk 1`` is the per-token path with identical greedy output.
"""
import argparse
import json

import jax
import numpy as np

from repro.cluster import SliceSpec, Supercomputer
from repro.configs import registry
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b",
                    choices=[a for a in registry.ALL_ARCHS if a != "dlrm0"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per device dispatch (1 = per-token)")
    ap.add_argument("--sample", action="store_true",
                    help="temperature sampling instead of greedy decode")
    ap.add_argument("--slice", dest="slice_chips", type=int, default=256)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = registry.get_reduced(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    sc = Supercomputer()
    with sc.allocate(args.slice_chips) as sl:
        session = sl.serve(cfg, params,
                           SliceSpec(slots=args.slots, max_len=args.max_len,
                                     prompt_len=args.prompt_len,
                                     greedy=not args.sample,
                                     chunk=args.chunk))
        rng = np.random.default_rng(0)
        for _ in range(args.requests):
            session.submit(rng.integers(0, cfg.vocab_size, size=8),
                           max_new_tokens=args.new_tokens)
        print(json.dumps(session.run(), indent=2))


if __name__ == "__main__":
    main()
