"""CLI training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50 \
        --reduced --batch 8 --seq 64

Full-scale configs (--arch without --reduced) target the production mesh and
are what the dry-run lowers; on this CPU container use --reduced.
"""
import argparse
import json


from repro.configs import (OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b",
                    choices=list(registry.ALL_ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--remat", default="none",
                    choices=["none", "block", "full"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--optimizer", default="adam",
                    choices=["adam", "sgd", "adafactor"])
    args = ap.parse_args()

    cfg = (registry.get_reduced(args.arch) if args.reduced
           else registry.get_config(args.arch))
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("cli", "train", args.seq, args.batch),
        parallel=ParallelConfig(remat=args.remat,
                                grad_compression=args.grad_compression),
        optimizer=OptimizerConfig(name=args.optimizer, lr=args.lr,
                                  warmup_steps=max(args.steps // 10, 1)))
    trainer = Trainer(run, mesh, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every)
    trainer.train(args.steps, log_every=max(args.steps // 10, 1))
    for m in trainer.metrics_log:
        print(json.dumps(m))


if __name__ == "__main__":
    main()
