"""`repro.data` — deterministic synthetic datasets."""
from repro.data.synthetic import Dataset

__all__ = ["Dataset"]
