"""Synthetic data pipeline.

Deterministic, seekable, host-side generation:
  * LM token streams (zipf-ish unigram distribution over the vocab, so the
    loss curve is non-trivial and embedding-gather traffic is realistically
    skewed — the paper's dedup win depends on that skew),
  * DLRM categorical features (power-law ids, per-table valency),
  * audio-frame / vision-patch stubs for the whisper/internvl2 frontends.

``Dataset.batch(step)`` is pure in (seed, step): any host can regenerate any
step, which is what makes checkpoint/restart and elastic rescaling exact.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.whisper import split_seq


@dataclass
class Dataset:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)

    def _zipf_tokens(self, rng, shape, vocab: int) -> np.ndarray:
        """Zipf-flavoured token ids in [0, vocab)."""
        u = rng.random(shape)
        ids = np.minimum((u ** 3.0) * vocab, vocab - 1)
        return ids.astype(np.int32)

    def batch(self, step: int) -> Dict[str, Any]:
        cfg, shape = self.cfg, self.shape
        rng = self._rng(step)
        B, T = shape.global_batch, shape.seq_len
        if cfg.family == "dlrm":
            return self._dlrm_batch(rng, B)
        if cfg.family == "audio":
            enc, dec = split_seq(cfg, T)
            stream = self._zipf_tokens(rng, (B, dec + 1), cfg.vocab_size)
            out = {"frames": rng.standard_normal(
                       (B, enc, cfg.d_model)).astype(np.float32) * 0.1,
                   "tokens": stream[:, :-1]}
            if shape.kind == "train":
                out["labels"] = stream[:, 1:]
            return out
        t_text = T - (cfg.vision_prefix if cfg.family == "vlm" else 0)
        stream = self._zipf_tokens(rng, (B, t_text + 1), cfg.vocab_size)
        out = {"tokens": stream[:, :-1]}
        if cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (B, cfg.vision_prefix, cfg.vision_dim)).astype(np.float32) * 0.1
        if shape.kind == "train":
            out["labels"] = stream[:, 1:]
        return out

    def _dlrm_batch(self, rng, B: int) -> Dict[str, Any]:
        cfg = self.cfg
        out: Dict[str, Any] = {
            "dense": rng.standard_normal(
                (B, cfg.dlrm.dense_features)).astype(np.float32),
            "labels": (rng.random(B) < 0.3).astype(np.int32),
        }
        for t in cfg.dlrm.tables:
            ids = self._zipf_tokens(rng, (B, t.max_valency), t.vocab_size)
            keep_p = min(1.0, t.avg_valency / max(t.max_valency, 1))
            live = rng.random((B, t.max_valency)) < keep_p
            live[:, 0] = True
            out[f"cat_{t.name}"] = np.where(live, ids, -1).astype(np.int32)
        return out
