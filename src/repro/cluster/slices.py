"""Slice session handles — the user-facing half of `repro.cluster`.

A `Slice` is what `Supercomputer.allocate` hands out: one OCS-programmed
torus slice (paper §2.3/§2.5) carrying its `SliceTopology` plus everything a
workload needs — a jax mesh, a topology-bound collective cost model, and
session constructors:

  * ``slice.train(run, steps)``     — fault-tolerant training on the slice,
  * ``slice.serve(cfg, params)``    — a batched serving session,
  * ``slice.dryrun(profile)``       — analytic step-time on THIS geometry,
  * ``slice.autotopo(profile)``     — the §4 search over all geometries of
                                      this chip count,
  * ``slice.retwist(True)``         — §2.8 twist as OCS reprogramming.

Sessions stay registered with their slice; when the machine swaps a failed
block underneath the slice (§2.3) every active session receives the
`SliceEvent`, so callers observe reconfigurations without touching the
scheduler or the fabric.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.core.autotopo import (Evaluation, ModelProfile, ParallelSpec,
                                 estimate_step_time, search)
from repro.core.ocs import reconfig_time
from repro.core.topology import SliceTopology, is_twistable
from repro.parallel.context import LOCAL, ParallelContext
from repro.serve.engine import ServeEngine, SliceSpec

if TYPE_CHECKING:                                     # pragma: no cover
    from repro.cluster.supercomputer import Supercomputer


@dataclasses.dataclass(frozen=True)
class SliceEvent:
    """One thing that happened to a slice after allocation."""
    kind: str                   # "allocate" | "reconfigure" | "retwist" |
                                # "straggler" | "preempt" | "lost" | "free" |
                                # "shrink_request" | "shrink"
    detail: str
    circuits_moved: int = 0
    downtime_s: float = 0.0
    blocks_needed: int = 0      # "shrink_request" only: blocks asked back


class SliceError(RuntimeError):
    """Operation on a freed or lost slice."""


# ---------------------------------------------------------------------------
# Topology-bound cost model
# ---------------------------------------------------------------------------

class BoundCollectives:
    """`CollectiveCostModel` with the slice topology pre-bound, so callers
    ask ``slice.cost.all_reduce(bytes)`` without ever holding a topology."""

    def __init__(self, model, topo: SliceTopology):
        self._model = model
        self._topo = topo

    def all_reduce(self, bytes_per_chip: float,
                   dims_subset: Optional[Sequence[int]] = None) -> float:
        """Seconds for an all-reduce of ``bytes_per_chip`` on this slice
        (optionally over a subset of torus dimensions)."""
        return self._model.all_reduce(self._topo, bytes_per_chip, dims_subset)

    def all_gather(self, bytes_per_chip_out: float,
                   dims_subset: Optional[Sequence[int]] = None) -> float:
        """Seconds for an all-gather producing ``bytes_per_chip_out`` per
        chip (reduce-scatter is cost-symmetric: same estimate)."""
        return self._model.all_gather(self._topo, bytes_per_chip_out,
                                      dims_subset)

    reduce_scatter = all_gather

    def all_to_all(self, bytes_per_chip: float) -> float:
        """Seconds for an all-to-all of ``bytes_per_chip`` (twist-aware)."""
        return self._model.all_to_all(self._topo, bytes_per_chip)

    def p2p(self, bytes_: float, hops: int = 1) -> float:
        """Seconds for a point-to-point transfer over ``hops`` links."""
        return self._model.p2p(bytes_, hops)


# ---------------------------------------------------------------------------
# Sessions
# ---------------------------------------------------------------------------

class SliceSession:
    """Base session: registered with its slice, receives machine events."""

    def __init__(self, slice_: "Slice"):
        self.slice = slice_
        self.interruptions: List[SliceEvent] = []
        self.lost = False
        self.closed = False
        self._listeners: List[Any] = []
        slice_._sessions.append(self)

    def add_listener(self, fn) -> None:
        """Register ``fn(session, event)`` to run on every machine event this
        session sees — how a fleet replica reacts to a slice reconfiguring or
        dying without polling ``interruptions``."""
        self._listeners.append(fn)

    def _on_event(self, ev: SliceEvent) -> None:
        self.interruptions.append(ev)
        if ev.kind in ("lost", "free"):
            self.lost = ev.kind == "lost"
            self.closed = True
        for fn in list(self._listeners):
            fn(self, ev)

    def _check_live(self) -> None:
        if self.lost:
            raise SliceError("slice lost; session is dead")
        if self.closed:
            raise SliceError("session closed (slice freed?)")

    @property
    def stall_s(self) -> float:
        """Accumulated reconfiguration downtime seen by this session."""
        return sum(e.downtime_s for e in self.interruptions
                   if np.isfinite(e.downtime_s))

    def close(self) -> None:
        """Detach from the slice (no further events; idempotent)."""
        self.closed = True
        if self in self.slice._sessions:
            self.slice._sessions.remove(self)


class TrainSession(SliceSession):
    """A `Trainer` bound to a slice: checkpoints, fail/restore, metrics.

    ``run`` wires the supercomputer's scheduler and this slice's job id into
    the trainer, so an injected block failure exercises the real OCS
    swap-spare path and the event lands back here.

    Preemption is cooperative and rides the listener hooks: a ``"preempt"``
    `SliceEvent` (from `Supercomputer.request_preemption` or
    `Slice.request_preempt`) flips the trainer's stop flag — at the next
    step boundary the trainer checkpoints and returns early, after which
    `preempted` is True and the owner is expected to `free` the slice and
    later resume from the checkpoint on whatever slice it gets next (the
    checkpoint format is slice-shape-elastic, see `repro.train.checkpoint`).
    """

    def __init__(self, slice_: "Slice", trainer):
        super().__init__(slice_)
        self.trainer = trainer
        self.state = None

    def _on_event(self, ev: SliceEvent) -> None:
        if ev.kind == "preempt":
            self.trainer.request_preempt()
        super()._on_event(ev)

    @property
    def metrics_log(self) -> List[Dict[str, float]]:
        """Per-step metric dicts logged by the trainer (loss, wall_s, …)."""
        return self.trainer.metrics_log

    @property
    def params(self):
        """Current model parameters, or None before the first `run`."""
        return None if self.state is None else self.state.params

    @property
    def preempted(self) -> bool:
        """True when the last `run` stopped early on a preemption request
        (state checkpointed when the trainer has a ``ckpt_dir`` — give it
        one for any preemptible run, or keep the returned state yourself;
        the owner should then free the slice)."""
        return self.trainer.preempted

    def run(self, num_steps: int, *, fail_at: Optional[int] = None,
            log_every: int = 10, state=None, straggler=None):
        """Train to ``num_steps`` (absolute), resuming from ``state``, the
        session's previous state, or the latest checkpoint.

        Args:
          num_steps: target step count (training resumes at the restored
            step, so fewer steps actually execute after a restore).
          fail_at: inject a block failure at this step (the §2.3 drill).
          log_every: metric logging period in steps.
          state: explicit `TrainerState` to continue from.
          straggler: optional `repro.cluster.straggler.StragglerDetector` —
            fed this slice's modeled per-block step times after every step;
            when it confirms a slow block and the payback check clears
            (time recovered over the remaining steps beats the ACOS
            reconfiguration blackout), the session swaps the block via
            `Slice.swap_straggler` and keeps training.

        Returns the final `TrainerState` (early if preempted — check
        `preempted`)."""
        self._check_live()
        sc = self.slice._sc

        on_step = None
        if straggler is not None:
            def on_step(step: int, step_s: float) -> None:
                if self.lost or self.slice.status != "active":
                    return
                blk = straggler.observe(self.slice.block_times(step_s))
                if blk is None:
                    return
                if not straggler.worth_swapping(
                        blk, step_s, self.slice.swap_cost_s(blk),
                        remaining_steps=max(0, num_steps - step)):
                    return
                if self.slice.swap_straggler(blk) is not None:
                    straggler.fired(blk)

        self.state = self.trainer.train(
            num_steps, state=state or self.state, fail_at=fail_at,
            scheduler=sc.scheduler, job_id=self.slice.job_id,
            log_every=log_every, on_step=on_step)
        return self.state


class ServeSession(SliceSession):
    """A `ServeEngine` bound to a slice.

    The engine's request API passes through; `run` stats are annotated with
    the interruptions and stall time the underlying slice saw while the
    session was live (a reconfigure costs the MEMS switch time, §2.2)."""

    def __init__(self, slice_: "Slice", engine: ServeEngine):
        super().__init__(slice_)
        self.engine = engine
        self.draining = False

    @property
    def spec(self) -> SliceSpec:
        """The engine's serving envelope."""
        return self.engine.spec

    def submit(self, prompt, max_new_tokens: int = 32):
        """Enqueue a prompt on the underlying engine (refused while
        draining or after the slice died)."""
        self._check_live()
        if self.draining:
            raise SliceError("session is draining; not accepting requests")
        return self.engine.submit(prompt, max_new_tokens=max_new_tokens)

    def step(self) -> int:
        """Advance one admission+decode step; returns tokens decoded."""
        return 0 if self.closed else self.engine.step()

    # -- fleet surface: drain + queue introspection ---------------------------

    def drain(self) -> None:
        """Stop accepting new requests; in-flight work keeps decoding.  The
        fleet autoscaler drains a replica to completion before freeing its
        slice, so scale-down never kills live requests."""
        self.draining = True

    def undrain(self) -> None:
        """Resume accepting requests (a drain cancelled before the free —
        cheaper than provisioning a fresh slice when load returns)."""
        self.draining = False

    @property
    def is_drained(self) -> bool:
        """True once a draining session owes no further work."""
        return self.draining and self.engine.depth == 0

    @property
    def depth(self) -> int:
        """Requests the engine still owes work to."""
        return self.engine.depth

    def tokens_owed(self) -> int:
        """Decode tokens still owed across active + pending requests."""
        return self.engine.tokens_owed()

    def chunk_time_ema(self, default: float = 0.05) -> float:
        """Measured per-chunk latency EMA (``default`` before any chunk)."""
        return self.engine.chunk_time_ema(default)

    def prefix_lookup(self, prompt) -> int:
        """Prompt-prefix tokens this session's engine already holds in its
        shared KV pool (0 when the engine is not pooled, or after the slice
        died) — the router's prefix-affinity score."""
        if self.closed:
            return 0
        return self.engine.prefix_lookup(prompt)

    def expected_ttft_s(self, default_chunk_s: float = 0.05, *,
                        chunk_time_s=None) -> float:
        """Queue-aware TTFT estimate; ``chunk_time_s`` overrides the
        measured latency EMA when the caller accounts time itself (the
        fleet's deterministic virtual clock)."""
        return self.engine.expected_ttft_s(default_chunk_s,
                                           chunk_time_s=chunk_time_s)

    def step_chunk(self) -> int:
        """Advance one admission + decode chunk (the fleet pacing quantum)."""
        return 0 if self.closed else self.engine.step_chunk()

    def export_inflight(self):
        """Pull every unfinished request off this session's engine (used by
        the fleet after the slice is lost — bypasses the live-check since the
        whole point is evacuating a dead session)."""
        return self.engine.export_inflight()

    def run(self, max_steps: int = 1000) -> Dict[str, float]:
        """Serve until the queue drains (or ``max_steps``); returns the
        engine's stats dict annotated with this session's interruption
        count and reconfiguration stall time."""
        if self.lost:
            # same key set as a normal run, so failure-path callers can
            # read standard stats without special-casing
            return {"aborted": True, "requests_done": 0, "tokens": 0,
                    "wall_s": 0.0, "tokens_per_s": 0.0, "mean_ttft_s": 0.0,
                    "p50_ttft_s": 0.0, "p95_ttft_s": 0.0,
                    "decode_steps": 0, "chunk": self.engine.spec.chunk,
                    "p50_chunk_s": 0.0, "p95_chunk_s": 0.0,
                    "interruptions": len(self.interruptions),
                    "reconfig_stall_s": self.stall_s}
        self._check_live()
        stats = dict(self.engine.run(max_steps))
        stats["aborted"] = False
        stats["interruptions"] = len(self.interruptions)
        stats["reconfig_stall_s"] = self.stall_s
        return stats


# ---------------------------------------------------------------------------
# The slice handle
# ---------------------------------------------------------------------------

class Slice:
    """Session handle for one allocated torus slice.

    Constructed by `Supercomputer.allocate` — not directly."""

    def __init__(self, sc: "Supercomputer", job, *, mesh=None):
        self._sc = sc
        self._job = job
        self._mesh = mesh
        self._sessions: List[SliceSession] = []
        self.status = "active"              # "active" | "lost" | "freed"
        self._obs_span = None               # lifecycle span (tracing only)
        self.events: List[SliceEvent] = [SliceEvent(
            "allocate", f"{job.dims_chips} twisted={job.twisted} "
                        f"blocks={job.blocks}")]

    # -- identity / geometry --------------------------------------------------

    @property
    def job_id(self) -> int:
        """Scheduler job id backing this slice."""
        return self._job.job_id

    @property
    def dims(self) -> Tuple[int, int, int]:
        """Chip geometry (a, b, c) of the slice."""
        return self._job.dims_chips

    @property
    def twisted(self) -> bool:
        """Whether the slice is currently programmed as a twisted torus."""
        return self._job.twisted

    @property
    def blocks(self) -> List[int]:
        """Machine block ids the slice occupies (copy; spare-swaps mutate
        the underlying job)."""
        return list(self._job.blocks)

    @property
    def num_chips(self) -> int:
        """Total chips in the slice (product of `dims`)."""
        a, b, c = self.dims
        return a * b * c

    @property
    def priority(self) -> int:
        """Scheduling priority this slice was allocated at (higher wins)."""
        return self._job.priority

    @property
    def topology(self) -> SliceTopology:
        """Link-level `SliceTopology` for the current geometry/twist."""
        return self._job.topology

    @property
    def cost(self) -> BoundCollectives:
        """Collective cost model bound to the current topology."""
        return BoundCollectives(self._sc.costs, self.topology)

    def describe(self) -> str:
        """Human-readable geometry string (e.g. "8x8x8", "4x4x16_T")."""
        return self.topology.describe()

    def __repr__(self):
        return (f"Slice(job{self.job_id}, {self.describe()}, "
                f"{self.status}, blocks={self.blocks})")

    # -- mesh / parallel context ----------------------------------------------

    @property
    def mesh(self):
        """The jax mesh compute on this slice uses.  At container scale this
        is a (1, 1) local mesh; on real hardware it would span the slice."""
        if self._mesh is None:
            from repro.launch.mesh import make_local_mesh
            self._mesh = make_local_mesh()
        return self._mesh

    def parallel_context(self, parallel=None) -> ParallelContext:
        """Build a `ParallelContext` for this slice's mesh from a
        `ParallelConfig` (or the LOCAL context when None)."""
        from repro.parallel import sharding as SH
        if parallel is None:
            return LOCAL
        return SH.make_context(self.mesh, parallel)

    # -- guards ---------------------------------------------------------------

    def _check_active(self) -> None:
        if self.status != "active":
            raise SliceError(f"slice job{self.job_id} is {self.status}")

    # -- workloads ------------------------------------------------------------

    def train(self, run: RunConfig, num_steps: Optional[int] = None, *,
              ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
              fail_at: Optional[int] = None, log_every: int = 10,
              accum_steps: Optional[int] = None) -> TrainSession:
        """Train `run` on this slice.  With ``num_steps`` the session runs to
        completion before returning; without, call ``session.run`` yourself."""
        self._check_active()
        from repro.train.trainer import Trainer
        trainer = Trainer(run, self.mesh, ckpt_dir=ckpt_dir,
                          ckpt_every=ckpt_every, accum_steps=accum_steps,
                          slice_dims=self.dims, obs=self._sc.obs,
                          obs_labels={"job": self.job_id})
        session = TrainSession(self, trainer)
        if num_steps is not None:
            session.run(num_steps, fail_at=fail_at, log_every=log_every)
        return session

    def serve(self, model_cfg: ModelConfig, params,
              spec: Optional[SliceSpec] = None, *,
              ctx: Optional[ParallelContext] = None) -> ServeSession:
        """Open a serving session on this slice."""
        self._check_active()
        engine = ServeEngine(model_cfg, params, spec or SliceSpec(),
                             ctx=ctx or LOCAL, obs=self._sc.obs,
                             obs_labels={"job": self.job_id})
        return ServeSession(self, engine)

    def dryrun(self, profile: ModelProfile,
               spec: Optional[ParallelSpec] = None, *,
               mfu: float = 0.55) -> Evaluation:
        """Analytic step time for `profile` on THIS slice's geometry.

        With ``spec`` the given partitioning is evaluated; without, the best
        partitioning for this geometry is searched (§4 restricted to the
        slice in hand)."""
        self._check_active()
        if spec is not None:
            ev = estimate_step_time(profile, self.dims, spec,
                                    hw=self._sc.hw, twisted=self.twisted,
                                    mfu=mfu)
            if ev is None:
                raise ValueError(
                    f"{spec.label()} does not map onto {self.dims}")
            return ev
        evs = search(profile, self.num_chips, hw=self._sc.hw,
                     geometries=[self.dims], twisted=self.twisted, top_k=1)
        if not evs:
            raise ValueError(f"no partitioning of {profile.name} maps onto "
                             f"{self.dims}")
        return evs[0]

    def autotopo(self, profile: ModelProfile, *, top_k: int = 5,
                 allow_twist: bool = True) -> List[Evaluation]:
        """Full §4 search over every geometry of this slice's chip count —
        'should I have asked for a different shape?'"""
        self._check_active()
        return search(profile, self.num_chips, hw=self._sc.hw,
                      top_k=top_k, allow_twist=allow_twist)

    # -- reconfiguration ------------------------------------------------------

    def retwist(self, twisted: bool) -> int:
        """(Un)twist in place — pure OCS reprogramming, §2.8.  Returns the
        number of circuits that moved."""
        self._check_active()
        if twisted and not is_twistable(self.dims):
            raise ValueError(f"{self.dims} is not twistable")
        if twisted == self.twisted:
            return 0
        new_cfg, changed = self._sc.fabric.retwist(self._job.config, twisted)
        self._job.config = new_cfg
        self._job.twisted = twisted
        self._notify(SliceEvent(
            "retwist", f"twisted={twisted}", circuits_moved=changed,
            downtime_s=reconfig_time(changed)))
        return changed

    def request_preempt(self, detail: str = "preemption requested") -> bool:
        """Ask this slice's tenant to vacate (cooperative preemption).

        Emits a ``"preempt"`` `SliceEvent` to every session, listener, and
        machine-level subscriber.  A cooperative tenant (e.g. an elastic
        training job) checkpoints and calls `free` from its handler — in
        that case this returns True.  Tenants that ignore the request keep
        running; nothing is killed."""
        if self.status != "active":
            return True                     # already gone: nothing to evict
        ev = SliceEvent("preempt", detail)
        self._notify(ev)
        self._sc._publish(self, ev)
        return self.status != "active"

    def shrink(self, new_dims: Tuple[int, int, int]) -> SliceEvent:
        """Hand blocks back WITHOUT vacating: re-carve this slice in place
        to the strictly-smaller ``new_dims`` (§2.5 partial shrink).  The
        scheduler keeps the fastest owned blocks, reprograms the OCS
        circuits to the smaller torus, and the surplus returns to the free
        pool — one reconfiguration blackout instead of a full
        preempt→checkpoint→resume cycle.  Sessions opened before the shrink
        see the ``"shrink"`` event but keep their (now stale) geometry;
        tenants that care (the elastic trainer) close and reopen their
        session on the new shape."""
        self._check_active()
        dims = tuple(new_dims)
        released, moved, secs = self._sc.scheduler.shrink(self.job_id, dims)
        ev = SliceEvent("shrink",
                        f"-> {dims}, released blocks {released}",
                        circuits_moved=moved, downtime_s=secs)
        self._notify(ev)
        self._sc._publish(self, ev)
        return ev

    def request_shrink(self, blocks_needed: int,
                       detail: str = "capacity requested") -> int:
        """Ask this slice's tenant to hand back ``blocks_needed`` blocks
        (cooperative, like `request_preempt` — but partial).  A shrink-aware
        tenant reacts to the ``"shrink_request"`` `SliceEvent` by
        checkpointing and calling `shrink` to a smaller geometry *during
        the notification*; a tenant may instead vacate entirely, or ignore
        the request.  Returns the number of blocks actually freed."""
        if self.status != "active":
            return 0
        before = len(self._job.blocks)
        ev = SliceEvent("shrink_request", detail,
                        blocks_needed=blocks_needed)
        self._notify(ev)
        self._sc._publish(self, ev)
        if self.status != "active":
            return before                   # tenant vacated entirely
        return before - len(self._job.blocks)

    def swap_straggler(self, slow_block: int) -> Optional[SliceEvent]:
        """Replace a slow-but-healthy block with the fastest spare (§2.3).
        Returns the emitted event, or None when the scheduler refused (no
        spare, or no spare faster than the block)."""
        self._check_active()
        res = self._sc.scheduler.swap_straggler(self.job_id, slow_block)
        if res is None:
            return None
        moved, secs = res
        ev = SliceEvent("straggler", f"block{slow_block} swapped out",
                        circuits_moved=moved, downtime_s=secs)
        self._notify(ev)
        return ev

    # -- straggler telemetry ---------------------------------------------------

    def slowdown_factor(self) -> float:
        """Step-time multiplier of the slice's SLOWEST block: a synchronous
        (data-parallel) step finishes when the last block does, so one
        straggler drags the whole slice to its pace."""
        sched = self._sc.scheduler
        return max((sched.slowdown_of(b) for b in self._job.blocks),
                   default=1.0)

    def block_times(self, base_s: float) -> Dict[int, float]:
        """Per-block step time under a nominal per-block cost of
        ``base_s``: what a per-block step timer would report this step —
        the straggler detector's input signal."""
        sched = self._sc.scheduler
        return {b: base_s * sched.slowdown_of(b) for b in self._job.blocks}

    def swap_cost_s(self, block: Optional[int] = None) -> float:
        """Predicted blackout of swapping ``block`` (any owned block by
        default — circuit counts are uniform) for a spare, through the
        ACOS-style `CollectiveCostModel.reconfig_time`.  The payback side
        of the repair decision: swap only if the steady-state gain
        amortizes this."""
        if block is None:
            block = self._job.blocks[0]
        moved = sum(1 for c in self._job.config.circuits
                    if block in (c.block_plus, c.block_minus))
        return self._sc.costs.reconfig_time(moved)

    # -- lifecycle ------------------------------------------------------------

    def _notify(self, ev: SliceEvent) -> None:
        self.events.append(ev)
        self._sc._obs_slice_event(self, ev)
        for s in list(self._sessions):
            s._on_event(ev)

    def free(self) -> None:
        """Release blocks and OCS ports back to the machine."""
        if self.status == "active":
            self._sc._release(self)

    def __enter__(self) -> "Slice":
        return self

    def __exit__(self, *exc) -> None:
        self.free()
