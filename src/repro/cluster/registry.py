"""`MachineRegistry` — a fleet of `Supercomputer`s spanning generations.

The Jouppi et al. v2→Ironwood retrospective frames Google's ML real estate
as a *fleet of supercomputers across generations*, not one machine.  This
registry is that fleet: several `Supercomputer` instances (each its own OCS
fabric, scheduler, and failure domain) tagged with per-generation cost
models (`repro.core.costmodel.Generation`), behind one placement surface:

    reg = MachineRegistry([
        Supercomputer(8, generation=GEN_V4),
        Supercomputer(8, generation=GEN_V3),
    ])
    sl = reg.allocate((4, 4, 4), objective="perf_watt", priority=1)

Placement ranks machines by a generation objective — ``perf`` (fastest
per-chip silicon: latency-SLO serving), ``perf_watt`` (the paper's §8
metric: v4 ≈ 2.7x v3), ``perf_dollar`` (cheap old silicon: batch/training
drains there), or ``blind`` (registration order; the baseline the het-fleet
benchmark must beat) — and walks the ranking twice: first taking genuinely
free capacity anywhere, then (when allowed) asking lower-priority tenants
to shrink or vacate.  A machine is never preempted while another still has
free blocks.

Job ids are per-machine; anything keying slices fleet-wide must key on
``(machine, job_id)`` — `slice_key` canonicalizes that.
"""
from __future__ import annotations

from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple, Union)

from repro.cluster.slices import Slice, SliceEvent
from repro.cluster.supercomputer import CapacityError, Supercomputer

OBJECTIVES = ("perf", "perf_watt", "perf_dollar", "blind")


def slice_key(sl: Slice) -> Tuple[int, int]:
    """Fleet-wide identity of a slice: job ids are unique only within one
    machine, so cross-machine maps key on (machine identity, job id)."""
    return (id(sl._sc), sl.job_id)


class MachineRegistry:
    """An ordered collection of named `Supercomputer`s with generation-aware
    placement.  Iteration order is registration order."""

    def __init__(self, machines: Sequence[Supercomputer] = ()):
        self.machines: List[Supercomputer] = []
        self._by_name: Dict[str, Supercomputer] = {}
        for m in machines:
            self.add(m)

    # -- membership -----------------------------------------------------------

    def add(self, sc: Supercomputer,
            name: Optional[str] = None) -> Supercomputer:
        """Register a machine under ``name`` (default: its own name, which
        is usually the hardware preset's).  Collisions get a ``-2``/``-3``
        suffix so every machine is addressable."""
        base = name or sc.name
        unique, i = base, 2
        while unique in self._by_name:
            unique = f"{base}-{i}"
            i += 1
        sc.name = unique
        self._by_name[unique] = sc
        self.machines.append(sc)
        return sc

    def get(self, name: str) -> Supercomputer:
        return self._by_name[name]

    def names(self) -> List[str]:
        return [m.name for m in self.machines]

    def __iter__(self) -> Iterator[Supercomputer]:
        return iter(self.machines)

    def __len__(self) -> int:
        return len(self.machines)

    def __getitem__(self, i: int) -> Supercomputer:
        return self.machines[i]

    # -- events ---------------------------------------------------------------

    def subscribe(self, fn: Callable[[Slice, SliceEvent], None]):
        """Register a fleet-wide observer on every machine (see
        `Supercomputer.subscribe`).  Returns ``fn``."""
        for m in self.machines:
            m.subscribe(fn)
        return fn

    def unsubscribe(self, fn: Callable[[Slice, SliceEvent], None]) -> None:
        for m in self.machines:
            m.unsubscribe(fn)

    # -- scoring / ranking ----------------------------------------------------

    @staticmethod
    def score(sc: Supercomputer, objective: str) -> float:
        """Generation score of one machine under an objective (0.0 for
        ``blind`` or for machines outside the generation registry)."""
        g = sc.generation
        if objective == "blind" or g is None:
            return 0.0
        if objective == "perf":
            return g.perf_factor
        if objective == "perf_watt":
            return g.perf_per_watt
        if objective == "perf_dollar":
            return g.perf_per_dollar
        raise ValueError(f"objective {objective!r} not in {OBJECTIVES}")

    def rank(self, objective: str = "perf_watt") -> List[Supercomputer]:
        """Machines best-first under ``objective`` (registration order on
        ties — which makes ``blind`` exactly registration order)."""
        return sorted(self.machines,
                      key=lambda m: -self.score(m, objective))

    # -- placement ------------------------------------------------------------

    def allocate(self, geometry, *, objective: str = "perf_watt",
                 priority: int = 0, preempt: Union[bool, str] = False,
                 required: bool = False, twisted: bool = False,
                 mesh=None) -> Optional[Slice]:
        """Place a slice on the best machine under ``objective``.

        Two passes over the ranking: free capacity anywhere beats
        shrinking/evicting a tenant on a better machine, so preemption
        (``preempt=True`` or ``"shrink"``) is only attempted — best machine
        first — after every machine refused a clean allocation."""
        ranked = self.rank(objective)
        for m in ranked:
            sl = m.allocate(geometry, required=False, priority=priority,
                            twisted=twisted, mesh=mesh)
            if sl is not None:
                return sl
        if preempt:
            for m in ranked:
                sl = m.allocate(geometry, required=False, priority=priority,
                                preempt=preempt, twisted=twisted, mesh=mesh)
                if sl is not None:
                    return sl
        if required:
            raise CapacityError(
                f"no machine in {self.names()} can place {geometry}")
        return None

    # -- aggregate views ------------------------------------------------------

    def free_healthy_blocks(self) -> int:
        return sum(len(m.scheduler.free & m.scheduler.healthy)
                   for m in self.machines)

    @property
    def num_blocks(self) -> int:
        return sum(m.num_blocks for m in self.machines)

    def utilization(self) -> float:
        used = sum(m.utilization() * m.num_blocks for m in self.machines)
        return used / max(1, self.num_blocks)

    def overview(self) -> Dict[str, Any]:
        """Fleet snapshot: one `Supercomputer.overview` per machine plus
        the generation economics the placer scores with."""
        return {
            m.name: dict(
                m.overview(),
                generation=(m.generation.name if m.generation else None),
                perf_factor=(m.generation.perf_factor
                             if m.generation else None),
            )
            for m in self.machines
        }
