"""`Supercomputer` — the machine-level facade of `repro.cluster`.

One object owns the whole paper-§2 stack: the `OCSFabric` (port accounting +
circuit programming), the `SliceScheduler` (any-blocks-anywhere allocation,
spare swapping), the `CollectiveCostModel`, and the Figure-4 goodput
arithmetic.  Users ask it for `Slice` handles and never touch the plumbing:

    sc = Supercomputer()                      # 64 blocks = 4096 chips
    sl = sc.allocate((8, 8, 8))               # or sc.allocate(512)
    sess = sl.train(run_cfg, steps)           # / sl.serve(cfg, params)
    sl.free()

`submit` + `run_pending` form a minimal job queue so train/serve jobs beyond
current capacity wait their turn, and `fail_block` propagates the §2.3
swap-a-spare reconfiguration into whatever slice (and live sessions) owned
the failed block.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.cluster.slices import Slice, SliceEvent
from repro.core.costmodel import (GENERATIONS, CollectiveCostModel,
                                  Generation, HardwareParams, TPU_V4)
from repro.core.goodput import goodput_ocs, goodput_static
from repro.core.scheduler import SliceScheduler
from repro.core.topology import geometries_for, is_twistable
from repro.obs import Telemetry

Geometry = Union[int, Tuple[int, int, int]]


class CapacityError(RuntimeError):
    """Not enough healthy free blocks for the requested slice."""


class _NotifyingScheduler(SliceScheduler):
    """SliceScheduler that reports failure handling back to the facade, so
    events reach `Slice` handles even when a component (e.g. the trainer's
    fault hook) drives the scheduler directly."""

    def __init__(self, *args, on_failure=None, **kwargs):
        super().__init__(*args, **kwargs)
        self._on_failure = on_failure

    def fail_block(self, block: int):
        res = super().fail_block(block)
        if self._on_failure is not None:
            self._on_failure(block, res)
        return res


@dataclasses.dataclass
class JobTicket:
    """One queued unit of work: a geometry request plus a function that gets
    the allocated `Slice` and returns the job's result.  ``priority`` orders
    the queue (higher first; FIFO within a priority)."""
    ticket_id: int
    dims: Tuple[int, int, int]
    twisted: bool
    fn: Callable[[Slice], Any]
    tag: str = ""
    priority: int = 0
    status: str = "queued"          # "queued" | "running" | "done" | "failed"
    result: Any = None
    error: Optional[str] = None


class Supercomputer:
    """Facade over one OCS-reconfigurable machine (default: 4096 chips)."""

    def __init__(self, num_blocks: int = 64, *,
                 hw: Optional[HardwareParams] = None,
                 generation: Optional[Generation] = None,
                 name: Optional[str] = None,
                 contiguous: bool = False,
                 obs: Optional[Telemetry] = None):
        if hw is None:
            hw = generation.hw if generation is not None else TPU_V4
        self.scheduler = _NotifyingScheduler(
            num_blocks, contiguous=contiguous, on_failure=self._on_failure)
        self.hw = hw
        # generation economics (perf factor, Watts, $/chip-hour) for the
        # multi-machine fleet placer; resolved from the hardware preset when
        # not given, None for hardware outside the registry
        self.generation = (generation if generation is not None
                           else GENERATIONS.get(hw.name))
        self.name = name if name is not None else hw.name
        self.costs = CollectiveCostModel(hw)
        self.slices: Dict[int, Slice] = {}      # job_id -> live Slice
        self.queue: List[JobTicket] = []
        self._next_ticket = 0
        self._subscribers: List[Callable[[Slice, SliceEvent], None]] = []
        # machine telemetry: a private wall-clock Telemetry unless the
        # caller shares one (the fleet layer injects a virtual-clock handle
        # so machine and fleet events land on one timeline)
        self.obs = obs if obs is not None else Telemetry()

    @property
    def fabric(self):
        """The machine's `OCSFabric` (port accounting, circuit state)."""
        return self.scheduler.fabric

    @property
    def num_blocks(self) -> int:
        """Total 4^3 blocks in the machine (64 = 4096 chips by default)."""
        return self.scheduler.num_blocks

    @property
    def events(self) -> List[str]:
        """Machine-level event log (allocations, failures, re-routes)."""
        return self.scheduler.events

    # -- geometry helpers ------------------------------------------------------

    @staticmethod
    def geometries(num_chips: int) -> List[Tuple[int, int, int]]:
        """All 4i×4j×4k slice shapes with this chip count (§2.5)."""
        return geometries_for(num_chips)

    def _resolve_geometry(self, geometry: Geometry,
                          twisted: bool) -> Tuple[int, int, int]:
        if isinstance(geometry, int):
            cands = geometries_for(geometry)
            if twisted:
                cands = [g for g in cands if is_twistable(g)]
            if not cands:
                raise ValueError(f"no 4i*4j*4k geometry for {geometry} chips"
                                 + (" (twisted)" if twisted else ""))
            # most cube-like shape: best bisection per §2.8's default choice
            return min(cands, key=lambda g: (max(g) / min(g), sum(g)))
        dims = tuple(geometry)
        assert len(dims) == 3, dims
        return dims

    # -- allocation ------------------------------------------------------------

    def allocate(self, geometry: Geometry, *, twisted: bool = False,
                 mesh=None, required: bool = True, priority: int = 0,
                 preempt: Union[bool, str] = False) -> Optional[Slice]:
        """Allocate a slice.

        Args:
          geometry: a ``(a, b, c)`` chip shape or a chip count (the most
            cube-like legal shape is picked).
          twisted: program the slice as a twisted torus (§2.8).
          mesh: jax mesh for compute on the slice (defaults to a local mesh).
          required: raise `CapacityError` instead of returning None when the
            machine cannot place the slice.
          priority: scheduling priority recorded on the job (higher wins).
          preempt: when capacity is short, cooperatively evict strictly
            lower-priority slices (see `request_preemption`) and retry
            once.  The string ``"shrink"`` asks shrink-capable tenants to
            hand back blocks FIRST (`request_capacity`), falling back to
            full preemption only when partial shrink cannot free enough.

        Returns:
          A live `Slice` handle, or None (``required=False`` only).
        """
        dims = self._resolve_geometry(geometry, twisted)
        job = self.scheduler.allocate(dims, twisted=twisted,
                                      priority=priority)
        if job is None and preempt:
            ok = (self.request_capacity(dims, priority)
                  if preempt == "shrink"
                  else self.request_preemption(dims, priority))
            if ok:
                job = self.scheduler.allocate(dims, twisted=twisted,
                                              priority=priority)
        if job is None:
            if required:
                raise CapacityError(
                    f"cannot place {dims} slice: "
                    f"{len(self.scheduler.free & self.scheduler.healthy)} "
                    f"healthy free blocks")
            return None
        sl = Slice(self, job, mesh=mesh)
        self.slices[job.job_id] = sl
        obs = self.obs
        obs.metrics.counter("machine.allocations").inc()
        obs.event("slice.allocate", cat="slice",
                  track=f"slice:job{job.job_id}",
                  dims=dims, blocks=list(job.blocks))
        if obs.tracer.enabled:
            # slice lifecycle span: allocate -> free/lost (ended by
            # _obs_slice_event); long-lived, so begin/end not a `with`
            sl._obs_span = obs.tracer.begin(
                "slice.lifetime", cat="slice",
                track=f"slice:job{job.job_id}", dims=str(dims))
        return sl

    def request_preemption(self, geometry: Geometry, priority: int, *,
                           twisted: bool = False) -> bool:
        """Cooperatively evict lower-priority slices until a ``geometry``
        request at ``priority`` could be placed.

        Victim slices receive a ``"preempt"`` `SliceEvent` (delivered to
        their sessions, listeners, and machine subscribers).  A well-behaved
        tenant — e.g. an elastic training job — reacts by checkpointing and
        freeing the slice *during the notification*; slices whose owners do
        not free are left running (preemption here is a request, never a
        kill).  Returns True if enough blocks were actually freed."""
        dims = self._resolve_geometry(geometry, twisted)
        victims = self.scheduler.preemption_victims(dims, priority)
        if victims is None:
            return False
        need = self.scheduler.blocks_needed(dims)
        for job in victims:
            sl = self.slices.get(job.job_id)
            if sl is None:
                continue
            self.scheduler.events.append(
                f"preempt job{job.job_id} (prio {job.priority} < {priority})")
            sl.request_preempt(
                f"evicted for a priority-{priority} {dims} request")
            if len(self.scheduler.free & self.scheduler.healthy) >= need:
                break
        return len(self.scheduler.free & self.scheduler.healthy) >= need

    def request_capacity(self, geometry: Geometry, priority: int, *,
                         twisted: bool = False) -> bool:
        """Free enough healthy blocks for a ``geometry`` request at
        ``priority``, preferring PARTIAL SHRINK over full preemption.

        Pass 1 walks strictly-lower-priority slices in the same
        cheapest-first victim order as `preemption_victims` and asks each to
        `Slice.request_shrink` the remaining deficit — a shrink-aware tenant
        (the elastic trainer) re-checkpoints onto a smaller geometry and
        keeps running, handing back only what the request needs.  Only if
        shrink leaves a deficit does pass 2 fall back to
        `request_preemption` (full cooperative eviction).  Returns True if
        enough blocks are free on exit."""
        dims = self._resolve_geometry(geometry, twisted)
        need = self.scheduler.blocks_needed(dims)

        def have() -> int:
            return len(self.scheduler.free & self.scheduler.healthy)

        if have() >= need:
            return True
        cands = sorted((j for j in self.scheduler.jobs.values()
                        if j.priority < priority),
                       key=lambda j: (j.priority, len(j.blocks), -j.job_id))
        for job in cands:
            if have() >= need:
                break
            sl = self.slices.get(job.job_id)
            if sl is None:
                continue
            freed = sl.request_shrink(
                need - have(),
                f"priority-{priority} {dims} request needs blocks")
            if freed:
                self.scheduler.events.append(
                    f"shrink job{job.job_id} freed {freed} blocks for a "
                    f"priority-{priority} {dims} request")
        if have() >= need:
            return True
        return self.request_preemption(dims, priority, twisted=twisted)

    def subscribe(self, fn: Callable[[Slice, SliceEvent], None]):
        """Register a machine-level observer: ``fn(slice, event)`` fires for
        every slice lifecycle event (reconfigure/lost/free) regardless of who
        owns the slice.  This is how the fleet layer learns that `fail_block`
        hit one of its serving replicas and re-routes the in-flight requests
        instead of erroring the whole service.  Returns ``fn`` so it can be
        used as a decorator."""
        self._subscribers.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[Slice, SliceEvent], None]) -> None:
        """Detach a `subscribe`d observer (no-op if already detached) —
        long-lived machines hosting successive services must not keep dead
        observers reachable."""
        if fn in self._subscribers:
            self._subscribers.remove(fn)

    def _publish(self, sl: Slice, ev: SliceEvent) -> None:
        for fn in list(self._subscribers):
            fn(sl, ev)

    def _obs_slice_event(self, sl: Slice, ev: SliceEvent) -> None:
        """Telemetry for every post-allocation `SliceEvent` (called from
        `Slice._notify`): one instant event on the slice's lane, labeled
        counters, downtime histograms, the lifecycle span end on
        free/lost, and a flight-recorder postmortem on lost/preempt."""
        obs = self.obs
        track = f"slice:job{sl.job_id}"
        obs.metrics.counter("machine.slice_events", kind=ev.kind).inc()
        if ev.downtime_s > 0 and np.isfinite(ev.downtime_s):
            obs.metrics.histogram("machine.reconfig_downtime_s").observe(
                ev.downtime_s)
        obs.event(f"slice.{ev.kind}", cat="slice", track=track,
                  detail=ev.detail, circuits_moved=ev.circuits_moved,
                  downtime_s=ev.downtime_s)
        if ev.kind in ("lost", "free") and sl._obs_span is not None:
            obs.tracer.end(sl._obs_span)
            sl._obs_span = None
        if ev.kind in ("lost", "preempt"):
            obs.postmortem(f"slice_{ev.kind}", job_id=sl.job_id,
                           detail=ev.detail)

    def _release(self, sl: Slice) -> None:
        self.scheduler.release(sl.job_id)
        self.slices.pop(sl.job_id, None)
        sl.status = "freed"
        ev = SliceEvent("free", f"released blocks {sl.blocks}")
        sl._notify(ev)
        self._publish(sl, ev)

    def utilization(self) -> float:
        """Fraction of blocks currently owned by live slices."""
        return self.scheduler.utilization()

    # -- failures --------------------------------------------------------------

    def fail_block(self, block: int):
        """Fail a block machine-wide; the owning slice (if any) is re-routed
        onto a spare or, with no spares, marked lost — and every live session
        on it is notified.  Returns the scheduler's (job_id, moved, secs)."""
        self.obs.metrics.counter("machine.block_failures").inc()
        self.obs.event("machine.fail_block", cat="failure", block=block)
        return self.scheduler.fail_block(block)

    def repair_block(self, block: int) -> None:
        """Return a failed block to the healthy pool (it rejoins the free
        set unless a slice still maps it)."""
        self.obs.metrics.counter("machine.block_repairs").inc()
        self.obs.event("machine.repair_block", cat="failure", block=block)
        self.scheduler.repair_block(block)

    def set_block_slowdown(self, block: int, factor: float) -> None:
        """Mark a block as a straggler: healthy but ``factor``x slower per
        synchronous step (1.0 clears it).  Sessions on slices owning the
        block model their step time off it; the straggler detector is what
        should notice and `Slice.swap_straggler` it away."""
        self.obs.metrics.gauge("machine.block_slowdown",
                               block=block).set(factor)
        self.obs.event("machine.set_slowdown", cat="straggler",
                       block=block, factor=factor)
        self.scheduler.set_slowdown(block, factor)

    def _on_failure(self, block: int, result) -> None:
        if result is None:
            return                          # idle block, nobody to notify
        job_id, moved, secs = result
        sl = self.slices.get(job_id)
        if sl is None:
            return
        if secs == float("inf"):
            # no spare (or static cabling): the scheduler already killed the
            # job; the slice and its sessions are lost until repair.
            sl.status = "lost"
            self.slices.pop(job_id, None)
            ev = SliceEvent(
                "lost", f"block{block} failed, no spare", downtime_s=secs)
        else:
            ev = SliceEvent(
                "reconfigure", f"block{block} -> spare",
                circuits_moved=moved, downtime_s=secs)
        sl._notify(ev)
        self._publish(sl, ev)

    # -- job queue -------------------------------------------------------------

    def submit(self, geometry: Geometry, fn: Callable[[Slice], Any], *,
               twisted: bool = False, tag: str = "",
               priority: int = 0) -> JobTicket:
        """Queue `fn` to run on a slice of `geometry` once one can be placed.
        Tickets run in `run_pending` (priority order, FIFO within a
        priority, with backfill)."""
        dims = self._resolve_geometry(geometry, twisted)
        if twisted and not is_twistable(dims):
            raise ValueError(f"{dims} is not twistable")
        need = self.scheduler.blocks_needed(dims)
        if need > self.num_blocks:
            raise ValueError(f"{dims} needs {need} blocks; machine has "
                             f"{self.num_blocks}")
        t = JobTicket(self._next_ticket, dims, twisted, fn, tag=tag,
                      priority=priority)
        self._next_ticket += 1
        self.queue.append(t)
        return t

    def run_pending(self) -> List[JobTicket]:
        """Drain the queue: allocate, run, free — repeating until no queued
        ticket can be placed.  Higher-priority tickets go first; smaller
        lower-priority jobs backfill around a blocked head-of-line job (the
        §2.5 scheduling benefit)."""
        finished: List[JobTicket] = []
        progress = True
        while progress:
            progress = False
            ordered = sorted(self.queue,
                             key=lambda t: (-t.priority, t.ticket_id))
            for t in ordered:
                if t not in self.queue:
                    continue
                try:
                    sl = self.allocate(t.dims, twisted=t.twisted,
                                       required=False, priority=t.priority)
                except ValueError as e:     # bad geometry: fail the ticket,
                    self.queue.remove(t)    # keep the rest draining
                    t.status, t.error = "failed", f"{type(e).__name__}: {e}"
                    finished.append(t)
                    progress = True
                    continue
                if sl is None:
                    continue
                self.queue.remove(t)
                t.status = "running"
                try:
                    t.result = t.fn(sl)
                    t.status = "done"
                except Exception as e:      # keep the queue draining
                    t.error = f"{type(e).__name__}: {e}"
                    t.status = "failed"
                finally:
                    sl.free()
                finished.append(t)
                progress = True
        return finished

    # -- fleet arithmetic ------------------------------------------------------

    def expected_goodput(self, slice_chips: int, host_availability: float, *,
                         mode: Optional[str] = None, trials: int = 2000,
                         seed: int = 0) -> float:
        """Figure-4 goodput: expected machine fraction doing useful work at
        the given CPU-host availability.  ``mode`` defaults to this machine's
        wiring ("ocs", or "static" when built with contiguous=True)."""
        mode = mode or ("static" if self.scheduler.contiguous else "ocs")
        fn = {"ocs": goodput_ocs, "static": goodput_static}[mode]
        return fn(slice_chips, host_availability, trials=trials, seed=seed)

    def overview(self) -> Dict[str, Any]:
        """Machine snapshot: block counts, utilization, live slices, queue
        depth — the one-call observability surface."""
        free = len(self.scheduler.free & self.scheduler.healthy)
        return {
            "num_blocks": self.num_blocks,
            "healthy_blocks": len(self.scheduler.healthy),
            "free_blocks": free,
            "utilization": self.utilization(),
            "live_slices": {jid: sl.describe()
                            for jid, sl in self.slices.items()},
            "queued_tickets": len(self.queue),
        }
