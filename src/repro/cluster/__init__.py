"""`repro.cluster` — one object model from the OCS fabric to workloads.

    from repro.cluster import Supercomputer, SliceSpec

    sc = Supercomputer()                 # 64 blocks = 4096 chips
    sl = sc.allocate((8, 8, 8))          # any 4i x 4j x 4k, from any blocks
    train = sl.train(run_cfg, 30)        # fault-tolerant training session
    serve = sl.serve(run_cfg.model, train.params, SliceSpec(slots=4))
    serve.submit(prompt); serve.run()
    sl.free()

Everything below this facade (`OCSFabric`, `SliceScheduler`,
`CollectiveCostModel`, goodput, autotopo, `Trainer`, `ServeEngine`) remains
importable for tests and benchmarks, but workloads should not need it.
"""
from repro.cluster.registry import MachineRegistry, slice_key
from repro.cluster.slices import (BoundCollectives, ServeSession, Slice,
                                  SliceError, SliceEvent, SliceSession,
                                  TrainSession)
from repro.cluster.straggler import StragglerConfig, StragglerDetector
from repro.cluster.supercomputer import (CapacityError, JobTicket,
                                         Supercomputer)
from repro.cluster.tenancy import (ElasticTrainJob, MixedTenancyDriver,
                                   TenancyReport, TrainTenantSpec)
from repro.serve.engine import SliceSpec

__all__ = [
    "BoundCollectives", "CapacityError", "ElasticTrainJob", "JobTicket",
    "MachineRegistry", "MixedTenancyDriver", "ServeSession", "Slice",
    "SliceError", "SliceEvent", "SliceSession", "SliceSpec",
    "StragglerConfig", "StragglerDetector", "Supercomputer",
    "TenancyReport", "TrainSession", "TrainTenantSpec", "slice_key",
]
