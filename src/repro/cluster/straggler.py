"""Automatic straggler detection with hysteresis (paper §2.3, §6).

TPU v4's availability story treats *stragglers* — healthy blocks running
slow (thermals, failing HBM, noisy hosts) — as first-class failures: the
OCS can swap a slow block for a spare in milliseconds, but something has to
NOTICE the slow block first.  This module is that something.

`StragglerDetector` consumes per-block step times (one observation per
synchronous step — `Slice.block_times` models them from the scheduler's
slowdown state) and flags a block only when its step-time ratio to the
slice median stays over threshold for `patience` CONSECUTIVE steps (an
EMA of the ratio grades severity, but the streak is instantaneous).  One
noisy step — however large — bumps the streak to 1 and the next normal
step resets it to 0: no flapping.  After
a swap fires, `cooldown_steps` of quiet follow before the next candidate
can fire, so back-to-back reconfigurations cannot cascade while the fabric
settles.

The swap itself is a *decision*, not a reflex: `worth_swapping` compares
the per-step time recovered against the ACOS-style reconfiguration blackout
(`Slice.swap_cost_s`) over the caller's remaining horizon — a straggler
near the end of a job is cheaper to tolerate than to fix.

Wiring: `ServeReplica` (fleet) and `TrainSession.run` (cluster) feed the
detector each step and call `Slice.swap_straggler` when it fires; the
resulting `SliceEvent` charges the blackout to the session's stall clock,
closing the detect → swap → recover loop end-to-end.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class StragglerConfig:
    """Knobs of the detector's hysteresis and the swap economics."""
    threshold: float = 1.25         # EMA step-time ratio vs slice median
    ema_alpha: float = 0.4          # per-step EMA weight of the new ratio
    patience: int = 3               # consecutive over-threshold steps to fire
    cooldown_steps: int = 8         # quiet steps after a swap
    horizon_steps: int = 200        # payback window for `worth_swapping`

    def __post_init__(self):
        assert self.threshold > 1.0
        assert 0.0 < self.ema_alpha <= 1.0
        assert self.patience >= 1 and self.cooldown_steps >= 0


class StragglerDetector:
    """Per-block step-time jitter tracker with hysteresis.

    Feed `observe` one ``{block: step_seconds}`` dict per synchronous step;
    it returns the block to swap (worst confirmed straggler) or None.  The
    caller performs the swap and reports it back via `fired` (which starts
    the cooldown and resets the block's history)."""

    def __init__(self, cfg: Optional[StragglerConfig] = None):
        self.cfg = cfg or StragglerConfig()
        self._ema: Dict[int, float] = {}
        self._streak: Dict[int, int] = {}
        self._cooldown = 0
        self.steps_seen = 0
        self.fired_log: List[Tuple[int, int]] = []   # (step, block)

    def observe(self, block_times: Dict[int, float]) -> Optional[int]:
        """One step of per-block times.  Returns a confirmed straggler to
        swap, or None (below threshold, within patience, or cooling down).
        """
        self.steps_seen += 1
        if len(block_times) < 2:
            return None         # a 1-block slice has no peers to lag behind
        times = sorted(block_times.values())
        mid = len(times) // 2
        median = (times[mid] if len(times) % 2
                  else 0.5 * (times[mid - 1] + times[mid]))
        if median <= 0.0:
            return None
        a = self.cfg.ema_alpha
        for blk, t in block_times.items():
            ratio = t / median
            prev = self._ema.get(blk, ratio)
            self._ema[blk] = a * ratio + (1.0 - a) * prev
            # the streak counts INSTANTANEOUS over-threshold steps — one
            # normal step resets it, so a single noisy outlier (however
            # large) can never fire; the EMA only grades severity
            if ratio > self.cfg.threshold:
                self._streak[blk] = self._streak.get(blk, 0) + 1
            else:
                self._streak[blk] = 0
        # forget blocks that left the slice (post-swap geometry change)
        for blk in list(self._ema):
            if blk not in block_times:
                self._ema.pop(blk)
                self._streak.pop(blk, None)
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        confirmed = [b for b, s in self._streak.items()
                     if s >= self.cfg.patience]
        if not confirmed:
            return None
        return max(confirmed, key=lambda b: (self._ema[b], b))

    def fired(self, block: int) -> None:
        """Record that the caller swapped ``block``: starts the cooldown
        and drops the block's history (its replacement starts clean)."""
        self._cooldown = self.cfg.cooldown_steps
        self._ema.pop(block, None)
        self._streak.pop(block, None)
        self.fired_log.append((self.steps_seen, block))

    def slowdown_estimate(self, block: int) -> float:
        """Detector's current estimate of the block's step-time ratio."""
        return self._ema.get(block, 1.0)

    def worth_swapping(self, block: int, base_step_s: float,
                       blackout_s: float,
                       remaining_steps: Optional[int] = None) -> bool:
        """Payback check: does the time recovered over the remaining
        horizon beat the reconfiguration blackout?  ``remaining_steps``
        defaults to the configured horizon (serving has no natural end).
        """
        horizon = (self.cfg.horizon_steps if remaining_steps is None
                   else remaining_steps)
        gain_per_step = (self.slowdown_estimate(block) - 1.0) * base_step_s
        return gain_per_step * horizon > blackout_s
