"""Mixed train+serve tenancy: training as an elastic, preemptible tenant.

The paper's availability argument (§2.3, §2.5) is that OCS reconfiguration
lets one machine carve, resize, and reclaim slices around failures and
shifting demand.  `repro.fleet` already flexes *serving* capacity; this
module makes *training* the other tenant of the same machine:

  * `ElasticTrainJob` — a training run that lives across slices.  It
    allocates the largest geometry (from a preference list) that currently
    fits, trains real steps in window-sized quanta, and reacts to a
    ``"preempt"`` `SliceEvent` by checkpointing (slice-shape-elastic, see
    `repro.train.checkpoint`), freeing its blocks, and waiting.  A later
    resume may land on a *different* geometry — the loss curve continues
    bitwise-identically because the checkpoint carries params + optimizer
    state + the data cursor, and the global batch is unchanged.
  * `MixedTenancyDriver` — the co-scheduler: one `Supercomputer`, one
    `FleetService` (high priority), one `ElasticTrainJob` (low priority).
    The fleet's virtual clock is chopped into windows; each window first
    serves its arrivals/failures, then lets training catch up with a
    quantum of real train steps.  A serving burst that cannot place a new
    replica evicts the training job through the scheduler's priority
    machinery (`FleetService(preempt_on_allocate=True)` →
    `Supercomputer.request_preemption`); at the trough the driver resumes
    training on whatever blocks drained replicas left behind.

Training throughput is geometry-aware in *virtual* time: a step on ``g``
blocks costs ``base_step_s / g`` virtual seconds (ideal data-parallel
scaling), so holding more blocks at the trough genuinely buys steps — the
utilization the static-partition baseline cannot reach.  The steps
themselves are real jax computation at fixed global batch regardless of
geometry (the container serializes what the hardware would spread).

Benchmarked (elastic vs static partition) in `benchmarks/mixed_tenancy.py`
→ ``BENCH_tenancy.json``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import (TYPE_CHECKING, Any, Dict, List, Optional, Sequence,
                    Tuple, Union)

from repro.cluster.registry import MachineRegistry
from repro.cluster.slices import Slice, SliceEvent, TrainSession
from repro.cluster.supercomputer import Supercomputer
from repro.configs.base import RunConfig

if TYPE_CHECKING:                                     # pragma: no cover
    from repro.fleet.service import FleetService
    from repro.fleet.traffic import FleetRequest

WAITING = "waiting"          # never started, or not yet re-placed
RUNNING = "running"          # holds a slice, training in quanta
PREEMPTED = "preempted"      # evicted; checkpointed and block-less
DONE = "done"                # reached target_steps


def _blocks_needed(dims: Tuple[int, int, int]) -> int:
    a, b, c = dims
    return (a // 4) * (b // 4) * (c // 4)


def shrink_target(geometries: Sequence[Tuple[int, int, int]],
                  held_blocks: int, blocks_requested: int
                  ) -> Optional[Tuple[int, int, int]]:
    """Pick the geometry a cooperative tenant shrinks to when asked to hand
    back ``blocks_requested`` of its ``held_blocks``.

    Pure policy (property-tested in isolation): among the tenant's
    acceptable ``geometries`` (preference order, largest first), take the
    LARGEST one that both strictly shrinks and frees the full request;
    when none frees enough, fall back to the smallest acceptable geometry
    (best-effort — every freed block still helps the requester's tally).
    Returns None when the tenant is already at (or below) its minimum
    geometry: a shrink never strands the gang below the smallest shape it
    declared it can train on."""
    cands = [tuple(d) for d in geometries
             if _blocks_needed(d) < held_blocks]
    if not cands:
        return None
    for dims in cands:
        if held_blocks - _blocks_needed(dims) >= blocks_requested:
            return dims
    return cands[-1]


@dataclasses.dataclass(frozen=True)
class TrainTenantSpec:
    """Configuration of one elastic training tenant.

    Args:
      run: the training `RunConfig` (model/shape/parallel/optimizer); the
        global batch is fixed by it, independent of slice geometry.
      target_steps: stop after this many global steps.
      ckpt_dir: checkpoint root shared across every slice the job touches.
      geometries: acceptable chip geometries in preference order (largest
        first); resume takes the first that fits the machine's free blocks.
      priority: scheduling priority (keep it below the serving fleet's so
        bursts can evict training; between trainers it is the tier — a
        higher-priority trainer is shrunk/evicted last).
      base_step_s: virtual seconds one step costs on ONE block; on ``g``
        blocks a step costs ``base_step_s / g`` (ideal DP scaling).
      ckpt_every: periodic checkpoint interval in steps (preemption always
        checkpoints regardless).
      log_every: trainer metric logging period.
      name: label in logs/reports (several tenants share one machine).
      objective: machine-ranking objective for placement on a multi-machine
        registry — "perf_dollar" (default: deadline-free training drains
        to the cheapest silicon) or any other `MachineRegistry` objective
        ("blind" = registration order, the generation-unaware baseline).
    """
    run: RunConfig
    target_steps: int
    ckpt_dir: str
    geometries: Sequence[Tuple[int, int, int]] = ((4, 4, 8), (4, 4, 4))
    priority: int = 0
    base_step_s: float = 0.25
    ckpt_every: int = 10
    log_every: int = 1
    name: str = "train"
    objective: str = "perf_dollar"


class ElasticTrainJob:
    """A training run that survives preemption and slice-shape changes.

    Lifecycle: WAITING → (try_start) → RUNNING → (preempt) → PREEMPTED →
    (try_start on possibly different geometry) → RUNNING → … → DONE.

    Preemption is cooperative and arrives over the PR-4 listener hooks: the
    slice's ``"preempt"`` `SliceEvent` reaches the `TrainSession`, which
    flips the trainer's stop flag (mid-quantum) or this job's handler
    (between quanta); either way the job checkpoints, frees its blocks
    during the notification, and re-enters the waiting pool."""

    def __init__(self, sc: Union[Supercomputer, MachineRegistry],
                 spec: TrainTenantSpec):
        # accept one machine or a fleet; placement ranks machines by
        # perf/$ — training is deadline-free, so it drains to the cheapest
        # silicon that fits (the ISSUE's batch-goes-to-old-pools story)
        if isinstance(sc, MachineRegistry):
            self.registry = sc
        else:
            self.registry = MachineRegistry([sc])
        self.sc = self.registry[0]
        self.spec = spec
        self.state = WAITING
        self.slice: Optional[Slice] = None
        self.session: Optional[TrainSession] = None
        self.steps_done = 0
        self.preemptions = 0
        self.resumes = 0                    # re-placements after preemption
        self.grows = 0                      # voluntary moves to more blocks
        self.shrinks = 0                    # cooperative partial shrinks
        self.geometry_history: List[Tuple[float, Optional[Tuple[int, int, int]]]] = []
        self.log: List[str] = []
        self._in_quantum = False
        self._ever_started = False
        # last virtual time this job observed (boundary/quantum stamps);
        # events that originate inside the fleet loop (a scale-up evicting
        # us mid-window) are stamped with it — accurate to one window
        self._now = 0.0

    def __repr__(self):
        dims = self.slice.dims if self.slice else None
        return (f"ElasticTrainJob({self.state}, step={self.steps_done}/"
                f"{self.spec.target_steps}, dims={dims})")

    # -- capacity ------------------------------------------------------------

    @property
    def blocks_held(self) -> int:
        """Blocks currently owned (0 while preempted/waiting/done)."""
        return len(self.slice.blocks) if self.slice is not None else 0

    def steps_in(self, window_s: float) -> int:
        """Real steps one window buys at the current geometry (virtual
        ideal-DP scaling: more blocks → more steps per virtual second)."""
        if self.blocks_held == 0:
            return 0
        return max(1, int(round(window_s * self.blocks_held
                                / self.spec.base_step_s)))

    # -- placement -----------------------------------------------------------

    def try_start(self, now: float = 0.0, *, _count_resume: bool = True
                  ) -> bool:
        """Place the job on the largest preferred geometry that fits.

        Builds a fresh `Trainer` on the new slice (the checkpoint under
        ``ckpt_dir`` restores the data cursor and state on first
        `run_quantum`).  Returns True when a slice was obtained."""
        if self.state not in (WAITING, PREEMPTED):
            return False
        self._now = max(self._now, now)
        sl = None
        for dims in self.spec.geometries:
            sl = self.registry.allocate(dims, objective=self.spec.objective,
                                        priority=self.spec.priority)
            if sl is not None:
                break
        if sl is None:
            return False
        self.slice = sl
        self.session = sl.train(self.spec.run, None,
                                ckpt_dir=self.spec.ckpt_dir,
                                ckpt_every=self.spec.ckpt_every)
        self.session.add_listener(self._on_session_event)
        if self._ever_started and _count_resume:
            self.resumes += 1
        self._ever_started = True
        self.state = RUNNING
        self.geometry_history.append((now, sl.dims))
        self.log.append(f"[t={now:8.3f}s] {self.spec.name} tenant on "
                        f"{sl.dims} ({sl._sc.name} blocks={sl.blocks}, "
                        f"step={self.steps_done})")
        return True

    def maybe_grow(self, now: float = 0.0) -> bool:
        """Move to a larger preferred geometry when idle blocks allow it.

        A squeezed job (resumed on 1 block mid-burst) would otherwise sit
        on its small slice while the trough frees the machine around it.
        Growing is a checkpoint + free + re-place on the bigger shape —
        the same elastic path as preemption, driven by opportunity instead
        of eviction.  Returns True when the job moved."""
        if self.state != RUNNING:
            return False
        self._now = max(self._now, now)
        here = self.slice._sc                  # machine holding the slice
        free_here = len(here.scheduler.free & here.scheduler.healthy)
        free_elsewhere = max(
            (len(m.scheduler.free & m.scheduler.healthy)
             for m in self.registry if m is not here), default=0)
        held = self.blocks_held
        target = None
        for dims in self.spec.geometries:
            need = _blocks_needed(dims)
            if need <= held:
                break                       # already at best fit
            # growing in place reuses the held blocks; moving to another
            # machine is a full re-place, so only its own free pool counts
            if need <= held + free_here or need <= free_elsewhere:
                target = dims
                break
        if target is None:
            return False
        self._release_slice(save=True)
        self.state = WAITING
        if self.try_start(now, _count_resume=False):
            self.grows += 1
            self.log.append(f"[t={now:8.3f}s] {self.spec.name} tenant grew "
                            f"to {self.slice.dims}")
            return True
        return False

    # -- preemption ----------------------------------------------------------

    def _on_session_event(self, _session, ev: SliceEvent) -> None:
        if ev.kind == "preempt" and not self._in_quantum:
            # between quanta: the trainer is not running, so checkpoint and
            # free right here, inside the requester's notification — by the
            # time `Supercomputer.request_preemption` returns, the blocks
            # are genuinely free
            self._vacate(save=True, reason=ev.detail)
        elif ev.kind == "shrink_request" and not self._in_quantum:
            # partial shrink: hand back blocks WITHOUT vacating — the job
            # checkpoints, re-carves its slice to a smaller preferred
            # geometry in place (during this notification, so the
            # requester's `request_shrink` sees the blocks freed), and
            # keeps training.  Mid-quantum requests are ignored; the
            # requester falls back to full preemption.
            self._shrink_to(ev.blocks_needed)
        elif ev.kind == "lost":
            # block failure with no spare: the slice died under us; the
            # last periodic/preemption checkpoint is the resume point
            self._drop_slice()
            self.state = PREEMPTED
            self.geometry_history.append((self._now, None))
            self.log.append(f"train tenant slice LOST ({ev.detail}); "
                            f"will resume from checkpoint")

    def _shrink_to(self, blocks_needed: int) -> int:
        """Cooperatively shrink onto a smaller preferred geometry, keeping
        the job RUNNING on the same slice.  Checkpoint → close the old
        session (its trainer is compiled for the old shape) → `Slice.shrink`
        in place → fresh session that resumes from the checkpoint on the
        next quantum.  The loss curve continues bitwise-identically: the
        checkpoint carries params + optimizer state + data cursor, and the
        global batch is geometry-independent.  Returns blocks freed."""
        if self.state != RUNNING or self.slice is None:
            return 0
        held = self.blocks_held
        target = shrink_target(self.spec.geometries, held, blocks_needed)
        if target is None:
            return 0                        # already at minimum geometry
        if self.session is not None and self.session.state is not None:
            self.session.trainer.save(self.session.state)
        sl = self.slice
        if self.session is not None:
            self.session.close()
        self.session = None
        sl.shrink(target)
        self.session = sl.train(self.spec.run, None,
                                ckpt_dir=self.spec.ckpt_dir,
                                ckpt_every=self.spec.ckpt_every)
        self.session.add_listener(self._on_session_event)
        self.shrinks += 1
        freed = held - len(sl.blocks)
        self.geometry_history.append((self._now, sl.dims))
        self.log.append(f"[t={self._now:8.3f}s] {self.spec.name} tenant "
                        f"shrank {held}->{len(sl.blocks)} blocks "
                        f"({sl.dims}) at step {self.steps_done}, "
                        f"freed {freed}")
        return freed

    def _drop_slice(self) -> None:
        if self.session is not None:
            self.session.close()
        self.session = None
        self.slice = None

    def _release_slice(self, *, save: bool) -> None:
        """Checkpoint (optionally), detach the session, and free the slice
        — the one release path used by preemption, growth, and completion."""
        if save and self.session is not None \
                and self.session.state is not None:
            self.session.trainer.save(self.session.state)
        sl = self.slice
        self._drop_slice()
        if sl is not None and sl.status == "active":
            sl.free()

    def _vacate(self, *, save: bool, reason: str) -> None:
        self._release_slice(save=save)
        self.preemptions += 1
        self.state = PREEMPTED
        self.geometry_history.append((self._now, None))
        self.log.append(f"[t={self._now:8.3f}s] train tenant preempted at "
                        f"step {self.steps_done} ({reason})")

    # -- the quantum ---------------------------------------------------------

    def run_quantum(self, window_s: float, now: float = 0.0) -> int:
        """Train for one window's worth of virtual time (real steps).

        Honors a mid-quantum preemption request: the trainer checkpoints at
        the step boundary and this method frees the slice before returning.
        Returns the number of steps actually completed."""
        if self.state != RUNNING:
            return 0
        self._now = max(self._now, now)
        target = min(self.spec.target_steps,
                     self.steps_done + self.steps_in(window_s))
        self._in_quantum = True
        try:
            state = self.session.run(target, log_every=self.spec.log_every)
        finally:
            self._in_quantum = False
        gained = state.step - self.steps_done
        self.steps_done = state.step
        if self.session.preempted:
            # trainer already checkpointed inside the loop
            self._vacate(save=False, reason="mid-quantum preempt")
        elif self.steps_done >= self.spec.target_steps:
            self._release_slice(save=True)
            self.state = DONE
            self.geometry_history.append((now, None))
            self.log.append(f"[t={now:8.3f}s] train tenant DONE "
                            f"at step {self.steps_done}")
        return gained


@dataclasses.dataclass
class TenancyReport:
    """What one mixed-workload scenario did to both tenants."""
    arm: str                        # "elastic" | "static"
    windows: int
    window_s: float
    train_steps: int
    train_target: int
    train_frac: float               # steps completed / target (mean over jobs)
    train_preemptions: int
    train_resumes: int
    train_grows: int
    geometry_changes: int           # distinct geometries the job ran on
    geometry_history: List[Any]
    serve: Dict[str, Any]           # merged FleetReport.to_dict()
    deferred_scale_ups: int
    combined_score: float           # train_frac + serve slo_goodput
    log: List[str]
    train_shrinks: int = 0          # cooperative partial shrinks (all jobs)
    per_job: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.pop("log")
        d["geometry_history"] = [[t, list(g) if g else None]
                                 for t, g in self.geometry_history]
        return d


class MixedTenancyDriver:
    """Co-schedule one serving fleet and one elastic training job on one
    `Supercomputer`, reallocating blocks between the tenants over time.

    Per window: (1) the fleet serves the window's arrivals (its autoscaler
    may scale up — with ``preempt_on_allocate`` that eviction reaches the
    training job synchronously), (2) if training is block-less and the
    fleet is not starved, resume it on the largest geometry that fits,
    (3) training runs one quantum of real steps.  Serve and train time
    overlap: they are independent slices of the modeled machine.

    Args:
      service: the serving tenant (its `FleetService` owns the traffic,
        routing, autoscaling, and failure handling).
      train_job: the training tenant.
      window_s: training-quantum window in virtual seconds (how often the
        training tenant catches up with fleet time and placement decisions
        are revisited).
      resume_training: re-place the training job when capacity frees (turn
        off for a static arm whose training never moves).
    """

    def __init__(self, service: "FleetService",
                 train_job: Union[ElasticTrainJob,
                                  Sequence[ElasticTrainJob]],
                 *, window_s: float = 0.5, resume_training: bool = True):
        self.service = service
        jobs = ([train_job] if isinstance(train_job, ElasticTrainJob)
                else list(train_job))
        assert jobs, "need at least one training job"
        self.train_jobs = jobs
        self.train_job = jobs[0]            # primary (legacy accessor)
        self.window_s = window_s
        self.resume_training = resume_training
        self._deferred_seen = 0

    def _boundary(self, t: float) -> None:
        """One co-scheduling decision + training quantum at virtual ``t``.
        With several trainers, placement runs in priority-tier order
        (highest first — the top tier grabs freed blocks before the rest),
        then every RUNNING job gets its quantum."""
        svc = self.service
        starved = (svc.deferred_scale_ups > self._deferred_seen
                   or len(svc.wait) > 0)
        self._deferred_seen = svc.deferred_scale_ups
        by_tier = sorted(self.train_jobs,
                         key=lambda j: -j.spec.priority)
        if self.resume_training and not starved:
            for job in by_tier:
                if job.state in (WAITING, PREEMPTED):
                    job.try_start(now=t)
                else:
                    job.maybe_grow(now=t)
        for job in by_tier:
            job.run_quantum(self.window_s, now=t)

    def run(self, trace: Sequence["FleetRequest"], *,
            fail_plan: Optional[Sequence[Tuple[float, Any]]] = None,
            repair_plan: Optional[Sequence[Tuple[float, Any]]] = None,
            extra_windows: int = 2, arm: str = "elastic") -> TenancyReport:
        """Drive one scenario to completion and report both tenants.

        The whole trace runs through ONE `FleetService.run` (true arrival /
        failure / repair timing, no artificial drain points); training
        quanta fire from the fleet loop's ``on_advance`` hook at every
        ``window_s`` boundary of virtual time.  After the fleet drains, the
        remaining boundaries up to the horizon (+``extra_windows``) run
        training alone — the trough where reclaimed blocks buy steps.
        """
        # key on time only: targets mix ints and strings, which plain tuple
        # sorting would try (and fail) to compare on time ties
        fail_plan = sorted(fail_plan or [], key=lambda f: f[0])
        repair_plan = sorted(repair_plan or [], key=lambda f: f[0])
        horizon = max(
            [r.t_arrival for r in trace]
            + [t for t, _ in fail_plan] + [t for t, _ in repair_plan]
            + [0.0])
        n_windows = int(math.ceil(horizon / self.window_s + 1e-9)) \
            + 1 + extra_windows
        end_t = n_windows * self.window_s
        svc = self.service
        self._deferred_seen = svc.deferred_scale_ups
        next_t = self.window_s

        def on_advance(now: float) -> None:
            nonlocal next_t
            while next_t <= min(now, end_t):
                self._boundary(next_t)
                next_t += self.window_s

        svc.run(trace, fail_plan=fail_plan, repair_plan=repair_plan,
                settle_s=self.window_s, on_advance=on_advance)
        while next_t <= end_t:
            # fleet is drained; let the autoscaler settle (frees finished
            # drains) and give training the leftover machine
            svc.run([], settle_s=self.window_s)
            self._boundary(next_t)
            next_t += self.window_s
        serve_report = svc.report_for(trace)
        jobs = self.train_jobs
        primary = self.train_job
        dims_seen = {g for _, g in primary.geometry_history if g is not None}
        fracs = [j.steps_done / max(1, j.spec.target_steps) for j in jobs]
        train_frac = sum(fracs) / len(fracs)
        combined = round(train_frac + serve_report.slo_goodput, 4)
        per_job = [{
            "name": j.spec.name,
            "priority": j.spec.priority,
            "state": j.state,
            "steps": j.steps_done,
            "target": j.spec.target_steps,
            "frac": round(f, 4),
            "preemptions": j.preemptions,
            "resumes": j.resumes,
            "grows": j.grows,
            "shrinks": j.shrinks,
            "geometry_history": [[t, list(g) if g else None]
                                 for t, g in j.geometry_history],
        } for j, f in zip(jobs, fracs)]
        return TenancyReport(
            arm=arm,
            windows=n_windows,
            window_s=self.window_s,
            train_steps=sum(j.steps_done for j in jobs),
            train_target=sum(j.spec.target_steps for j in jobs),
            train_frac=round(train_frac, 4),
            train_preemptions=sum(j.preemptions for j in jobs),
            train_resumes=sum(j.resumes for j in jobs),
            train_grows=sum(j.grows for j in jobs),
            geometry_changes=len(dims_seen),
            geometry_history=list(primary.geometry_history),
            serve=serve_report.to_dict(),
            deferred_scale_ups=svc.deferred_scale_ups,
            combined_score=combined,
            log=(list(svc.log)
                 + [ln for j in jobs for ln in j.log]),
            train_shrinks=sum(j.shrinks for j in jobs),
            per_job=per_job,
        )
