"""Architecture registry: --arch <id> -> (full config, reduced config, shapes).

Shape skips follow DESIGN.md §Arch-applicability:
  * long_500k only for sub-quadratic archs (ssm / hybrid);
  * all assigned archs have decoders, so decode shapes always run.
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (LM_SHAPES, LONG_500K, ModelConfig, ShapeConfig)

_ARCH_MODULES = {
    "gemma2-9b": "repro.configs.gemma2_9b",
    "olmo-1b": "repro.configs.olmo_1b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "whisper-small": "repro.configs.whisper_small",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "dlrm0": "repro.configs.dlrm0",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if k != "dlrm0")
ALL_ARCHS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return importlib.import_module(_ARCH_MODULES[name]).reduced()


def shapes_for(name: str) -> Tuple[ShapeConfig, ...]:
    """The assigned shape cells for an arch, with documented skips applied."""
    cfg = get_config(name)
    if cfg.family == "dlrm":
        # DLRM has its own training shape (paper Fig 8: global batch scaled
        # with chips; 65536 at 256 chips).
        return (ShapeConfig("dlrm_train", "train", 1, 65536),)
    out: List[ShapeConfig] = []
    for s in LM_SHAPES:
        if s is LONG_500K and not cfg.supports_long_context():
            continue  # documented skip: full-attention arch at 500k context
        out.append(s)
    return tuple(out)


def all_cells() -> List[Tuple[str, ShapeConfig]]:
    """Every (arch, shape) dry-run cell, assigned archs only."""
    cells = []
    for arch in ASSIGNED_ARCHS:
        for s in shapes_for(arch):
            cells.append((arch, s))
    return cells


def skipped_cells() -> List[Tuple[str, str, str]]:
    """(arch, shape, reason) for every documented skip."""
    out = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        if not cfg.supports_long_context():
            out.append((arch, "long_500k",
                        "full-attention arch: 524288-token decode is quadratic"))
    return out
