"""olmo-1b [dense] — 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.

Non-parametric LayerNorm. [arXiv:2402.00838; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    d_ff=8192,
    vocab_size=50304,
    attention=AttentionConfig(
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        rope_theta=10000.0,
    ),
    norm="nonparam_ln",
    act="silu",
    ffn_glu=True,
    tie_embeddings=True,
    max_seq_len=2048,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        max_seq_len=128,
    )
