from repro.configs.base import (AttentionConfig, DLRMConfig,
                                EmbeddingTableConfig, LM_SHAPES, ModelConfig,
                                MoEConfig, OptimizerConfig, ParallelConfig,
                                RunConfig, ShapeConfig, SSMConfig,
                                TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
from repro.configs.registry import (ALL_ARCHS, ASSIGNED_ARCHS, all_cells,
                                    get_config, get_reduced, shapes_for,
                                    skipped_cells)

__all__ = [
    "AttentionConfig", "DLRMConfig", "EmbeddingTableConfig", "LM_SHAPES",
    "ModelConfig", "MoEConfig", "OptimizerConfig", "ParallelConfig",
    "RunConfig", "ShapeConfig", "SSMConfig", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "ALL_ARCHS", "ASSIGNED_ARCHS", "all_cells",
    "get_config", "get_reduced", "shapes_for", "skipped_cells",
]
