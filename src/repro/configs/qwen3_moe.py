"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) vocab=151936.

128 experts, top-8, per-expert d_ff=768. [hf:Qwen/Qwen3-30B-A3B]
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    d_ff=768,                    # per-expert hidden dim
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        rope_theta=1_000_000.0,
    ),
    moe=MoEConfig(
        num_experts=128,
        top_k=8,
        expert_ffw=768,
    ),
    norm="rmsnorm",
    act="silu",
    ffn_glu=True,
    max_seq_len=131072,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3,
        d_model=64,
        d_ff=32,
        vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, expert_ffw=32),
        max_seq_len=128,
    )
