"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.

InternViT + InternLM2 backbone; the ViT frontend is a STUB (input_specs provides
precomputed patch embeddings prepended to the token stream). [arXiv:2404.16821; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92553,
    attention=AttentionConfig(
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=1_000_000.0,
    ),
    vision_prefix=1024,          # stub patch positions per example
    vision_dim=1024,             # InternViT-300M hidden size (projected to d_model)
    norm="rmsnorm",
    act="silu",
    ffn_glu=True,
    max_seq_len=32768,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        vision_prefix=8,
        vision_dim=32,
        max_seq_len=128,
    )
