"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.

Local+global alternating attention, logit softcapping. [arXiv:2408.00118; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    d_ff=14336,
    vocab_size=256000,
    attention=AttentionConfig(
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        logit_softcap=50.0,
        sliding_window=4096,
        global_every=2,          # every 2nd layer is global, others local
        rope_theta=10000.0,
        attn_scale=256 ** -0.5,
    ),
    norm="rmsnorm",
    act="gelu",
    ffn_glu=True,
    tie_embeddings=True,
    final_logit_softcap=30.0,
    post_norm=True,
    embed_scale=True,
    max_seq_len=8192,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(
            num_heads=4, num_kv_heads=2, head_dim=16,
            logit_softcap=50.0, sliding_window=16, global_every=2,
            attn_scale=16 ** -0.5,
        ),
        max_seq_len=128,
    )
