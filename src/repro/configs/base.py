"""Config system for the repro framework.

Every architecture in the assigned pool (plus the paper's own DLRM0) is a
``ModelConfig``.  Configs are plain frozen dataclasses so they hash, compare,
and print cleanly; ``replace`` / ``reduced`` derive smoke-test variants.

Shape points (the four assigned input-shape cells per LM arch) are
``ShapeConfig`` instances; ``repro.configs.registry`` binds archs to shapes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------

# kind: which step function the cell lowers.
#   "train"   -> train_step   (forward + backward + optimizer update)
#   "prefill" -> serve_prefill (forward over full sequence, builds KV cache)
#   "decode"  -> serve_decode  (one new token against a seq_len KV cache/state)
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode"), self.kind


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


# ---------------------------------------------------------------------------
# Attention / block variants
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False            # qwen2
    logit_softcap: Optional[float] = None   # gemma2: 50.0
    # Sliding-window pattern: window size for local layers; None = all global.
    sliding_window: Optional[int] = None
    # every `global_every`-th layer is global; others local (gemma2: 2).
    # 0 means all layers global.
    global_every: int = 0
    rope_theta: float = 10000.0
    # attention logit scale override; None -> 1/sqrt(head_dim)
    attn_scale: Optional[float] = None


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ffw: int                    # per-expert FFN hidden dim
    num_shared_experts: int = 0        # kimi-k2 style shared expert(s)
    shared_ffw: int = 0
    router_softcap: Optional[float] = None
    # first `dense_layers` layers use a dense FFN instead of MoE (deepseek/kimi style)
    dense_layers: int = 0
    dense_ffw: int = 0


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int                     # N (ssm_state)
    head_dim: int = 64                 # P per SSD head
    num_heads: int = 0                 # 0 -> derive: d_inner // head_dim
    expand: int = 2                    # d_inner = expand * d_model
    chunk: int = 256                   # SSD chunk length
    conv_width: int = 4


# ---------------------------------------------------------------------------
# Embedding / DLRM (SparseCore) configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EmbeddingTableConfig:
    name: str
    vocab_size: int
    dim: int
    # average number of categorical values per example (1 = univalent)
    avg_valency: float = 1.0
    max_valency: int = 1
    combiner: str = "sum"              # "sum" | "mean"

    def __post_init__(self):
        assert self.combiner in ("sum", "mean")


@dataclass(frozen=True)
class DLRMConfig:
    tables: Tuple[EmbeddingTableConfig, ...]
    # dense tower
    bottom_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    dense_features: int = 13
    interaction: str = "dot"           # "dot" | "cat"


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm", "dlrm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # one of FAMILIES
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    dlrm: Optional[DLRMConfig] = None

    norm: str = "rmsnorm"              # "rmsnorm" | "layernorm" | "nonparam_ln"
    act: str = "silu"                  # "silu" | "gelu" (glu applied per ffn_glu)
    ffn_glu: bool = True               # gated FFN (SwiGLU/GeGLU)
    tie_embeddings: bool = False
    final_logit_softcap: Optional[float] = None   # gemma2: 30.0
    post_norm: bool = False            # gemma2 post-layer norms
    embed_scale: bool = False          # gemma2 scales embeddings by sqrt(d_model)
    max_seq_len: int = 131072

    # encoder-decoder (whisper): encoder layer count; 0 = decoder-only
    encoder_layers: int = 0
    encoder_seq_reduction: int = 1     # conv frontend downsampling (stubbed)

    # vlm: number of prefix patch positions fed as stub embeddings
    vision_prefix: int = 0
    vision_dim: int = 0

    # hybrid: run attention and SSM in parallel per layer (hymba)
    parallel_heads: bool = False

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # --- derived helpers ------------------------------------------------
    @property
    def head_dim(self) -> int:
        assert self.attention is not None
        return self.attention.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models.counting import param_count
        return param_count(self)

    def active_param_count(self) -> int:
        from repro.models.counting import active_param_count
        return active_param_count(self)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def supports_long_context(self) -> bool:
        """True if decode at 500k context is sub-quadratic (SSM/hybrid/local-attn)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True
        return False

    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder


# ---------------------------------------------------------------------------
# Run-level config (parallelism + training knobs), consumed by launch/*
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    # axis names must match the mesh axes
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: Optional[str] = None     # None on single-pod meshes
    fsdp: bool = True                  # shard params over data axis (ZeRO-3 style)
    zero1: bool = True                 # shard optimizer state over data axis
    tensor_parallel: bool = True       # shard heads/ffn/vocab over model axis
    expert_parallel: bool = True       # shard experts over model axis (MoE)
    sequence_parallel: bool = True     # shard long sequences / KV over model axis
    # Table 3 hyperparameter: activation/weight partitioning dimensionality
    activation_partition: str = "1d"   # "1d" | "2d"
    weight_partition: str = "1d"       # "1d" | "2d"
    pipeline_stages: int = 1           # >1 maps pipeline onto pod axis
    remat: str = "block"               # "none" | "block" | "full"
    grad_compression: str = "none"     # "none" | "int8" | "topk"
    overlap_decomposition: int = 1     # >1: split matmuls to overlap collectives
    use_sparse_embed: bool = True      # SparseCore-style vocab embedding path
    # §Perf: compute the LM loss in sequence chunks so the (tokens x vocab)
    # logits tensor never materialises; lets grad-accumulation drop to 1-2
    # steps and with it the per-microbatch FSDP weight regathers.
    xent_chunk: int = 0                # 0 = off (materialise full logits)
    # §Perf: cast FSDP-gathered weights to bf16 BEFORE the all-gather
    bf16_fsdp_gather: bool = False
    # §Perf: attention implementation. "qchunked" scans a static list of
    # reachable (q-chunk, kv-chunk) pairs: causal skips the upper triangle,
    # static sliding windows keep only the diagonal band.
    attn_impl: str = "blocked"         # "blocked" | "qchunked"
    # §Perf: SparseCore embedding exchange knobs
    emb_wire_bf16: bool = False        # bf16 vectors on the ICI wire
    emb_capacity_factor: float = 2.0   # all-to-all send slot provisioning
    emb_method: str = "auto"           # "auto" | "a2a" | "psum"
    emb_pipeline: bool = True          # fused multi-group pipelined executor


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adam"                 # "adam" | "adafactor" | "sgd"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    state_dtype: str = "float32"       # "bfloat16" for the 1T config
    warmup_steps: int = 100


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
