"""qwen2-7b [dense] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

GQA with QKV bias. [arXiv:2407.10671; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    d_ff=18944,
    vocab_size=152064,
    attention=AttentionConfig(
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    norm="rmsnorm",
    act="silu",
    ffn_glu=True,
    max_seq_len=131072,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3,
        d_model=64,
        d_ff=160,
        vocab_size=512,
        attention=AttentionConfig(
            num_heads=4, num_kv_heads=2, head_dim=16, qkv_bias=True),
        max_seq_len=128,
    )
