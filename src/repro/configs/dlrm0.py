"""dlrm0 — the paper's own production recommendation workload (Figs 8-10, 17).

From the paper: "a DLRM with ~100M dense parameters in fully connected layers,
~20B embedding parameters (~300 features mapped to ~150 tables), and 1-100
average valency per feature" (Fig 8 caption).  Table specs are generated
deterministically with a Zipf-flavoured size distribution so that the totals hit
the paper's numbers: ~150 tables, ~20B embedding parameters, valency 1-100.
"""
from __future__ import annotations

import math

from repro.configs.base import DLRMConfig, EmbeddingTableConfig, ModelConfig

NUM_TABLES = 150
TARGET_EMB_PARAMS = 20_000_000_000


def _table_specs(num_tables: int = NUM_TABLES,
                 target_params: int = TARGET_EMB_PARAMS):
    """Deterministic Zipf-ish table size distribution summing to ~target_params."""
    dims = [32, 64, 96, 128, 192, 256]
    tables = []
    # Zipf weights over table index: a few huge tables, a long small tail —
    # matches production DLRMs (paper §3.3: O(10 MiB) .. O(100 GiB) per table).
    weights = [1.0 / (i + 1) ** 0.85 for i in range(num_tables)]
    wsum = sum(weights)
    for i in range(num_tables):
        dim = dims[(i * 7) % len(dims)]
        params_i = target_params * weights[i] / wsum
        vocab = max(1000, int(params_i / dim))
        # valency 1..100: small frequent tables get multivalent features
        if i % 3 == 0:
            avg_val, max_val = 1.0, 1        # univalent
        elif i % 3 == 1:
            avg_val, max_val = 10.0, 32
        else:
            avg_val, max_val = 100.0, 128
        tables.append(EmbeddingTableConfig(
            name=f"table_{i:03d}",
            vocab_size=vocab,
            dim=dim,
            avg_valency=avg_val,
            max_valency=max_val,
            combiner="sum" if i % 2 == 0 else "mean",
        ))
    return tuple(tables)


def _dense_tower():
    # ~100M dense parameters: sized via the top MLP over the interaction output.
    # bottom: 13 dense features -> 512 -> 512 -> 256
    # top: concat(emb dims sample + bottom) -> 4096 -> 4096 -> 2048 -> 1024 -> 1
    return dict(
        bottom_mlp=(512, 512, 256),
        top_mlp=(4096, 4096, 2048, 1024, 1),
        dense_features=13,
        interaction="cat",
    )


CONFIG = ModelConfig(
    name="dlrm0",
    family="dlrm",
    num_layers=0,
    d_model=256,
    d_ff=0,
    vocab_size=0,
    dlrm=DLRMConfig(tables=_table_specs(), **_dense_tower()),
    norm="layernorm",
    act="gelu",
    ffn_glu=False,
)


def reduced() -> ModelConfig:
    tables = tuple(
        EmbeddingTableConfig(
            name=f"table_{i}",
            vocab_size=64 + 32 * i,
            dim=8,
            avg_valency=[1.0, 4.0, 8.0][i % 3],
            max_valency=[1, 8, 16][i % 3],
            combiner="sum" if i % 2 == 0 else "mean",
        )
        for i in range(6)
    )
    return CONFIG.replace(
        d_model=32,
        dlrm=DLRMConfig(
            tables=tables,
            bottom_mlp=(32, 16),
            top_mlp=(64, 32, 1),
            dense_features=13,
            interaction="cat",
        ),
    )
