"""whisper-small [audio] — 12L enc + 12L dec, d_model=768 12H d_ff=3072 vocab=51865.

Encoder-decoder; conv frontend is a STUB (input_specs provides precomputed frame
embeddings). [arXiv:2212.04356]
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,               # decoder layers
    encoder_layers=12,
    encoder_seq_reduction=2,     # conv frontend stride (stubbed)
    d_model=768,
    d_ff=3072,
    vocab_size=51865,
    attention=AttentionConfig(
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        rope_theta=10000.0,      # we use RoPE in place of learned positions (backbone only)
    ),
    norm="layernorm",
    act="gelu",
    ffn_glu=False,
    tie_embeddings=True,
    max_seq_len=448,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        max_seq_len=128,
    )
