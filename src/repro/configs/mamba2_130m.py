"""mamba2-130m [ssm] — 24L d_model=768 (attention-free) vocab=50280 ssm_state=128.

SSD (state-space duality), chunked scan. [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    d_ff=0,                      # attention-free, no FFN: the SSD block is the mixer
    vocab_size=50280,
    ssm=SSMConfig(
        state_dim=128,
        head_dim=64,             # d_inner = 1536 -> 24 SSD heads
        expand=2,
        chunk=256,
        conv_width=4,
    ),
    norm="rmsnorm",
    act="silu",
    ffn_glu=False,
    tie_embeddings=True,
    max_seq_len=1_048_576,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3,
        d_model=64,
        vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk=16, conv_width=4),
        max_seq_len=2048,
    )
