"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001.

Parallel attention + mamba heads within each layer; sliding-window attention on
most layers with a few global layers; ssm_state=16. [arXiv:2411.13676; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    d_ff=5504,
    vocab_size=32001,
    attention=AttentionConfig(
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        sliding_window=1024,
        global_every=16,         # layers 0, 16 (and the last, handled in-model)
        rope_theta=10000.0,
    ),
    ssm=SSMConfig(
        state_dim=16,
        head_dim=64,
        expand=2,                # d_inner = 3200 -> 50 SSM heads
        chunk=256,
        conv_width=4,
    ),
    parallel_heads=True,
    norm="rmsnorm",
    act="silu",
    ffn_glu=True,
    max_seq_len=8192,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(
            num_heads=4, num_kv_heads=2, head_dim=16,
            sliding_window=16, global_every=2),
        ssm=SSMConfig(state_dim=8, head_dim=16, expand=2, chunk=16, conv_width=4),
        max_seq_len=128,
    )
