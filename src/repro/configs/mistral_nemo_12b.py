"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.

128k context. [hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    d_ff=14336,
    vocab_size=131072,
    attention=AttentionConfig(
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=1_000_000.0,
    ),
    norm="rmsnorm",
    act="silu",
    ffn_glu=True,
    max_seq_len=131072,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        max_seq_len=128,
    )
