"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) vocab=163840.

MoE with 384 experts, top-8, per-expert d_ff=2048, one shared expert, first
layer dense (paper-table trillion-parameter MoE). [arXiv:2501.kimi2]
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    d_ff=2048,                   # per-expert hidden dim
    vocab_size=163840,
    attention=AttentionConfig(
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=50000.0,
    ),
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        expert_ffw=2048,
        num_shared_experts=1,
        shared_ffw=2048,
        dense_layers=1,
        dense_ffw=18432,
    ),
    norm="rmsnorm",
    act="silu",
    ffn_glu=True,
    max_seq_len=131072,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3,
        d_model=64,
        d_ff=32,
        vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, expert_ffw=32,
                      num_shared_experts=1, shared_ffw=32,
                      dense_layers=1, dense_ffw=128),
        max_seq_len=128,
    )
