"""Mamba-2 SSD (state-space duality) block.

Three implementations of the same layer:
  * ``ssd_forward``   — chunked matmul form (training / prefill).  Intra-chunk
    work is attention-like matmuls (MXU-friendly); inter-chunk state passing is
    a ``jax.lax.associative_scan`` so a sequence-sharded (context-parallel)
    layout lowers to a log-depth collective chain instead of a serial loop.
  * ``ssd_step``      — O(1) recurrent decode step.
  * ``ssd_reference`` — naive sequential recurrence (test oracle).

Layout: d_inner = expand*d_model, H heads of P = head_dim, state N, one B/C
group (mamba2 default n_groups=1).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SSMConfig


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = s.num_heads or (d_inner // s.head_dim)
    return d_inner, nheads, s.head_dim, s.state_dim


def ssd_init(cfg: ModelConfig, key, stacked: Optional[int] = None):
    s = cfg.ssm
    d = cfg.d_model
    DI, H, P, N = ssm_dims(cfg)
    conv_dim = DI + 2 * N
    ks = jax.random.split(key, 4)
    L = () if stacked is None else (stacked,)

    def mk(k, din, dout):
        return (jax.random.truncated_normal(k, -2.0, 2.0, L + (din, dout),
                                            jnp.float32) / np.sqrt(din))
    # dt_bias: softplus^-1 of log-spaced dt in [1e-3, 1e-1]
    dt = np.exp(np.linspace(np.log(1e-3), np.log(1e-1), H)).astype(np.float32)
    dt_bias = np.log(np.expm1(dt))
    a_init = np.linspace(1.0, 16.0, H).astype(np.float32)
    return {
        "in_proj": mk(ks[0], d, 2 * DI + 2 * N + H),
        "conv_w": (jax.random.truncated_normal(
            ks[1], -2.0, 2.0, L + (s.conv_width, conv_dim), jnp.float32)
            / np.sqrt(s.conv_width)),
        "conv_b": jnp.zeros(L + (conv_dim,), jnp.float32),
        "A_log": jnp.broadcast_to(jnp.log(jnp.asarray(a_init)), L + (H,)),
        "D": jnp.ones(L + (H,), jnp.float32),
        "dt_bias": jnp.broadcast_to(jnp.asarray(dt_bias), L + (H,)),
        "norm_w": jnp.zeros(L + (DI,), jnp.float32),
        "out_proj": mk(ks[3], DI, d),
    }


def _split_proj(cfg: ModelConfig, proj):
    DI, H, P, N = ssm_dims(cfg)
    z, x, B, C, dt = jnp.split(
        proj, [DI, 2 * DI, 2 * DI + N, 2 * DI + 2 * N], axis=-1)
    return z, x, B, C, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv.  xBC: (B, T, C); w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(W):
        out = out + pad[:, i: i + xBC.shape[1], :].astype(jnp.float32) * w[i]
    return jax.nn.silu(out + b).astype(xBC.dtype)


def _gated_rmsnorm(y, z, w, eps=1e-6):
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32)))


def ssd_forward(cfg: ModelConfig, p, u, *, init_state=None,
                dtype=jnp.bfloat16):
    """u: (B, T, D) -> (out (B,T,D), final ssm state (B,H,P,N), conv tail).

    T must be a multiple of the chunk length after internal padding.
    """
    s = cfg.ssm
    DI, H, P, N = ssm_dims(cfg)
    B_, T, _ = u.shape
    Q = min(s.chunk, T)
    if T % Q:
        padT = Q - T % Q
        u = jnp.pad(u, ((0, 0), (0, padT), (0, 0)))
    else:
        padT = 0
    Tp = u.shape[1]
    nc = Tp // Q

    proj = jnp.einsum("btd,de->bte", u, p["in_proj"].astype(dtype))
    z, x, Bv, Cv, dt = _split_proj(cfg, proj)
    xBC = jnp.concatenate([x, Bv, Cv], axis=-1)
    # raw (pre-conv) tail of the true sequence — the decode conv history
    w1 = s.conv_width - 1
    raw_tail = xBC[:, max(0, T - w1): T, :].astype(jnp.bfloat16)
    if T < w1:
        raw_tail = jnp.pad(raw_tail, ((0, 0), (w1 - T, 0), (0, 0)))
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    x, Bv, Cv = jnp.split(xBC, [DI, DI + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,Tp,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                   # (H,)
    x = x.reshape(B_, Tp, H, P)

    # mask padding so it contributes nothing and carries no decay
    if padT:
        valid = (jnp.arange(Tp) < T)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)

    a = dt * A                                                     # (B,Tp,H) <=0
    ac = a.reshape(B_, nc, Q, H)
    cum = jnp.cumsum(ac, axis=2)                                   # (B,nc,Q,H)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j), i >= j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]            # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    Bc = Bv.reshape(B_, nc, Q, N)
    Cc = Cv.reshape(B_, nc, Q, N)
    xc = x.reshape(B_, nc, Q, H, P)
    dtc = dt.reshape(B_, nc, Q, H)

    CB = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.bfloat16),
                    Bc.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)
    M = CB[..., None] * L                                          # (B,nc,Q,Q,H)
    xdt = xc.astype(jnp.float32) * dtc[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(jnp.bfloat16),
                         xdt.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)

    # chunk-final states
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)                   # (B,nc,Q,H)
    S = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc.astype(jnp.float32),
                   decay_end * dtc, xc.astype(jnp.float32))        # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                        # (B,nc,H)

    def combine(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s1 * d2[..., None, None] + s2

    dA, Sacc = jax.lax.associative_scan(combine, (chunk_decay, S), axis=1)
    # state *before* chunk c (exclusive scan) + init contribution
    before = jnp.concatenate(
        [jnp.zeros_like(Sacc[:, :1]), Sacc[:, :-1]], axis=1)       # (B,nc,H,P,N)
    decay_excl = jnp.concatenate(
        [jnp.ones_like(dA[:, :1]), dA[:, :-1]], axis=1)            # (B,nc,H)
    if init_state is not None:
        before = before + (init_state[:, None].astype(jnp.float32)
                           * decay_excl[..., None, None])
        final_state = (Sacc[:, -1]
                       + init_state.astype(jnp.float32) * dA[:, -1][..., None, None])
    else:
        final_state = Sacc[:, -1]

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc.astype(jnp.float32),
                         before, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B_, Tp, H, P)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B_, Tp, DI)
    y = _gated_rmsnorm(y, z, p["norm_w"])
    out = jnp.einsum("bte,ed->btd", y.astype(dtype), p["out_proj"].astype(dtype))
    if padT:
        out = out[:, :T]
    return out, final_state.astype(jnp.float32), raw_tail


def ssd_step(cfg: ModelConfig, p, u_t, state, conv_state, *,
             dtype=jnp.bfloat16):
    """Single decode step.

    u_t: (B, D); state: (B, H, P, N); conv_state: (B, W-1, conv_dim) raw
    (pre-activation) xBC history.  Returns (out (B,D), state, conv_state).
    """
    s = cfg.ssm
    DI, H, P, N = ssm_dims(cfg)
    proj = jnp.einsum("bd,de->be", u_t, p["in_proj"].astype(dtype))
    z, x, Bv, Cv, dt = _split_proj(cfg, proj)
    xBC = jnp.concatenate([x, Bv, Cv], axis=-1)                    # (B, conv_dim)
    hist = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)  # (B, W, conv)
    conv_out = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), p["conv_w"])
    conv_out = jax.nn.silu(conv_out + p["conv_b"]).astype(dtype)
    new_conv_state = hist[:, 1:, :]
    x, Bv, Cv = jnp.split(conv_out, [DI, DI + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    x = x.reshape(-1, H, P).astype(jnp.float32)
    da = jnp.exp(dt * A)                                           # (B,H)
    state = (state * da[..., None, None]
             + jnp.einsum("bn,bh,bhp->bhpn", Bv.astype(jnp.float32),
                          dt, x))
    y = jnp.einsum("bn,bhpn->bhp", Cv.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * x
    y = y.reshape(-1, DI)
    y = _gated_rmsnorm(y, z, p["norm_w"])
    out = jnp.einsum("be,ed->bd", y.astype(dtype), p["out_proj"].astype(dtype))
    return out, state, new_conv_state


def init_ssm_state(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    DI, H, P, N = ssm_dims(cfg)
    return (jnp.zeros((batch, H, P, N), jnp.float32),
            jnp.zeros((batch, s.conv_width - 1, DI + 2 * N), jnp.bfloat16))


def ssd_reference(cfg: ModelConfig, p, u, *, init_state=None):
    """Naive sequential recurrence — the oracle for ssd_forward/ssd_step."""
    s = cfg.ssm
    DI, H, P, N = ssm_dims(cfg)
    B_, T, _ = u.shape
    proj = jnp.einsum("btd,de->bte", u.astype(jnp.float32), p["in_proj"])
    z, x, Bv, Cv, dt = _split_proj(cfg, proj)
    xBC = jnp.concatenate([x, Bv, Cv], axis=-1)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    x, Bv, Cv = jnp.split(xBC, [DI, DI + N], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    x = x.reshape(B_, T, H, P)
    state = (jnp.zeros((B_, H, P, N), jnp.float32) if init_state is None
             else init_state.astype(jnp.float32))

    def step(state, xs):
        xt, bt, ct, dtt = xs
        da = jnp.exp(dtt * A)                                      # (B,H)
        state = (state * da[..., None, None]
                 + jnp.einsum("bn,bh,bhp->bhpn", bt, dtt, xt))
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    xs = (x.transpose(1, 0, 2, 3), Bv.transpose(1, 0, 2),
          Cv.transpose(1, 0, 2), dt.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3) + p["D"][None, None, :, None] * x
    y = y.reshape(B_, T, DI)
    y = _gated_rmsnorm(y, z, p["norm_w"])
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(jnp.float32))
    return out, state
