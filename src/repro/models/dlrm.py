"""DLRM0 — the paper's production recommendation workload (§3, Figs 8-10).

Sparse stack (SparseCore): EmbeddingCollection lookup with dedup + all-to-all.
Dense stack (TensorCore): bottom MLP over dense features, feature interaction,
top MLP to a single logit.  The SC/TC split is explicit so the sparsecore
timing model (core/sparsecore.py) and the PA-NAS balance search (§4) can
reason about the two sides independently.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.embeddings.engine import EmbeddingCollection
from repro.parallel.context import LOCAL, ParallelContext


def collection_for(cfg: ModelConfig, num_shards: int = 1
                   ) -> EmbeddingCollection:
    # pipeline-v2 layout: locally-resident tables live in one fused
    # descriptor-addressed row space (no per-step re-concatenation)
    return EmbeddingCollection(cfg.dlrm.tables, num_shards,
                               fused_storage=True)


def _mlp_init(key, dims, in_dim):
    params = []
    ks = jax.random.split(key, len(dims))
    prev = in_dim
    for k, h in zip(ks, dims):
        w = (jax.random.truncated_normal(k, -2.0, 2.0, (prev, h), jnp.float32)
             / np.sqrt(prev))
        params.append({"w": w, "b": jnp.zeros((h,), jnp.float32)})
        prev = h
    return params


def _mlp_apply(params, x, final_linear: bool = True):
    n = len(params)
    for i, lyr in enumerate(params):
        x = x @ lyr["w"].astype(x.dtype) + lyr["b"].astype(x.dtype)
        if i < n - 1 or not final_linear:
            x = jax.nn.relu(x)
    return x


def init_params(cfg: ModelConfig, key, num_shards: int = 1) -> Dict[str, Any]:
    d = cfg.dlrm
    coll = collection_for(cfg, num_shards)
    k1, k2, k3 = jax.random.split(key, 3)
    bottom_out = d.bottom_mlp[-1]
    inter_dim = bottom_out + sum(t.dim for t in d.tables)
    return {
        "tables": coll.init(k1),
        "bottom": _mlp_init(k2, d.bottom_mlp, d.dense_features),
        "top": _mlp_init(k3, d.top_mlp, inter_dim),
    }


def sparse_forward(cfg: ModelConfig, p, batch, ctx: ParallelContext = LOCAL,
                   *, coll: Optional[EmbeddingCollection] = None,
                   method: str = "auto", use_kernel: bool = False,
                   fused: Optional[bool] = None, cache=None):
    """SC side: returns concatenated per-table embeddings (B, sum_dims).

    ``fused=None`` follows ``ctx.emb_pipeline`` (default on): one fused
    descriptor-stream launch over the local tables and software-pipelined
    multi-group exchanges for the sharded ones.  ``cache`` threads a
    ``HotIdCache`` (or its arrays) into the a2a path.
    """
    coll = coll or collection_for(cfg, ctx.model_axis_size)
    feats = {t.name: batch[f"cat_{t.name}"] for t in cfg.dlrm.tables}
    emb = coll.lookup(p["tables"], feats, ctx, method=method,
                      use_kernel=use_kernel, fused=fused, cache=cache)
    return jnp.concatenate([emb[t.name].astype(jnp.bfloat16)
                            for t in cfg.dlrm.tables], axis=-1)


def dense_forward(cfg: ModelConfig, p, batch, sparse_vec):
    """TC side: bottom MLP + interaction + top MLP -> logits (B,)."""
    x = batch["dense"].astype(jnp.bfloat16)
    bot = _mlp_apply(p["bottom"], x, final_linear=False)
    inter = jnp.concatenate([bot, sparse_vec], axis=-1)
    logit = _mlp_apply(p["top"], inter, final_linear=True)
    return logit[..., 0].astype(jnp.float32)


def forward(cfg: ModelConfig, p, batch, ctx: ParallelContext = LOCAL,
            *, coll: Optional[EmbeddingCollection] = None,
            method: str = "auto", use_kernel: bool = False,
            fused: Optional[bool] = None, cache=None, **_):
    logits = dense_forward(
        cfg, p, batch,
        sparse_forward(cfg, p, batch, ctx, coll=coll, method=method,
                       use_kernel=use_kernel, fused=fused, cache=cache))
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, p, batch, ctx: ParallelContext = LOCAL,
            *, coll: Optional[EmbeddingCollection] = None,
            method: str = "auto", fused: Optional[bool] = None, cache=None):
    logits, aux = forward(cfg, p, batch, ctx, coll=coll, method=method,
                          fused=fused, cache=cache)
    labels = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, aux


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    sds = jax.ShapeDtypeStruct
    out: Dict[str, Any] = {
        "dense": sds((B, cfg.dlrm.dense_features), jnp.float32),
    }
    for t in cfg.dlrm.tables:
        out[f"cat_{t.name}"] = sds((B, t.max_valency), jnp.int32)
    if shape.kind == "train":
        out["labels"] = sds((B,), jnp.int32)
    return out


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key) -> Dict[str, Any]:
    """Zipf-distributed categorical ids with valency padding (synthetic)."""
    B = shape.global_batch
    ks = jax.random.split(key, len(cfg.dlrm.tables) + 2)
    out = {"dense": jax.random.normal(ks[0], (B, cfg.dlrm.dense_features)),
           "labels": jax.random.bernoulli(
               ks[1], 0.3, (B,)).astype(jnp.int32)}
    for t, k in zip(cfg.dlrm.tables, ks[2:]):
        k1, k2 = jax.random.split(k)
        # approximate zipf: exponential of exponential spread over vocab
        u = jax.random.uniform(k1, (B, t.max_valency), minval=1e-6, maxval=1.0)
        ids = jnp.minimum((u ** 2.0) * t.vocab_size,
                          t.vocab_size - 1).astype(jnp.int32)
        # valency mask: on average avg_valency live slots
        keep_p = min(1.0, t.avg_valency / max(t.max_valency, 1))
        live = jax.random.bernoulli(k2, keep_p, (B, t.max_valency))
        live = live.at[:, 0].set(True)       # at least one value
        out[f"cat_{t.name}"] = jnp.where(live, ids, -1)
    return out
