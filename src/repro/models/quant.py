"""Mixed-precision policy + tile-wise int8 weight storage (the quantized
fast path).

TPU v4's perf/Watt story is "move fewer bits per useful FLOP" (paper §7);
the serving analogue is weight *storage*: decode is HBM-bandwidth-bound, so
streaming 1-byte weights instead of 4-byte ones is a direct bytes/token win.
Two pieces:

  * ``Policy`` — a jmp-style mixed-precision policy (param storage dtype,
    compute dtype, output dtype).  ``cast_to_compute`` is the single choke
    point the hot matmuls use: for plain arrays it is ``astype``; for
    ``QTensor`` leaves it dequantises tile-wise right at the consuming
    einsum, so the full-width copy only ever exists as a fused temporary.
  * ``QTensor`` — int8 values + per-tile float32 scales over the last axis,
    registered as a pytree so quantized param trees flow through the same
    jit'd serve programs (lax.scan over stacked layers included) untouched.

Numerics contract (benchmarks/quantization.py enforces it):
  * storage-only arm: running with ``QTensor`` params is BITWISE identical
    to running with the materialised ``dequantize_params`` tree — on-the-fly
    dequant is an execution strategy, not an approximation;
  * int8-compute arm: quantize->run vs the original full-width weights is
    bounded-divergence (<=1% greedy-token disagreement on the bench traffic).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp

DEFAULT_TILE = 128

# Param-tree keys that are matmul weights consumed through
# ``layers.attention_qkv/attention_out/mlp_apply`` or
# ``transformer.embed_tokens/unembed`` — the only code paths taught to
# dequantise.  Everything else (norm scales, biases, SSM state kernels,
# MoE experts/routers) stays full-width.
QUANT_KEYS = frozenset({"wq", "wk", "wv", "wo", "wg", "wu", "wi",
                        "embed", "head"})
_EXCLUDE = re.compile(r"(^|/)(moe|router|experts?)(/|$)")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QTensor:
    """int8 weight + per-tile fp32 scales over the last axis.

    ``q`` has the logical weight shape; ``scale`` has shape
    ``q.shape[:-1] + (last // tile,)``.  ``w ~= q * scale`` per tile.
    """
    q: jax.Array
    scale: jax.Array
    tile: int = DEFAULT_TILE

    def tree_flatten(self):
        return (self.q, self.scale), (self.tile,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q, scale, aux[0])

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    @property
    def nbytes(self):
        return self.q.nbytes + self.scale.nbytes

    def dequant(self, dtype=jnp.bfloat16):
        lead, last = self.q.shape[:-1], self.q.shape[-1]
        nt = last // self.tile
        r = self.q.reshape(lead + (nt, self.tile)).astype(jnp.float32)
        w = r * self.scale[..., None]
        return w.reshape(self.q.shape).astype(dtype)


def quantize(w: jax.Array, tile: int = DEFAULT_TILE) -> QTensor:
    """Symmetric int8 quantisation, one scale per `tile` of the last axis
    (whole-row tiles when the axis doesn't divide)."""
    last = w.shape[-1]
    if last % tile:
        tile = last
    nt = last // tile
    lead = w.shape[:-1]
    r = w.astype(jnp.float32).reshape(lead + (nt, tile))
    scale = jnp.maximum(jnp.max(jnp.abs(r), axis=-1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(r / scale[..., None]), -127, 127)
    return QTensor(q.reshape(w.shape).astype(jnp.int8), scale, tile)


def cast(w: Any, dtype=jnp.bfloat16):
    """The mixed-precision choke point: dequantise-or-cast to compute dtype."""
    if isinstance(w, QTensor):
        return w.dequant(dtype)
    return w.astype(dtype)


def take(w: Any, ids: jax.Array, dtype=jnp.bfloat16):
    """Row gather for embedding tables: gathers int8 rows + their scales and
    dequantises ONLY the gathered rows (tile-wise), never the full table."""
    if isinstance(w, QTensor):
        rows = QTensor(jnp.take(w.q, ids, axis=0),
                       jnp.take(w.scale, ids, axis=0), w.tile)
        return rows.dequant(dtype)
    return jnp.take(w, ids, axis=0).astype(dtype)


# ---------------------------------------------------------------------------
# jmp-style policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Policy:
    """Mixed-precision policy (jmp idiom): where each tensor class lives.

    ``storage="int8"`` additionally swaps eligible param leaves to
    ``QTensor`` via ``quantize_params``; ``cast_to_compute`` then
    dequantises at the consuming matmul.
    """
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    output_dtype: str = "float32"
    storage: str = "none"              # "none" | "int8"
    tile: int = DEFAULT_TILE

    @classmethod
    def parse(cls, s: str) -> "Policy":
        """``"params=float32,compute=bfloat16,storage=int8"`` (any subset)."""
        kw = {}
        names = {"params": "param_dtype", "compute": "compute_dtype",
                 "output": "output_dtype", "storage": "storage"}
        for part in s.split(","):
            if not part.strip():
                continue
            k, v = part.split("=")
            kw[names[k.strip()]] = v.strip()
        return cls(**kw)

    def _cast(self, tree, dtype_name: str):
        dt = jnp.dtype(dtype_name)

        def one(x):
            if isinstance(x, QTensor):
                return x.dequant(dt)
            if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
                return x.astype(dt)
            return x

        return jax.tree.map(one, tree,
                            is_leaf=lambda x: isinstance(x, QTensor))

    def cast_to_compute(self, tree):
        return self._cast(tree, self.compute_dtype)

    def cast_to_param(self, tree):
        return self._cast(tree, self.param_dtype)

    def cast_to_output(self, tree):
        return self._cast(tree, self.output_dtype)


POLICY_INT8 = Policy(storage="int8")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _eligible(path: str, leaf) -> bool:
    if isinstance(leaf, QTensor) or not hasattr(leaf, "ndim"):
        return False
    if leaf.ndim < 2 or _EXCLUDE.search(path):
        return False
    name = path.rsplit("/", 1)[-1]
    return name in QUANT_KEYS


def quantize_params(cfg, params, policy: Policy = POLICY_INT8):
    """Swap eligible matmul/embedding weights for ``QTensor`` storage.

    Returns ``params`` unchanged for ``storage="none"``.  The result is a
    drop-in argument for every serve program (same tree paths; QTensor
    leaves flatten to (q, scale) pairs so scan/tree_map/jit see ordinary
    arrays).
    """
    if policy.storage == "none":
        return params
    assert policy.storage == "int8", policy.storage

    def one(path, leaf):
        p = _path_str(path)
        if _eligible(p, leaf):
            return quantize(leaf, policy.tile)
        return leaf

    return jax.tree_util.tree_map_with_path(
        one, params, is_leaf=lambda x: isinstance(x, QTensor))


def dequantize_params(params, dtype=jnp.bfloat16):
    """Materialise every QTensor leaf at full width (the bitwise baseline
    arm: running this tree must match running the quantized tree exactly)."""
    return jax.tree.map(
        lambda x: x.dequant(dtype) if isinstance(x, QTensor) else x,
        params, is_leaf=lambda x: isinstance(x, QTensor))


def storage_bytes(tree) -> int:
    """HBM weight-storage footprint (== bytes streamed per decode step for
    a batch of active slots, since decode touches every weight once)."""
    return int(sum(x.nbytes for x in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# int8 KV-cache blocks (consumed inside the paged-decode Pallas kernels)
# ---------------------------------------------------------------------------

def quantize_kv(kv: jax.Array):
    """Per-row KV quantisation: ``kv (..., D) -> (int8 (..., D), f32 (...))``.

    One scale per cache row keeps the in-kernel dequant a single broadcast
    multiply right after the block DMA (the "tile" is the row the kernel
    streams).
    """
    scale = jnp.maximum(jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
