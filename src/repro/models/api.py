"""Family-dispatching model API.

Every architecture exposes the same five functions through this module:
    init_params(cfg, key)                 -> params
    forward(cfg, p, batch, ctx)           -> (logits, aux)        [train]
    prefill(cfg, p, batch, ctx, max_len)  -> (last_logits, cache) [serve]
    decode_step(cfg, p, cache, tokens, ctx) -> (logits, cache)    [serve]
    make_batch(cfg, shape, key) / batch_specs(cfg, shape)         [data]

batch_specs returns ShapeDtypeStructs (no allocation) for the dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as TF
from repro.models import whisper as WH
from repro.parallel.context import LOCAL, ParallelContext


def init_params(cfg: ModelConfig, key, ctx: ParallelContext = LOCAL):
    if cfg.family == "audio":
        return WH.init_params(cfg, key)
    if cfg.family == "dlrm":
        from repro.models import dlrm as DL
        return DL.init_params(cfg, key, num_shards=ctx.model_axis_size)
    return TF.init_params(cfg, key)


def forward(cfg: ModelConfig, p, batch, ctx: ParallelContext = LOCAL, **kw):
    if cfg.family == "audio":
        return WH.forward(cfg, p, batch, ctx, **kw)
    if cfg.family == "dlrm":
        from repro.models import dlrm as DL
        return DL.forward(cfg, p, batch, ctx, **kw)
    return TF.forward(cfg, p, batch, ctx, **kw)


def prefill(cfg: ModelConfig, p, batch, ctx: ParallelContext = LOCAL,
            *, max_len: Optional[int] = None, **kw):
    if cfg.family == "audio":
        return WH.prefill(cfg, p, batch, ctx, max_len=max_len, **kw)
    return TF.prefill(cfg, p, batch, ctx, max_len=max_len, **kw)


def decode_step(cfg: ModelConfig, p, cache, tokens,
                ctx: ParallelContext = LOCAL, **kw):
    if cfg.family == "audio":
        return WH.decode_step(cfg, p, cache, tokens, ctx, **kw)
    return TF.decode_step(cfg, p, cache, tokens, ctx, **kw)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               enc_len: Optional[int] = None):
    if cfg.family == "audio":
        return WH.init_cache(cfg, batch, max_len, enc_len or max_len)
    return TF.init_cache(cfg, batch, max_len)


# -- serve fast path --------------------------------------------------------
# Incremental continuous batching: admit ONE request by prefilling ONLY its
# slot (prefill_slot), then advance every live slot `num_steps` tokens per
# dispatch with per-slot cache lengths (decode_n).  The whisper enc-dec stack
# has its own cache layout and stays on the legacy full-batch path.


def prefill_slot(cfg: ModelConfig, p, batch, cache, slot,
                 ctx: ParallelContext = LOCAL, *,
                 max_len: Optional[int] = None, **kw):
    """Prefill newly admitted request(s) and write their KV/state rows into
    batch rows ``slot`` of the live ``cache`` — active slots are never
    recomputed.  ``batch`` holds n prompts and ``slot`` n slot indices (a
    scalar admits one); a whole admission wave is one dispatch.
    Returns (last_logits (n, V), cache)."""
    if cfg.family == "audio":
        raise NotImplementedError(
            "incremental admission is transformer-cache only; serve whisper "
            "through the legacy full-batch path")
    logits, slot_cache = TF.prefill(cfg, p, batch, ctx, max_len=max_len,
                                    **kw)
    return logits, TF.cache_insert(cache, slot_cache, slot)


def cache_insert(cache, slot_cache, slot):
    return TF.cache_insert(cache, slot_cache, slot)


def decode_n(cfg: ModelConfig, p, cache, tokens, seq_lens, budget,
             ctx: ParallelContext = LOCAL, *, num_steps: int, **kw):
    """Multi-step on-device decode with per-slot lengths/budgets; see
    transformer.decode_n.  Pass ``tables=(B, nb)`` to decode over a pooled
    prefix-shared KV cache (init_kv_pool) instead of per-slot rows."""
    if cfg.family == "audio":
        raise NotImplementedError(
            "decode_n is transformer-cache only; serve whisper through the "
            "legacy per-token path")
    return TF.decode_n(cfg, p, cache, tokens, seq_lens, budget, ctx,
                       num_steps=num_steps, **kw)


# -- pooled prefix-shared KV (serve/kvpool.py block tables) ------------------


def init_kv_pool(cfg: ModelConfig, num_blocks: int, block_size: int, **kw):
    """Pooled KV cache (Ls, NB, bs, KH, hd); dense attention families only;
    see transformer.init_kv_pool."""
    if cfg.family != "dense":
        raise NotImplementedError(
            "pooled prefix-shared KV is dense-transformer only")
    return TF.init_kv_pool(cfg, num_blocks, block_size, **kw)


def prefill_suffix(cfg: ModelConfig, p, cache, tokens, start, valid, tables,
                   ctx: ParallelContext = LOCAL, **kw):
    """Fixed-width suffix prefill over a pooled KV cache: rows resume at
    logical position ``start`` with ``valid`` fresh tokens, KV lands in the
    blocks named by ``tables``; see transformer.prefill_suffix."""
    if cfg.family != "dense":
        raise NotImplementedError(
            "pooled prefix-shared KV is dense-transformer only")
    return TF.prefill_suffix(cfg, p, cache, tokens, start, valid, tables,
                             ctx, **kw)


# ---------------------------------------------------------------------------
# Batches: concrete (smoke/tests) and spec-only (dry-run)
# ---------------------------------------------------------------------------

def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.family == "vlm":
        return max(2, seq_len - cfg.vision_prefix)
    return seq_len


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, T = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    if cfg.family == "dlrm":
        from repro.models import dlrm as DL
        return DL.batch_specs(cfg, shape)
    if shape.kind == "decode":
        return {"tokens": sds((B,), i32)}
    if cfg.family == "audio":
        enc, dec = WH.split_seq(cfg, T)
        out = {"frames": sds((B, enc, cfg.d_model), f32),
               "tokens": sds((B, dec), i32)}
        if shape.kind == "train":
            out["labels"] = sds((B, dec), i32)
        return out
    out = {"tokens": sds((B, _text_len(cfg, T)), i32)}
    if cfg.family == "vlm":
        out["patches"] = sds((B, cfg.vision_prefix, cfg.vision_dim), f32)
    if shape.kind == "train":
        out["labels"] = sds((B, _text_len(cfg, T)), i32)
        if cfg.family == "vlm":
            # labels cover only the text region; prefix is masked in-loss
            pass
    return out


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key) -> Dict[str, Any]:
    """Concrete random batch matching batch_specs."""
    if cfg.family == "dlrm":
        from repro.models import dlrm as DL
        return DL.make_batch(cfg, shape, key)
    specs = batch_specs(cfg, shape)
    ks = jax.random.split(key, len(specs))
    out = {}
    for (name, spec), k in zip(sorted(specs.items()), ks):
        if spec.dtype == jnp.int32:
            hi = max(cfg.vocab_size, 2) if cfg.family != "dlrm" else 2
            out[name] = jax.random.randint(k, spec.shape, 0, hi, jnp.int32)
        else:
            out[name] = jax.random.normal(k, spec.shape, jnp.float32)
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct pytree for the decode cache of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        enc, _ = WH.split_seq(cfg, S)
        fn = lambda: WH.init_cache(cfg, B, S, enc)
    else:
        fn = lambda: TF.init_cache(cfg, B, S)
    return jax.eval_shape(fn)
