"""Analytic parameter counts for MODEL_FLOPS = 6*N*D (§Roofline).

These count *trainable* parameters from the config alone so the roofline's
"useful FLOPs" term never depends on actually materialising weights.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig


def _attn_params(cfg: ModelConfig) -> int:
    a = cfg.attention
    if a is None:
        return 0
    d = cfg.d_model
    q = d * a.num_heads * a.head_dim
    kv = 2 * d * a.num_kv_heads * a.head_dim
    o = a.num_heads * a.head_dim * d
    bias = (a.num_heads + 2 * a.num_kv_heads) * a.head_dim if a.qkv_bias else 0
    return q + kv + o + bias


def _ffn_params(d_model: int, d_ff: int, glu: bool) -> int:
    if d_ff == 0:
        return 0
    n_in = 2 if glu else 1
    return n_in * d_model * d_ff + d_ff * d_model


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    if s is None:
        return 0
    d = cfg.d_model
    d_inner = s.expand * d
    nheads = s.num_heads or (d_inner // s.head_dim)
    # in_proj: [z, x, B, C, dt] (mamba2 fused projection)
    in_proj = d * (2 * d_inner + 2 * s.state_dim + nheads)
    conv = s.conv_width * (d_inner + 2 * s.state_dim)
    extras = 3 * nheads               # A_log, D, dt_bias
    out_proj = d_inner * d
    norm = d_inner                    # gated RMSNorm
    return in_proj + conv + extras + out_proj + norm


def _norm_params(cfg: ModelConfig) -> int:
    if cfg.norm == "nonparam_ln":
        return 0
    scale = cfg.d_model
    if cfg.norm == "layernorm":
        scale *= 2
    return scale


def _moe_layer_params(cfg: ModelConfig) -> int:
    m = cfg.moe
    d = cfg.d_model
    router = d * m.num_experts
    experts = m.num_experts * _ffn_params(d, m.expert_ffw, cfg.ffn_glu)
    shared = m.num_shared_experts * _ffn_params(d, m.shared_ffw, cfg.ffn_glu)
    return router + experts + shared


def _moe_active_layer_params(cfg: ModelConfig) -> int:
    m = cfg.moe
    d = cfg.d_model
    router = d * m.num_experts
    experts = m.top_k * _ffn_params(d, m.expert_ffw, cfg.ffn_glu)
    shared = m.num_shared_experts * _ffn_params(d, m.shared_ffw, cfg.ffn_glu)
    return router + experts + shared


def _decoder_layer_params(cfg: ModelConfig, layer_idx: int, active: bool) -> int:
    p = 0
    n_norms = 2
    if cfg.family in ("dense", "audio", "vlm"):
        p += _attn_params(cfg) + _ffn_params(cfg.d_model, cfg.d_ff, cfg.ffn_glu)
    elif cfg.family == "moe":
        p += _attn_params(cfg)
        if layer_idx < cfg.moe.dense_layers:
            p += _ffn_params(cfg.d_model, cfg.moe.dense_ffw, cfg.ffn_glu)
        else:
            p += (_moe_active_layer_params(cfg) if active
                  else _moe_layer_params(cfg))
    elif cfg.family == "ssm":
        p += _ssm_params(cfg)
        n_norms = 1
    elif cfg.family == "hybrid":
        p += _attn_params(cfg) + _ssm_params(cfg)
        p += _ffn_params(cfg.d_model, cfg.d_ff, cfg.ffn_glu)
    if cfg.post_norm:
        n_norms *= 2
    p += n_norms * _norm_params(cfg)
    return p


def _dlrm_params(cfg: ModelConfig) -> int:
    d = cfg.dlrm
    total = 0
    for t in d.tables:
        total += t.vocab_size * t.dim
    # bottom tower
    prev = d.dense_features
    for h in d.bottom_mlp:
        total += prev * h + h
        prev = h
    # interaction output width (cat): bottom out + sum of table dims
    inter = prev + sum(t.dim for t in d.tables)
    prev = inter
    for h in d.top_mlp:
        total += prev * h + h
        prev = h
    return total


def _dlrm_dense_params(cfg: ModelConfig) -> int:
    d = cfg.dlrm
    total = 0
    prev = d.dense_features
    for h in d.bottom_mlp:
        total += prev * h + h
        prev = h
    inter = prev + sum(t.dim for t in d.tables)
    prev = inter
    for h in d.top_mlp:
        total += prev * h + h
        prev = h
    return total


def param_count(cfg: ModelConfig) -> int:
    if cfg.family == "dlrm":
        return _dlrm_params(cfg)
    total = cfg.vocab_size * cfg.d_model            # token embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model       # LM head
    if cfg.vision_prefix:
        total += cfg.vision_dim * cfg.d_model       # patch projection
    for i in range(cfg.num_layers):
        total += _decoder_layer_params(cfg, i, active=False)
    # encoder stack (whisper): self-attn + ffn per layer, plus decoder cross-attn
    if cfg.encoder_layers:
        enc_layer = _attn_params(cfg) + _ffn_params(cfg.d_model, cfg.d_ff, cfg.ffn_glu)
        enc_layer += 2 * _norm_params(cfg)
        total += cfg.encoder_layers * enc_layer
        total += cfg.num_layers * (_attn_params(cfg) + _norm_params(cfg))  # cross-attn
    total += _norm_params(cfg)                      # final norm
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top_k experts only)."""
    if cfg.family != "moe":
        return param_count(cfg)
    total = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model
    for i in range(cfg.num_layers):
        total += _decoder_layer_params(cfg, i, active=True)
    total += _norm_params(cfg)
    return total


def embedding_param_count(cfg: ModelConfig) -> int:
    if cfg.family == "dlrm":
        return sum(t.vocab_size * t.dim for t in cfg.dlrm.tables)
    return cfg.vocab_size * cfg.d_model
