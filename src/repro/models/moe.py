"""Mixture-of-Experts layer with expert-parallel all-to-all dispatch.

Three dispatch paths share one router:

  * ``moe_local``   — sort-based dispatch, no collectives.  The reference
    implementation and the single-device (smoke-test) path.
  * ``moe_ep``      — shard_map expert parallelism: tokens are exchanged with
    ``lax.all_to_all`` over the model axis (the paper's SparseCore traffic
    pattern — variable-length all-to-all, §3.4), experts live ``E/|model|``
    per shard, expert weights are FSDP-gathered over the data axes.
  * ``moe_decode``  — tiny-token-count path (decode): tokens are replicated
    over the model axis (they are ~KiB), every shard computes its local
    experts at small capacity, partial outputs are psum-merged.

All paths implement *dropping* MoE with a static capacity factor, matching
GSPMD-style production MoE.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.parallel.context import LOCAL, ParallelContext, shard_map

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def moe_init(cfg: ModelConfig, key, stacked: Optional[int] = None):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    L = () if stacked is None else (stacked,)

    def mk(k, *dims):
        return (jax.random.truncated_normal(k, -2.0, 2.0, L + dims,
                                            jnp.float32)
                / np.sqrt(dims[-2]))

    p = {
        "router": mk(ks[0], d, m.num_experts),
        "wo": mk(ks[3], m.num_experts, m.expert_ffw, d),
    }
    if cfg.ffn_glu:
        p["wg"] = mk(ks[1], m.num_experts, d, m.expert_ffw)
        p["wu"] = mk(ks[2], m.num_experts, d, m.expert_ffw)
    else:
        p["wi"] = mk(ks[1], m.num_experts, d, m.expert_ffw)
    if m.num_shared_experts:
        f = m.shared_ffw * m.num_shared_experts
        p["shared"] = {
            "wg": mk(ks[4], d, f),
            "wu": mk(ks[5], d, f),
            "wo": mk(ks[6], f, d),
        }
    return p


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------

def router_topk(cfg: ModelConfig, p, x, dtype=jnp.bfloat16):
    """x: (S, D) -> gates (S, k), expert idx (S, k), aux load-balance loss."""
    m = cfg.moe
    logits = jnp.einsum("sd,de->se", x, p["router"].astype(dtype)
                        ).astype(jnp.float32)
    if m.router_softcap:
        logits = m.router_softcap * jnp.tanh(logits / m.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    me = probs.mean(axis=0)                                   # (E,)
    onehot = jax.nn.one_hot(eidx[:, 0], m.num_experts)        # top-1 fraction
    ce = onehot.mean(axis=0)
    aux = m.num_experts * jnp.sum(me * ce)
    return gates, eidx, aux


def _shared_expert(cfg: ModelConfig, p, x, dtype=jnp.bfloat16):
    g = jnp.einsum("sd,df->sf", x, p["wg"].astype(dtype))
    u = jnp.einsum("sd,df->sf", x, p["wu"].astype(dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("sf,fd->sd", h, p["wo"].astype(dtype))


def _expert_ffn(cfg: ModelConfig, p, buf, dtype=jnp.bfloat16):
    """buf: (E, C, D) -> (E, C, D) with per-expert weights (E, D, F)/(E, F, D)."""
    if cfg.ffn_glu:
        g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dtype)))
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# Local sort-based dispatch (reference / single device)
# ---------------------------------------------------------------------------

def _dispatch_sorted(x, gates, eidx, num_experts: int, capacity: int):
    """Sort-based dropping dispatch.

    x: (S, D); gates/eidx: (S, k).  Returns (buf (E, C, D), combine closure).
    """
    S, D = x.shape
    k = eidx.shape[1]
    flat_e = eidx.reshape(-1)                                  # (S*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    token_of = order // k
    counts = jnp.bincount(flat_e, length=num_experts)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(S * k) - starts[sorted_e]
    keep = pos < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos, num_experts * capacity)
    src = x[token_of] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((num_experts * capacity + 1, x.shape[1]), x.dtype)
    buf = buf.at[slot].set(src, mode="drop",
                           unique_indices=False)
    buf = buf[:-1].reshape(num_experts, capacity, D)
    gate_sorted = gates.reshape(-1)[order]

    def combine(y):                                            # y: (E, C, D)
        y_flat = jnp.concatenate(
            [y.reshape(num_experts * capacity, D),
             jnp.zeros((1, D), y.dtype)], axis=0)
        contrib = (y_flat[slot] * gate_sorted[:, None].astype(y.dtype)
                   * keep[:, None].astype(y.dtype))
        out = jnp.zeros((S, D), y.dtype).at[token_of].add(contrib)
        return out

    dropped = 1.0 - keep.mean()
    return buf, combine, dropped


def capacity_for(tokens: int, m: MoEConfig, factor: float) -> int:
    return max(4, int(math.ceil(tokens * m.top_k * factor / m.num_experts)))


def moe_local(cfg: ModelConfig, p, x, *, capacity_factor: float = 1.25,
              dtype=jnp.bfloat16):
    """x: (S, D) -> (out (S, D), aux loss, dropped fraction)."""
    m = cfg.moe
    S = x.shape[0]
    gates, eidx, aux = router_topk(cfg, p, x, dtype)
    C = capacity_for(S, m, capacity_factor)
    buf, combine, dropped = _dispatch_sorted(x, gates, eidx, m.num_experts, C)
    y = _expert_ffn(cfg, p, buf, dtype)
    out = combine(y)
    if m.num_shared_experts:
        out = out + _shared_expert(cfg, p["shared"], x, dtype)
    return out, aux, dropped


# ---------------------------------------------------------------------------
# Expert-parallel all-to-all dispatch (shard_map)
# ---------------------------------------------------------------------------

def _fsdp_gather(w, axes: Tuple[str, ...], gather_dim: int,
                 bf16: bool = False):
    if bf16 and w.dtype == jnp.float32:
        # cast BEFORE the gather: halves FSDP wire traffic (§Perf)
        w = w.astype(jnp.bfloat16)
    for a in axes:
        w = jax.lax.all_gather(w, a, axis=gather_dim, tiled=True)
    return w


def moe_ep(cfg: ModelConfig, p, x, ctx: ParallelContext, *,
           batch_spec, seq_spec, capacity_factor: float = 1.25,
           dtype=jnp.bfloat16):
    """Expert-parallel MoE over (B, T, D) activations.

    Tokens sharded over (batch_spec, seq_spec); experts sharded over
    ctx.model_axis; expert weights FSDP-sharded on D over ctx.fsdp_axes.
    Emits lax.all_to_all over the model axis — the paper's §3.4 traffic.
    """
    m = cfg.moe
    ES = ctx.model_axis_size
    if ES <= 1 or not ctx.has_mesh:
        B, T, D = x.shape
        out, aux, dropped = moe_local(
            cfg, p, x.reshape(B * T, D),
            capacity_factor=capacity_factor, dtype=dtype)
        return out.reshape(B, T, D), aux, dropped
    E_loc = m.num_experts // ES
    axis = ctx.model_axis
    fsdp_axes = ctx.fsdp_axes
    bf16g = ctx.bf16_fsdp_gather

    B, T, D = x.shape
    # local token count per device (shard_map blocks)
    b_sh = math.prod(ctx.axis_size(a) for a in _as_tuple(batch_spec))
    t_sh = math.prod(ctx.axis_size(a) for a in _as_tuple(seq_spec))
    S_loc = (B // b_sh) * (T // t_sh)
    C_send = capacity_for(S_loc, m, capacity_factor) * E_loc  # per-dest slots
    C_loc = C_send * ES // E_loc                              # per-expert slots

    def local_fn(x_loc, router, wg, wu, wi, wo, shared):
        xs = x_loc.reshape(-1, D)                              # (S_loc, D)
        router = _fsdp_gather(router, fsdp_axes, 0, bf16g)
        gates, eidx, aux = router_topk(cfg, {"router": router}, xs, dtype)
        aux = jax.lax.pmean(aux, axis)

        # ---- forward all-to-all: route (token, k) pairs to expert shards
        flat_e = eidx.reshape(-1)                              # (S_loc*k,)
        dest = flat_e // E_loc
        order = jnp.argsort(dest, stable=True)
        sorted_dest = dest[order]
        token_of = order // m.top_k
        counts = jnp.bincount(dest, length=ES)
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(flat_e.shape[0]) - starts[sorted_dest]
        keep = pos < C_send
        slot = jnp.where(keep, sorted_dest * C_send + pos, ES * C_send)
        src = xs[token_of] * keep[:, None].astype(xs.dtype)
        send = jnp.zeros((ES * C_send + 1, D), xs.dtype).at[slot].set(
            src, mode="drop")[:-1]
        send_eloc = jnp.full((ES * C_send + 1,), E_loc, jnp.int32).at[slot].set(
            (flat_e[order] % E_loc).astype(jnp.int32), mode="drop")[:-1]
        # exchange: recv[j] = block sent to me by shard j
        recv = jax.lax.all_to_all(
            send.reshape(ES, C_send, D), axis, 0, 0, tiled=False)
        recv_eloc = jax.lax.all_to_all(
            send_eloc.reshape(ES, C_send), axis, 0, 0, tiled=False)

        # ---- local dispatch to E_loc experts
        r_flat = recv.reshape(ES * C_send, D)
        re = recv_eloc.reshape(ES * C_send)
        order2 = jnp.argsort(re, stable=True)
        sorted_e2 = re[order2]
        counts2 = jnp.bincount(re, length=E_loc + 1)[:E_loc]
        starts2 = jnp.concatenate(
            [jnp.zeros((1,), counts2.dtype), jnp.cumsum(counts2)[:-1]])
        pos2 = jnp.arange(re.shape[0]) - starts2[
            jnp.minimum(sorted_e2, E_loc - 1)]
        keep2 = (pos2 < C_loc) & (sorted_e2 < E_loc)
        slot2 = jnp.where(keep2, sorted_e2 * C_loc + pos2, E_loc * C_loc)
        buf = jnp.zeros((E_loc * C_loc + 1, D), xs.dtype).at[slot2].set(
            r_flat[order2] * keep2[:, None].astype(xs.dtype), mode="drop")[:-1]
        buf = buf.reshape(E_loc, C_loc, D)

        # ---- expert FFN with FSDP-gathered weights
        wloc = {}
        for name, w in (("wg", wg), ("wu", wu), ("wi", wi)):
            if w is not None:
                wloc[name] = _fsdp_gather(w, fsdp_axes, 1, bf16g)
        wloc["wo"] = _fsdp_gather(wo, fsdp_axes, 2, bf16g)
        y = _expert_ffn(cfg, wloc, buf, dtype)                 # (E_loc, C_loc, D)

        # ---- reverse path
        y_flat = jnp.concatenate(
            [y.reshape(E_loc * C_loc, D), jnp.zeros((1, D), y.dtype)], 0)
        y_sorted = y_flat[slot2] * keep2[:, None].astype(y.dtype)
        y_recv_order = jnp.zeros((ES * C_send, D), y.dtype).at[order2].set(
            y_sorted)
        y_back = jax.lax.all_to_all(
            y_recv_order.reshape(ES, C_send, D), axis, 0, 0, tiled=False)
        yb_flat = jnp.concatenate(
            [y_back.reshape(ES * C_send, D), jnp.zeros((1, D), y.dtype)], 0)
        gate_sorted = gates.reshape(-1)[order]
        contrib = (yb_flat[slot] * gate_sorted[:, None].astype(y.dtype)
                   * keep[:, None].astype(y.dtype))
        out = jnp.zeros((xs.shape[0], D), y.dtype).at[token_of].add(contrib)

        if m.num_shared_experts:
            sh = {k2: _fsdp_gather(v, fsdp_axes, 1 if k2 == "wo" else 0,
                                   bf16g)
                  for k2, v in shared.items()}
            out = out + _shared_expert(cfg, sh, xs, dtype)
        dropped = jax.lax.pmean(1.0 - keep.mean(), axis)
        return out.reshape(x_loc.shape), aux, dropped

    fs = tuple(fsdp_axes) if fsdp_axes else None
    w_specs = dict(
        router=P(fs, None),
        wg=P(axis, fs, None), wu=P(axis, fs, None), wi=P(axis, fs, None),
        wo=P(axis, None, fs),
        shared={"wg": P(fs, None), "wu": P(fs, None), "wo": P(None, fs)},
    )
    args = dict(
        router=p["router"],
        wg=p.get("wg"), wu=p.get("wu"), wi=p.get("wi"), wo=p["wo"],
        shared=p.get("shared", {"wg": None, "wu": None, "wo": None}),
    )
    in_specs = (P(batch_spec, seq_spec, None),
                w_specs["router"], w_specs["wg"], w_specs["wu"],
                w_specs["wi"], w_specs["wo"],
                {"wg": w_specs["shared"]["wg"], "wu": w_specs["shared"]["wu"],
                 "wo": w_specs["shared"]["wo"]})
    out_specs = (P(batch_spec, seq_spec, None), P(), P())
    fn = shard_map(local_fn, mesh=ctx.mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(x, args["router"], args["wg"], args["wu"], args["wi"],
              args["wo"], args["shared"])


def _as_tuple(spec):
    if spec is None:
        return ()
    if isinstance(spec, tuple):
        return spec
    return (spec,)


# ---------------------------------------------------------------------------
# Decode (tiny token count) path
# ---------------------------------------------------------------------------

def moe_decode(cfg: ModelConfig, p, x, ctx: ParallelContext, *,
               batch_spec, capacity_factor: float = 2.0,
               dtype=jnp.bfloat16):
    """x: (B, 1, D) with tiny B·1 — replicate tokens over model axis,
    compute local experts at small capacity, psum partial outputs."""
    m = cfg.moe
    ES = ctx.model_axis_size
    if ES <= 1 or not ctx.has_mesh:
        B, T, D = x.shape
        out, aux, dropped = moe_local(
            cfg, p, x.reshape(B * T, D),
            capacity_factor=capacity_factor, dtype=dtype)
        return out.reshape(B, T, D), aux, dropped
    axis = ctx.model_axis
    E_loc = m.num_experts // ES
    fsdp_axes = ctx.fsdp_axes
    B, T, D = x.shape
    b_sh = math.prod(ctx.axis_size(a) for a in _as_tuple(batch_spec))
    S_loc = (B // b_sh) * T
    C = capacity_for(max(S_loc, 1), m, capacity_factor) * ES

    def local_fn(x_loc, router, wg, wu, wi, wo, shared):
        xs = x_loc.reshape(-1, D)
        router = _fsdp_gather(router, fsdp_axes, 0,
                              ctx.bf16_fsdp_gather)
        gates, eidx, aux = router_topk(cfg, {"router": router}, xs, dtype)
        aux = jax.lax.pmean(aux, axis)
        my_shard = jax.lax.axis_index(axis)
        # keep only (token, k) pairs routed to my local experts
        local_mask = (eidx // E_loc) == my_shard
        local_e = jnp.where(local_mask, eidx % E_loc, E_loc)
        gates_m = jnp.where(local_mask, gates, 0.0)
        flat_e = local_e.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        token_of = order // m.top_k
        counts = jnp.bincount(flat_e, length=E_loc + 1)[:E_loc]
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(flat_e.shape[0]) - starts[
            jnp.minimum(sorted_e, E_loc - 1)]
        keep = (pos < C) & (sorted_e < E_loc)
        slot = jnp.where(keep, sorted_e * C + pos, E_loc * C)
        buf = jnp.zeros((E_loc * C + 1, D), xs.dtype).at[slot].set(
            xs[token_of] * keep[:, None].astype(xs.dtype), mode="drop")[:-1]
        buf = buf.reshape(E_loc, C, D)
        wloc = {}
        for name, w in (("wg", wg), ("wu", wu), ("wi", wi)):
            if w is not None:
                wloc[name] = _fsdp_gather(w, fsdp_axes, 1,
                                          ctx.bf16_fsdp_gather)
        wloc["wo"] = _fsdp_gather(wo, fsdp_axes, 2, ctx.bf16_fsdp_gather)
        y = _expert_ffn(cfg, wloc, buf, dtype)
        y_flat = jnp.concatenate(
            [y.reshape(E_loc * C, D), jnp.zeros((1, D), y.dtype)], 0)
        gate_sorted = gates_m.reshape(-1)[order]
        contrib = (y_flat[slot] * gate_sorted[:, None].astype(y.dtype)
                   * keep[:, None].astype(y.dtype))
        out = jnp.zeros((xs.shape[0], D), y.dtype).at[token_of].add(contrib)
        out = jax.lax.psum(out, axis)
        if m.num_shared_experts:
            sh = {k2: _fsdp_gather(v, fsdp_axes, 1 if k2 == "wo" else 0,
                                   ctx.bf16_fsdp_gather)
                  for k2, v in shared.items()}
            out = out + _shared_expert(cfg, sh, xs, dtype)
        return out.reshape(x_loc.shape), aux, jnp.zeros((), jnp.float32)

    fs = tuple(fsdp_axes) if fsdp_axes else None
    in_specs = (P(batch_spec, None, None),
                P(fs, None),
                P(axis, fs, None), P(axis, fs, None), P(axis, fs, None),
                P(axis, None, fs),
                {"wg": P(fs, None), "wu": P(fs, None), "wo": P(None, fs)})
    out_specs = (P(batch_spec, None, None), P(), P())
    fn = shard_map(local_fn, mesh=ctx.mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    shared = p.get("shared", {"wg": None, "wu": None, "wo": None})
    return fn(x, p["router"], p.get("wg"), p.get("wu"), p.get("wi"),
              p["wo"], shared)
