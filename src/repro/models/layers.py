"""Shared model layers: norms, RoPE, blocked (online-softmax) attention, MLP.

All code is mesh-agnostic pure JAX; sharding is applied from outside via
parameter PartitionSpecs + activation constraints (parallel/sharding.py).
Attention is *blocked* — a lax.scan over KV chunks with an online softmax —
so the T×S logits tensor never materialises (required for the 32k prefill and
500k decode shapes).  A Pallas flash-attention kernel (kernels/flash_attention)
is the TPU fast path for the same computation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionConfig, ModelConfig
from repro.models import quant as Q
from repro.parallel.context import active_ctx, hint

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, *out_dims: int, dtype=jnp.float32):
    """Truncated-normal fan-in init, matching common LM practice."""
    shape = (in_dim,) + tuple(out_dims)
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def nonparam_ln(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no scale, no bias)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm_init(cfg: ModelConfig, key, stacked: Optional[int] = None):
    d = cfg.d_model
    shape = (d,) if stacked is None else (stacked, d)
    if cfg.norm == "nonparam_ln":
        return {}
    if cfg.norm == "layernorm":
        return {"w": jnp.ones(shape, jnp.float32),
                "b": jnp.zeros(shape, jnp.float32)}
    return {"w": jnp.zeros(shape, jnp.float32)}   # rmsnorm: stored as (w-1)


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "nonparam_ln":
        return nonparam_ln(x)
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


# ---------------------------------------------------------------------------
# RoPE (NeoX half-rotation convention)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32)
                     / (head_dim // 2))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., T, d/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., T, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked online-softmax attention
# ---------------------------------------------------------------------------

def _mask_block(q_pos, kv_pos, causal, window):
    """(Tq, Tk) bool allow-mask. window: None or traced scalar (tokens)."""
    allow = kv_pos[None, :] >= 0                        # padding slots use -1
    if causal:
        allow &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        allow &= (q_pos[:, None] - kv_pos[None, :]) < window
    return allow


def _heads_shardable(kh: int) -> bool:
    ctx = active_ctx()
    if ctx is None:
        return True
    ms = ctx.model_axis_size
    return ms <= 1 or kh % ms == 0


def blocked_attention(q, k, v, q_pos, kv_pos, *,
                      causal: bool = True,
                      window=None,
                      softcap: Optional[float] = None,
                      scale: Optional[float] = None,
                      kv_chunk: int = 1024):
    """Online-softmax attention, scanning KV in chunks.

    q: (B, Tq, H, D)    k, v: (B, S, KH, D)   (GQA: H % KH == 0)
    q_pos: (B, Tq) int32; kv_pos: (B, S) int32 (-1 marks invalid slots).
    window may be a python int, None, or a traced scalar (per-layer choice).
    Returns (B, Tq, H, D).
    """
    B, Tq, H, D = q.shape
    S, KH = k.shape[1], k.shape[2]
    G = H // KH
    if scale is None:
        scale = D ** -0.5
    ck = min(kv_chunk, S)
    if S % ck:
        pad = ck - S % ck
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        S += pad
    nc = S // ck

    # When kv heads don't divide the model axis, shard the QUERY TIME dim
    # over it instead (context-parallel attention): carries stay T-sharded
    # and the chunk loop needs no per-iteration resharding (§Perf).
    t_role = None if _heads_shardable(KH) else "model"
    h_role = "heads" if _heads_shardable(KH) else None
    qr = (q.reshape(B, Tq, KH, G, D) * scale).astype(jnp.bfloat16)
    qr = hint(qr, "batch", t_role, h_role, None, None)
    # chunk-major layout for scan
    kc = k.reshape(B, nc, ck, KH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, ck, KH, D).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(B, nc, ck).transpose(1, 0, 2)
    kc = hint(kc, None, "batch", None, h_role, None)
    vc = hint(vc, None, "batch", None, h_role, None)

    m0 = hint(jnp.full((B, Tq, KH, G), NEG_INF, jnp.float32),
              "batch", t_role, h_role, None)
    l0 = hint(jnp.zeros((B, Tq, KH, G), jnp.float32),
              "batch", t_role, h_role, None)
    a0 = hint(jnp.zeros((B, Tq, KH, G, D), jnp.float32),
              "batch", t_role, h_role, None, None)

    if window is not None:
        window = jnp.asarray(window, jnp.int32)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs                                  # (B,ck,KH,D), (B,ck)
        s = jnp.einsum("btkgd,bckd->btkgc", qr, kb.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        allow = jax.vmap(
            lambda qp, kp: _mask_block(qp, kp, causal, window))(q_pos, pb)
        allow = allow[:, :, None, None, :]               # (B,Tq,1,1,ck)
        s = jnp.where(allow, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None]) * allow        # kill fully-masked rows
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "btkgc,bckd->btkgd", p.astype(jnp.bfloat16),
            vb.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
        m_new = hint(m_new, "batch", t_role, h_role, None)
        l_new = hint(l_new, "batch", t_role, h_role, None)
        acc_new = hint(acc_new, "batch", t_role, h_role, None, None)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Tq, H, D).astype(q.dtype)


def blocked_attention_qchunked(q, k, v, q_pos, kv_pos, *,
                               causal: bool = True,
                               window: Optional[int] = None,
                               softcap: Optional[float] = None,
                               scale: Optional[float] = None,
                               q_chunk: int = 2048, kv_chunk: int = 1024):
    """§Perf variant of blocked_attention: q is chunked too, and the scan
    runs over a STATIC list of reachable (q-chunk, kv-chunk) pairs — causal
    masking skips the upper triangle entirely (2x fewer FLOPs) and a static
    sliding window keeps only the diagonal band (window/T of the work).

    ``window`` must be a python int here (static pair pruning); the layer
    scan regroups local/global layers so each gets a static window
    (transformer.attn_group_size).  The online-softmax merge is associative,
    so pair order doesn't matter.
    """
    B, Tq, H, D = q.shape
    S, KH = k.shape[1], k.shape[2]
    G = H // KH
    if scale is None:
        scale = D ** -0.5
    cq = min(q_chunk, Tq)
    ck = min(kv_chunk, S)
    assert Tq % cq == 0 and S % ck == 0, (Tq, cq, S, ck)
    nq, nk = Tq // cq, S // ck

    # static reachable-pair list (assumes aligned layouts: q chunk i covers
    # positions [i*cq, (i+1)*cq) — true for training/prefill)
    pairs = []
    for i in range(nq):
        qlo, qhi = i * cq, (i + 1) * cq - 1
        for j in range(nk):
            klo, khi = j * ck, (j + 1) * ck - 1
            if causal and klo > qhi:
                continue
            if window is not None and (qlo - khi) >= window:
                continue
            pairs.append((i, j))
    pair_arr = jnp.asarray(pairs, jnp.int32)

    t_role = None if _heads_shardable(KH) else "model"
    h_role = "heads" if _heads_shardable(KH) else None
    qr = (q.reshape(B, nq, cq, KH, G, D) * scale).astype(jnp.bfloat16)
    qr = qr.transpose(1, 0, 2, 3, 4, 5)              # (nq, B, cq, KH, G, D)
    qp = q_pos.reshape(B, nq, cq).transpose(1, 0, 2)
    kc = k.reshape(B, nk, ck, KH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ck, KH, D).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(B, nk, ck).transpose(1, 0, 2)
    qr = hint(qr, None, "batch", t_role, h_role, None, None)
    kc = hint(kc, None, "batch", None, h_role, None)
    vc = hint(vc, None, "batch", None, h_role, None)

    m0 = hint(jnp.full((nq, B, cq, KH, G), NEG_INF, jnp.float32),
              None, "batch", t_role, h_role, None)
    l0 = hint(jnp.zeros((nq, B, cq, KH, G), jnp.float32),
              None, "batch", t_role, h_role, None)
    a0 = hint(jnp.zeros((nq, B, cq, KH, G, D), jnp.float32),
              None, "batch", t_role, h_role, None, None)

    def body(carry, ij):
        m, l, acc = carry
        i, j = ij[0], ij[1]
        qb = jax.lax.dynamic_index_in_dim(qr, i, 0, keepdims=False)
        qpb = jax.lax.dynamic_index_in_dim(qp, i, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kc, j, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vc, j, 0, keepdims=False)
        pb = jax.lax.dynamic_index_in_dim(pc, j, 0, keepdims=False)
        s = jnp.einsum("btkgd,bckd->btkgc", qb, kb.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        allow = jax.vmap(
            lambda a_, b_: _mask_block(a_, b_, causal, window))(qpb, pb)
        allow = allow[:, :, None, None, :]
        s = jnp.where(allow, s, NEG_INF)
        m_i = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None]) * allow
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum(axis=-1)
        a_new = a_i * alpha[..., None] + jnp.einsum(
            "btkgc,bckd->btkgd", p.astype(jnp.bfloat16),
            vb.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), pair_arr)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tq, H, D)
    return out.astype(q.dtype)


def reference_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                        softcap=None, scale=None):
    """Unblocked oracle for tests (materialises the full logits tensor)."""
    B, Tq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    if scale is None:
        scale = D ** -0.5
    qr = q.reshape(B, Tq, KH, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("btkgd,bskd->btkgs", qr, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    allow = jax.vmap(
        lambda qp, kp: _mask_block(qp, kp, causal, window))(q_pos, kv_pos)
    s = jnp.where(allow[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("btkgs,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Tq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (GQA, optional cross-attention, optional KV cache)
# ---------------------------------------------------------------------------

def attention_init(cfg: ModelConfig, key, stacked: Optional[int] = None,
                   cross: bool = False):
    a = cfg.attention
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    L = () if stacked is None else (stacked,)

    def mk(k, *dims):
        full = L + dims
        flat = jax.random.truncated_normal(
            k, -2.0, 2.0, full, jnp.float32) / np.sqrt(dims[0])
        return flat

    p = {
        "wq": mk(ks[0], d, a.num_heads, a.head_dim),
        "wk": mk(ks[1], d, a.num_kv_heads, a.head_dim),
        "wv": mk(ks[2], d, a.num_kv_heads, a.head_dim),
        "wo": mk(ks[3], a.num_heads * a.head_dim, d),
    }
    if a.qkv_bias and not cross:
        p["bq"] = jnp.zeros(L + (a.num_heads, a.head_dim), jnp.float32)
        p["bk"] = jnp.zeros(L + (a.num_kv_heads, a.head_dim), jnp.float32)
        p["bv"] = jnp.zeros(L + (a.num_kv_heads, a.head_dim), jnp.float32)
    return p


def attention_qkv(p, x, a: AttentionConfig, positions, *, rope: bool = True,
                  dtype=jnp.bfloat16):
    """Project to q, k, v and apply RoPE.  x: (B, T, D)."""
    q = jnp.einsum("btd,dhk->bthk", x, Q.cast(p["wq"], dtype))
    k = jnp.einsum("btd,dhk->bthk", x, Q.cast(p["wk"], dtype))
    v = jnp.einsum("btd,dhk->bthk", x, Q.cast(p["wv"], dtype))
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    if rope:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    q = hint(q, "batch", None, "heads", None)
    k = hint(k, "batch", None, "heads", None)
    v = hint(v, "batch", None, "heads", None)
    return q, k, v


def attention_out(p, o, dtype=jnp.bfloat16):
    B, T, H, D = o.shape
    return jnp.einsum("bthk,hkd->btd",
                      o.astype(dtype),
                      Q.cast(p["wo"], dtype).reshape(H, D, -1))


def self_attention(p, x, a: AttentionConfig, positions, *,
                   causal: bool = True, window=None, kv_chunk: int = 1024,
                   dtype=jnp.bfloat16):
    q, k, v = attention_qkv(p, x, a, positions, dtype=dtype)
    o = blocked_attention(q, k, v, positions, positions, causal=causal,
                          window=window, softcap=a.logit_softcap,
                          scale=a.attn_scale, kv_chunk=kv_chunk)
    return attention_out(p, o, dtype=dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, key, d_ff: Optional[int] = None,
             stacked: Optional[int] = None):
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    ks = jax.random.split(key, 3)
    L = () if stacked is None else (stacked,)

    def mk(k, din, dout):
        return (jax.random.truncated_normal(k, -2.0, 2.0, L + (din, dout),
                                            jnp.float32) / np.sqrt(din))
    p = {"wo": mk(ks[2], f, d)}
    if cfg.ffn_glu:
        p["wg"] = mk(ks[0], d, f)
        p["wu"] = mk(ks[1], d, f)
    else:
        p["wi"] = mk(ks[0], d, f)
    return p


def _act(name: str, x):
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def mlp_apply(cfg: ModelConfig, p, x, dtype=jnp.bfloat16):
    if cfg.ffn_glu:
        g = jnp.einsum("btd,df->btf", x, Q.cast(p["wg"], dtype))
        u = jnp.einsum("btd,df->btf", x, Q.cast(p["wu"], dtype))
        h = _act(cfg.act, g) * u
    else:
        h = _act(cfg.act, jnp.einsum("btd,df->btf", x, Q.cast(p["wi"], dtype)))
    h = hint(h, "batch", None, "model")
    return jnp.einsum("btf,fd->btd", h, Q.cast(p["wo"], dtype))


# ---------------------------------------------------------------------------
# softcap
# ---------------------------------------------------------------------------

def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
