"""`repro.models` — the model zoo behind one family-dispatching API.

``repro.models.api`` is the public surface: every family (dense, MoE, SSM,
hybrid, audio, VLM, DLRM) answers the same init/forward/prefill/decode
calls.  Family modules (`transformer`, `whisper`, `dlrm`, ...) stay
importable for tests that poke internals.
"""
from repro.models import api
from repro.models.api import (batch_specs, cache_insert, cache_specs,
                              decode_n, decode_step, forward, init_cache,
                              init_params, make_batch, prefill, prefill_slot)

__all__ = [
    "api", "batch_specs", "cache_insert", "cache_specs", "decode_n",
    "decode_step", "forward", "init_cache", "init_params", "make_batch",
    "prefill", "prefill_slot",
]
