"""Unified decoder stack for the assigned LM families.

Families handled here: dense (gemma2/olmo/qwen2/mistral-nemo), moe (kimi-k2,
qwen3-moe), ssm (mamba2), hybrid (hymba), vlm (internvl2 — stub patch
embeddings prepended).  whisper (enc-dec) wraps this in models/whisper.py.

Design notes
  * Layers are stacked and executed with ``jax.lax.scan`` so the lowered HLO
    is one layer body + a loop — essential to keep 512-device dry-run compiles
    tractable and matches production JAX LM frameworks.
  * Heterogeneous layers (gemma2 local/global alternation, hymba's sparse
    global layers) are expressed with per-layer *data* (window sizes as an
    int32 array scanned as xs), never per-layer Python branches.
  * MoE layers with a dense prefix (kimi-k2) unroll the prefix outside the
    scan and scan the uniform MoE remainder.
  * The KV cache is stacked over layers, scanned as xs/ys.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import quant as Q
from repro.models import ssm as SSM
from repro.parallel.context import LOCAL, ParallelContext, hint

GLOBAL_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# per-layer window schedule
# ---------------------------------------------------------------------------

def window_schedule(cfg: ModelConfig) -> np.ndarray:
    """int32 (num_layers,): attention window per layer (GLOBAL_WINDOW = full)."""
    a = cfg.attention
    n = cfg.num_layers
    if a is None:
        return np.full((n,), GLOBAL_WINDOW, np.int32)
    if a.sliding_window is None or a.global_every == 0:
        return np.full((n,), GLOBAL_WINDOW, np.int32)
    win = np.full((n,), a.sliding_window, np.int32)
    for l in range(n):
        if l % a.global_every == a.global_every - 1:
            win[l] = GLOBAL_WINDOW
    return win


def num_moe_layers(cfg: ModelConfig) -> int:
    return cfg.num_layers - (cfg.moe.dense_layers if cfg.moe else 0)


def attn_group_size(cfg: ModelConfig) -> int:
    """Layer-group size for static-window scanning (§Perf qchunked path):
    the local/global pattern repeats every `global_every` layers, so scanning
    groups of that size gives every position a STATIC window."""
    a = cfg.attention
    n = num_moe_layers(cfg) if cfg.family == "moe" else cfg.num_layers
    if (a and a.sliding_window and a.global_every > 0
            and n % a.global_every == 0):
        return a.global_every
    return 1


def can_qchunk(cfg: ModelConfig) -> bool:
    """qchunked attention needs static windows: either no sliding windows at
    all, or a local/global pattern that tiles the stack exactly."""
    a = cfg.attention
    if a is None:
        return True
    if a.sliding_window is None or a.global_every == 0:
        return True
    n = num_moe_layers(cfg) if cfg.family == "moe" else cfg.num_layers
    return n % a.global_every == 0


def static_window_for(cfg: ModelConfig, idx_in_group: int, group: int):
    a = cfg.attention
    if a is None or a.sliding_window is None or a.global_every == 0:
        return None
    if group == 1:
        return None
    return None if idx_in_group == group - 1 else a.sliding_window


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 16)
    p: Dict[str, Any] = {
        "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": L.norm_init(cfg, keys[1]),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(keys[2], cfg.d_model, cfg.vocab_size)
    if cfg.vision_prefix:
        p["vision_proj"] = L.dense_init(keys[3], cfg.vision_dim, cfg.d_model)

    n_scan = num_moe_layers(cfg) if cfg.family == "moe" else cfg.num_layers
    lp: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        lp["ln1"] = L.norm_init(cfg, keys[4], stacked=n_scan)
        lp["attn"] = L.attention_init(cfg, keys[5], stacked=n_scan)
        lp["ln2"] = L.norm_init(cfg, keys[6], stacked=n_scan)
        if cfg.post_norm:
            lp["post_ln1"] = L.norm_init(cfg, keys[7], stacked=n_scan)
            lp["post_ln2"] = L.norm_init(cfg, keys[8], stacked=n_scan)
    if cfg.family in ("dense", "vlm", "hybrid"):
        lp["mlp"] = L.mlp_init(cfg, keys[9], stacked=n_scan)
    if cfg.family == "moe":
        lp["moe"] = MOE.moe_init(cfg, keys[9], stacked=n_scan)
    if cfg.family == "ssm":
        lp["ln1"] = L.norm_init(cfg, keys[4], stacked=n_scan)
        lp["ssm"] = SSM.ssd_init(cfg, keys[10], stacked=n_scan)
    if cfg.family == "hybrid":
        lp["ssm"] = SSM.ssd_init(cfg, keys[10], stacked=n_scan)
        lp["alpha_attn"] = jnp.zeros((n_scan, cfg.d_model), jnp.float32)
        lp["alpha_ssm"] = jnp.zeros((n_scan, cfg.d_model), jnp.float32)
    p["layers"] = lp

    if cfg.family == "moe" and cfg.moe.dense_layers:
        dense_cfg = cfg  # same dims, dense FFN of width dense_ffw
        prefix = []
        dkeys = jax.random.split(keys[11], cfg.moe.dense_layers)
        for i in range(cfg.moe.dense_layers):
            ks = jax.random.split(dkeys[i], 4)
            blk = {
                "ln1": L.norm_init(cfg, ks[0]),
                "attn": L.attention_init(cfg, ks[1]),
                "ln2": L.norm_init(cfg, ks[2]),
                "mlp": L.mlp_init(cfg, ks[3], d_ff=cfg.moe.dense_ffw),
            }
            prefix.append(blk)
        p["dense_prefix"] = prefix
    return p


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, p, tokens, dtype=jnp.bfloat16):
    x = Q.take(p["embed"], tokens, dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    return x


def unembed(cfg: ModelConfig, p, x, dtype=jnp.bfloat16):
    w = (Q.cast(p["embed"], dtype).T if cfg.tie_embeddings
         else Q.cast(p["head"], dtype))
    logits = jnp.einsum("btd,dv->btv", x, w,
                        preferred_element_type=jnp.float32)
    logits = hint(logits, "batch", None, "model")
    return L.softcap(logits, cfg.final_logit_softcap)


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------

def _ffn_part(cfg: ModelConfig, lp, h, ctx, *, decode: bool,
              batch_spec, seq_spec, moe_cf: Optional[float] = None):
    """Returns (ffn_out, aux)."""
    if cfg.family == "moe":
        if decode:
            out, aux, _ = MOE.moe_decode(
                cfg, lp["moe"], h, ctx, batch_spec=batch_spec,
                capacity_factor=moe_cf or 2.0)
        else:
            out, aux, _ = MOE.moe_ep(
                cfg, lp["moe"], h, ctx, batch_spec=batch_spec,
                seq_spec=seq_spec, capacity_factor=moe_cf or 1.25)
        return out, aux
    return L.mlp_apply(cfg, lp["mlp"], h), jnp.zeros((), jnp.float32)


def _self_attn(cfg, lp, h, positions, window, *, kv_chunk, attn_impl):
    a = cfg.attention
    if attn_impl == "qchunked":
        # window must be static here (int or None)
        q, k, v = L.attention_qkv(lp["attn"], h, a, positions)
        o = L.blocked_attention_qchunked(
            q, k, v, positions, positions,
            window=window if not hasattr(window, "dtype") else None,
            softcap=a.logit_softcap, scale=a.attn_scale,
            kv_chunk=kv_chunk)
        return L.attention_out(lp["attn"], o)
    return L.self_attention(lp["attn"], h, a, positions,
                            window=window, kv_chunk=kv_chunk)


def _mixer_part(cfg: ModelConfig, lp, h, positions, window, *,
                kv_chunk: int = 1024, attn_impl: str = "blocked"):
    """Full-sequence (training/prefill) token mixer.  Returns (out, ssm_state,
    conv_tail) — states are None for pure-attention families."""
    a = cfg.attention
    attn_out = ssm_out = None
    state = tail = None
    if cfg.family in ("dense", "moe", "vlm"):
        attn_out = _self_attn(cfg, lp, h, positions, window,
                              kv_chunk=kv_chunk, attn_impl=attn_impl)
        return attn_out, None, None
    if cfg.family == "ssm":
        out, state, tail = SSM.ssd_forward(cfg, lp["ssm"], h)
        return out, state, tail
    # hybrid: attention ∥ SSM on the same input
    attn_out = _self_attn(cfg, lp, h, positions, window,
                          kv_chunk=kv_chunk, attn_impl=attn_impl)
    ssm_out, state, tail = SSM.ssd_forward(cfg, lp["ssm"], h)
    out = 0.5 * (attn_out * (1.0 + lp["alpha_attn"].astype(attn_out.dtype))
                 + ssm_out * (1.0 + lp["alpha_ssm"].astype(attn_out.dtype)))
    return out, state, tail


def _dense_layer(cfg: ModelConfig, lp, x, positions, window, ctx, *,
                 decode=False, batch_spec=None, seq_spec=None,
                 kv_chunk=1024, d_ff=None, moe_cf=None,
                 attn_impl="blocked"):
    """One standard pre-norm transformer layer (used by the kimi dense prefix
    and as the scan body for pure-attention families)."""
    x = hint(x, "batch", None, None)
    h = L.apply_norm(cfg, lp["ln1"], x)
    h, state, tail = _mixer_part(cfg, lp, h, positions, window,
                                 kv_chunk=kv_chunk, attn_impl=attn_impl)
    if cfg.post_norm:
        h = L.apply_norm(cfg, lp["post_ln1"], h)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        return x, aux, state, tail
    h = L.apply_norm(cfg, lp["ln2"], x)
    if "mlp" in lp and cfg.family != "moe":
        h = L.mlp_apply(cfg, lp["mlp"], h)
    elif "mlp" in lp:   # kimi dense prefix layer
        h = L.mlp_apply(cfg, lp["mlp"], h)
    else:
        h, aux = _ffn_part(cfg, lp, h, ctx, decode=decode,
                           batch_spec=batch_spec, seq_spec=seq_spec,
                           moe_cf=moe_cf)
    if cfg.post_norm:
        h = L.apply_norm(cfg, lp["post_ln2"], h)
    return x + h, aux, state, tail


# ---------------------------------------------------------------------------
# Forward (training / teacher-forced)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, p, batch: Dict[str, Any],
            ctx: ParallelContext = LOCAL, *,
            collect_states: bool = False, kv_chunk: int = 1024,
            remat: bool = False, moe_cf=None, return_hidden: bool = False,
            attn_impl: str = "blocked"):
    """Returns (logits (B, T, V), aux_losses scalar[, states])."""
    tokens = batch["tokens"]
    B, T_text = tokens.shape
    x = embed_tokens(cfg, p, tokens)
    if cfg.vision_prefix:
        patches = batch["patches"]                  # (B, P, vision_dim)
        pv = jnp.einsum("bpe,ed->bpd", patches.astype(x.dtype),
                        p["vision_proj"].astype(x.dtype))
        x = jnp.concatenate([pv, x], axis=1)
    T = x.shape[1]
    positions = hint(jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32), (B, T)), "batch", None)

    bspec = (ctx.batch_axes or None) if ctx.has_mesh else None
    ms = ctx.model_axis_size
    sspec = (ctx.model_axis
             if ctx.has_mesh and ms > 1 and T % ms == 0 else None)

    aux_total = jnp.zeros((), jnp.float32)

    # kimi dense prefix (unrolled)
    for blk in p.get("dense_prefix", []):
        x, aux, _, _ = _dense_layer(cfg, blk, x, positions, None, ctx,
                                    batch_spec=bspec, seq_spec=sspec,
                                    kv_chunk=kv_chunk)
        aux_total += aux

    windows = jnp.asarray(window_schedule(cfg)[
        (cfg.moe.dense_layers if cfg.family == "moe" and cfg.moe else 0):])

    if attn_impl == "qchunked" and can_qchunk(cfg):
        # regroup the stack so every scan position has a STATIC window
        g = attn_group_size(cfg)
        lp_g = jax.tree.map(
            lambda a: a.reshape((a.shape[0] // g, g) + a.shape[1:]),
            p["layers"])

        def body(carry, lp_group):
            x, aux_acc = carry
            states = []
            for idx in range(g):
                lp = jax.tree.map(lambda a: a[idx], lp_group)
                win = static_window_for(cfg, idx, g)
                x, aux, state, tail = _dense_layer(
                    cfg, lp, x, positions, win, ctx,
                    batch_spec=bspec, seq_spec=sspec, kv_chunk=kv_chunk,
                    moe_cf=moe_cf, attn_impl="qchunked")
                aux_acc = aux_acc + aux
            ys = (state, tail) if collect_states else (None, None)
            return (x, aux_acc), ys

        if remat:
            body = jax.checkpoint(body)
        (x, aux_total), states = jax.lax.scan(
            body, (x, aux_total), lp_g)
    else:
        def body(carry, xs):
            x, aux_acc = carry
            lp, win = xs
            x, aux, state, tail = _dense_layer(
                cfg, lp, x, positions, win, ctx,
                batch_spec=bspec, seq_spec=sspec, kv_chunk=kv_chunk,
                moe_cf=moe_cf)
            ys = (state, tail) if collect_states else (None, None)
            return (x, aux_acc + aux), ys

        if remat:
            body = jax.checkpoint(body)
        (x, aux_total), states = jax.lax.scan(
            body, (x, aux_total), (p["layers"], windows))
    x = L.apply_norm(cfg, p["final_norm"], x)
    if return_hidden:
        return x, aux_total
    logits = unembed(cfg, p, x)
    if collect_states:
        return logits, aux_total, states
    return logits, aux_total


# ---------------------------------------------------------------------------
# KV / state cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cache:
    k: Optional[jax.Array] = None        # (Ls, B, S, KH, hd)
    v: Optional[jax.Array] = None
    ssm: Optional[jax.Array] = None      # (Ls, B, H, P, N)
    conv: Optional[jax.Array] = None     # (Ls, B, W-1, conv_dim)
    prefix_k: Optional[list] = None      # kimi dense prefix (unrolled layers)
    prefix_v: Optional[list] = None
    pos: Optional[jax.Array] = None      # scalar int32: tokens already cached


jax.tree_util.register_dataclass(
    Cache, data_fields=["k", "v", "ssm", "conv", "prefix_k", "prefix_v",
                        "pos"],
    meta_fields=[])


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Cache:
    a = cfg.attention
    n_scan = num_moe_layers(cfg) if cfg.family == "moe" else cfg.num_layers
    c = Cache(pos=jnp.zeros((), jnp.int32))
    if a is not None:
        kv = (n_scan, batch, max_len, a.num_kv_heads, a.head_dim)
        c.k = jnp.zeros(kv, dtype)
        c.v = jnp.zeros(kv, dtype)
        npre = cfg.moe.dense_layers if cfg.family == "moe" and cfg.moe else 0
        if npre:
            c.prefix_k = [jnp.zeros(kv[1:], dtype) for _ in range(npre)]
            c.prefix_v = [jnp.zeros(kv[1:], dtype) for _ in range(npre)]
    if cfg.family in ("ssm", "hybrid"):
        DI, H, Pd, N = SSM.ssm_dims(cfg)
        c.ssm = jnp.zeros((n_scan, batch, H, Pd, N), jnp.float32)
        c.conv = jnp.zeros((n_scan, batch, cfg.ssm.conv_width - 1,
                            DI + 2 * N), jnp.bfloat16)
    return c


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, p, batch: Dict[str, Any],
            ctx: ParallelContext = LOCAL, *, max_len: Optional[int] = None,
            kv_chunk: int = 1024, moe_cf=None,
            attn_impl: str = "blocked") -> Tuple[jax.Array, Cache]:
    """Forward over the prompt; returns (last-position logits, filled cache)."""
    tokens = batch["tokens"]
    B, T_text = tokens.shape
    x = embed_tokens(cfg, p, tokens)
    if cfg.vision_prefix:
        pv = jnp.einsum("bpe,ed->bpd", batch["patches"].astype(x.dtype),
                        p["vision_proj"].astype(x.dtype))
        x = jnp.concatenate([pv, x], axis=1)
    T = x.shape[1]
    S = max_len or T
    positions = hint(jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32), (B, T)), "batch", None)
    bspec = (ctx.batch_axes or None) if ctx.has_mesh else None
    ms = ctx.model_axis_size
    sspec = (ctx.model_axis
             if ctx.has_mesh and ms > 1 and T % ms == 0 else None)

    cache = init_cache(cfg, B, S)
    a = cfg.attention

    def attn_with_cache(lp, h, win):
        q, k, v = L.attention_qkv(lp["attn"], h, a, positions)
        if attn_impl == "qchunked" and not hasattr(win, "dtype"):
            o = L.blocked_attention_qchunked(
                q, k, v, positions, positions, window=win,
                softcap=a.logit_softcap, scale=a.attn_scale,
                kv_chunk=kv_chunk)
        else:
            o = L.blocked_attention(q, k, v, positions, positions,
                                    window=win, softcap=a.logit_softcap,
                                    scale=a.attn_scale, kv_chunk=kv_chunk)
        kpad = jnp.pad(k, ((0, 0), (0, S - T), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (0, S - T), (0, 0), (0, 0)))
        return L.attention_out(lp["attn"], o), kpad, vpad

    aux = jnp.zeros((), jnp.float32)
    for i, blk in enumerate(p.get("dense_prefix", [])):
        h = L.apply_norm(cfg, blk["ln1"], x)
        h, kc, vc = attn_with_cache(blk, h, None)
        cache.prefix_k[i] = kc
        cache.prefix_v[i] = vc
        x = x + h
        h = L.apply_norm(cfg, blk["ln2"], x)
        x = x + L.mlp_apply(cfg, blk["mlp"], h)

    windows = jnp.asarray(window_schedule(cfg)[
        (cfg.moe.dense_layers if cfg.family == "moe" and cfg.moe else 0):])

    def body(x_and_aux, xs):
        x, aux_acc = x_and_aux
        lp, win = xs
        kc = vc = state = tail = None
        h = L.apply_norm(cfg, lp["ln1"], x)
        if cfg.family in ("dense", "moe", "vlm"):
            h, kc, vc = attn_with_cache(lp, h, win)
        elif cfg.family == "ssm":
            h, state, tail = SSM.ssd_forward(cfg, lp["ssm"], h)
        else:  # hybrid
            h_attn, kc, vc = attn_with_cache(lp, h, win)
            h_ssm, state, tail = SSM.ssd_forward(cfg, lp["ssm"], h)
            h = 0.5 * (h_attn * (1.0 + lp["alpha_attn"].astype(h.dtype))
                       + h_ssm * (1.0 + lp["alpha_ssm"].astype(h.dtype)))
        if cfg.post_norm:
            h = L.apply_norm(cfg, lp["post_ln1"], h)
        x = x + h
        aux = jnp.zeros((), jnp.float32)
        if cfg.family != "ssm":
            h = L.apply_norm(cfg, lp["ln2"], x)
            if cfg.family == "moe":
                h, aux = _ffn_part(cfg, lp, h, ctx, decode=False,
                                   batch_spec=bspec, seq_spec=sspec,
                                   moe_cf=moe_cf)
            else:
                h = L.mlp_apply(cfg, lp["mlp"], h)
            if cfg.post_norm:
                h = L.apply_norm(cfg, lp["post_ln2"], h)
            x = x + h
        return (x, aux_acc + aux), (kc, vc, state, tail)

    if attn_impl == "qchunked" and can_qchunk(cfg):
        g = attn_group_size(cfg)
        lp_g = jax.tree.map(
            lambda a_: a_.reshape((a_.shape[0] // g, g) + a_.shape[1:]),
            p["layers"])

        def gbody(x_and_aux, lp_group):
            acc_ys = None
            for idx in range(g):
                lp = jax.tree.map(lambda a_: a_[idx], lp_group)
                win = static_window_for(cfg, idx, g)
                x_and_aux, ys = body(x_and_aux, (lp, win))
                ys = jax.tree.map(lambda t: t[None] if t is not None else t,
                                  ys, is_leaf=lambda t: t is None)
                acc_ys = ys if acc_ys is None else jax.tree.map(
                    lambda a_, b_: (jnp.concatenate([a_, b_])
                                    if a_ is not None else None),
                    acc_ys, ys, is_leaf=lambda t: t is None)
            return x_and_aux, acc_ys

        (x, aux), grouped = jax.lax.scan(gbody, (x, aux), lp_g)
        # grouped ys: (n_groups, g, ...) -> flatten layer dim
        ks, vs, states, tails = jax.tree.map(
            lambda t: (t.reshape((-1,) + t.shape[2:])
                       if t is not None else None),
            grouped, is_leaf=lambda t: t is None)
    else:
        (x, aux), (ks, vs, states, tails) = jax.lax.scan(
            body, (x, aux), (p["layers"], windows))
    if ks is not None:
        cache.k, cache.v = ks, vs
    if states is not None:
        cache.ssm = states
        cache.conv = tails
    cache.pos = jnp.asarray(T, jnp.int32)
    x = L.apply_norm(cfg, p["final_norm"], x)
    logits = unembed(cfg, p, x[:, -1:, :])
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _decode_layer(cfg: ModelConfig, lp, win, x, kc, vc, sst, scv, ctx, *,
                  attn_fn, bspec, moe_cf=None, active=None):
    """Shared per-layer decode body for the legacy and paged decode paths.

    ``attn_fn(lp, h, kc, vc, win) -> (h, kc, vc)`` supplies the path's
    attention (shared-position dense vs per-slot paged); ``active`` (B,)
    bool, when given, freezes the recurrent state of done slots (paged
    done-masking).  Returns (x, (kc, vc, state, conv))."""
    h = L.apply_norm(cfg, lp["ln1"], x)
    state = conv = None
    if cfg.family in ("dense", "moe", "vlm"):
        h, kc, vc = attn_fn(lp, h, kc, vc, win)
    elif cfg.family == "ssm":
        o, state, conv = SSM.ssd_step(cfg, lp["ssm"], h[:, 0], sst, scv)
        h = o[:, None, :]
    else:  # hybrid
        ha, kc, vc = attn_fn(lp, h, kc, vc, win)
        o, state, conv = SSM.ssd_step(cfg, lp["ssm"], h[:, 0], sst, scv)
        hs = o[:, None, :]
        h = 0.5 * (ha * (1.0 + lp["alpha_attn"].astype(ha.dtype))
                   + hs * (1.0 + lp["alpha_ssm"].astype(ha.dtype)))
    if state is not None and active is not None:
        B = x.shape[0]
        keep = active.reshape((B,) + (1,) * (state.ndim - 1))
        state = jnp.where(keep, state, sst)
        conv = jnp.where(active.reshape((B,) + (1,) * (conv.ndim - 1)),
                         conv, scv)
    if cfg.post_norm:
        h = L.apply_norm(cfg, lp["post_ln1"], h)
    x = x + h
    if cfg.family != "ssm":
        h = L.apply_norm(cfg, lp["ln2"], x)
        if cfg.family == "moe":
            h, _ = _ffn_part(cfg, lp, h, ctx, decode=True,
                             batch_spec=bspec, seq_spec=None, moe_cf=moe_cf)
        else:
            h = L.mlp_apply(cfg, lp["mlp"], h)
        if cfg.post_norm:
            h = L.apply_norm(cfg, lp["post_ln2"], h)
        x = x + h
    return x, (kc, vc, state, conv)


def decode_step(cfg: ModelConfig, p, cache: Cache, tokens,
                ctx: ParallelContext = LOCAL, *, kv_chunk: int = 2048,
                moe_cf=None):
    """One decode step.  tokens: (B,) int32.  Returns (logits (B, V), cache).

    The new token is written at index ``cache.pos``; attention sees positions
    [0, pos] (windowed per layer).
    """
    a = cfg.attention
    B = tokens.shape[0]
    pos = cache.pos
    x = embed_tokens(cfg, p, tokens[:, None])           # (B, 1, D)
    q_pos = hint(jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32),
                 "batch", None)
    bspec = (ctx.batch_axes or None) if ctx.has_mesh else None

    def attn_decode(lp, h, kc, vc, win):
        q, k, v = L.attention_qkv(lp["attn"], h, a, q_pos)
        S = kc.shape[1]
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, pos, 0, 0))
        kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        o = L.blocked_attention(q, kc, vc, q_pos, kv_pos,
                                window=win, softcap=a.logit_softcap,
                                scale=a.attn_scale, kv_chunk=kv_chunk)
        return L.attention_out(lp["attn"], o), kc, vc

    new_prefix_k, new_prefix_v = [], []
    for i, blk in enumerate(p.get("dense_prefix", [])):
        h = L.apply_norm(cfg, blk["ln1"], x)
        h, kc, vc = attn_decode(blk, h, cache.prefix_k[i], cache.prefix_v[i],
                                None)
        new_prefix_k.append(kc)
        new_prefix_v.append(vc)
        x = x + h
        h = L.apply_norm(cfg, blk["ln2"], x)
        x = x + L.mlp_apply(cfg, blk["mlp"], h)

    windows = jnp.asarray(window_schedule(cfg)[
        (cfg.moe.dense_layers if cfg.family == "moe" and cfg.moe else 0):])

    def body(x, xs):
        lp, win, kc, vc, sst, scv = xs
        return _decode_layer(cfg, lp, win, x, kc, vc, sst, scv, ctx,
                             attn_fn=attn_decode, bspec=bspec,
                             moe_cf=moe_cf)

    dummy = jnp.zeros((num_moe_layers(cfg) if cfg.family == "moe"
                       else cfg.num_layers,), jnp.float32)
    xs = (p["layers"], windows,
          cache.k if cache.k is not None else dummy,
          cache.v if cache.v is not None else dummy,
          cache.ssm if cache.ssm is not None else dummy,
          cache.conv if cache.conv is not None else dummy)
    x, (ks, vs, states, convs) = jax.lax.scan(body, x, xs)

    new_cache = Cache(
        k=ks if cache.k is not None else None,
        v=vs if cache.v is not None else None,
        ssm=states if cache.ssm is not None else None,
        conv=convs if cache.conv is not None else None,
        prefix_k=new_prefix_k or None,
        prefix_v=new_prefix_v or None,
        pos=pos + 1,
    )
    x = L.apply_norm(cfg, p["final_norm"], x)
    logits = unembed(cfg, p, x)
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# Serve fast path: per-slot cache insertion + paged multi-step decode
# ---------------------------------------------------------------------------
# Continuous batching keeps ONE batch-wide cache alive across admissions;
# slots differ in valid length.  `cache_insert` writes a freshly prefilled
# (batch=1) slot cache into its batch row; `decode_step_paged` advances every
# slot one token at ITS OWN position (per-slot seq_lens replaces the shared
# cache.pos); `decode_n` scans that step on-device so the host syncs once per
# chunk instead of once per token.


def cache_insert(cache: Cache, slot_cache: Cache, slot) -> Cache:
    """Write the (batch=n) ``slot_cache`` into batch rows ``slot`` of
    ``cache``.  ``slot`` is a scalar or an (n,) vector of slot indices (a
    whole admission wave lands in ONE dispatch); scalars/traced values both
    work, so one jitted admission program serves every slot."""
    slots = jnp.atleast_1d(jnp.asarray(slot, jnp.int32))

    def ins(dst, src, axis):
        src = src.astype(dst.dtype)
        if axis == 0:
            return dst.at[slots].set(src)
        return dst.at[:, slots].set(src)

    new = Cache(pos=jnp.maximum(cache.pos, slot_cache.pos))
    if cache.k is not None:
        new.k = ins(cache.k, slot_cache.k, 1)
        new.v = ins(cache.v, slot_cache.v, 1)
    if cache.ssm is not None:
        new.ssm = ins(cache.ssm, slot_cache.ssm, 1)
        new.conv = ins(cache.conv, slot_cache.conv, 1)
    if cache.prefix_k is not None:
        new.prefix_k = [ins(d, s, 0) for d, s in
                        zip(cache.prefix_k, slot_cache.prefix_k)]
        new.prefix_v = [ins(d, s, 0) for d, s in
                        zip(cache.prefix_v, slot_cache.prefix_v)]
    return new


def _decode_attn_impl(ctx: ParallelContext) -> str:
    return {"auto": "auto", "paged": "pallas", "dense": "xla"}[
        getattr(ctx, "decode_attn", "auto")]


def decode_step_paged(cfg: ModelConfig, p, cache: Cache, tokens, seq_lens,
                      active, ctx: ParallelContext = LOCAL, *, moe_cf=None,
                      tables=None):
    """One decode step with PER-SLOT cache lengths (continuous batching).

    tokens (B,) int32 — previous token per slot;
    seq_lens (B,) int32 — valid cached tokens per slot (the new token is
    written at this row, then attended);
    active (B,) bool — slots past their budget keep their cache, state, and
    seq_len frozen (their lane still computes, output is discarded upstream).

    Returns (logits (B, V), cache, seq_lens + active).  Attention runs
    through ``ops.paged_decode_attention`` — the Pallas paged kernel on TPU,
    the dense XLA reference elsewhere (ctx.decode_attn overrides).

    ``tables`` (B, nb) int32 switches to the POOLED cache layout (k/v from
    ``init_kv_pool``, shape (Ls, NB, bs, KH, hd)): each slot's logical
    block j lives at pool block ``tables[b, j]``, the fresh token's KV
    scatters to its logical position's pool row, and attention runs through
    the block-table-indexed kernel.  Writes land strictly past the prompt,
    so shared prefix blocks are never touched (see serve/kvpool.py).
    """
    from repro.kernels import ops as OPS

    a = cfg.attention
    B = tokens.shape[0]
    seq_lens = seq_lens.astype(jnp.int32)
    act_i = active.astype(jnp.int32)
    x = embed_tokens(cfg, p, tokens[:, None])            # (B, 1, D)
    q_pos = hint(seq_lens[:, None], "batch", None)       # per-slot positions
    bspec = (ctx.batch_axes or None) if ctx.has_mesh else None
    impl = _decode_attn_impl(ctx)
    kv_block = getattr(ctx, "decode_kv_block", 128)

    def attn_dense_paged(lp, h, kc, vc, win):
        q, k, v = L.attention_qkv(lp["attn"], h, a, q_pos)
        S = kc.shape[1]
        # per-slot KV write at each slot's own next row.  Frozen slots write
        # a garbage row one past their (frozen) valid length — never read,
        # and overwritten by the next admission's cache_insert.
        idx = jnp.minimum(seq_lens, S - 1)

        def wr(dst_b, new_b, i):
            return jax.lax.dynamic_update_slice(
                dst_b, new_b.astype(dst_b.dtype), (i, 0, 0))

        kc = jax.vmap(wr)(kc, k, idx)
        vc = jax.vmap(wr)(vc, v, idx)
        lens_now = jnp.minimum(seq_lens + 1, S)
        o = OPS.paged_decode_attention(
            q[:, 0], kc, vc, lens_now, window=win,
            softcap=a.logit_softcap, scale=a.attn_scale, bk=kv_block,
            impl=impl)
        return L.attention_out(lp["attn"], o[:, None]), kc, vc

    def attn_pooled(lp, h, kc, vc, win):
        # kc, vc: (NB, bs, KH, hd) physical block pool
        q, k, v = L.attention_qkv(lp["attn"], h, a, q_pos)
        NB, bs = kc.shape[0], kc.shape[1]
        W = tables.shape[1] * bs
        pos = jnp.minimum(seq_lens, W - 1)   # overflow clamps into the
        blk = pos // bs                      # slot's (private) last block
        phys = jnp.take_along_axis(tables, blk[:, None], axis=1)[:, 0]
        # OOB table entries (unadmitted slots) give an OOB flat row, which
        # the scatter drops — no trash block needed
        dest = phys * bs + pos % bs
        kf = kc.reshape(NB * bs, *kc.shape[2:])
        vf = vc.reshape(NB * bs, *vc.shape[2:])
        kf = kf.at[dest].set(k[:, 0].astype(kc.dtype))
        vf = vf.at[dest].set(v[:, 0].astype(vc.dtype))
        kc, vc = kf.reshape(kc.shape), vf.reshape(vc.shape)
        lens_now = jnp.minimum(seq_lens + 1, W)
        o = OPS.paged_decode_attention_bt(
            q[:, 0], kc, vc, lens_now, tables, window=win,
            softcap=a.logit_softcap, scale=a.attn_scale, impl=impl)
        return L.attention_out(lp["attn"], o[:, None]), kc, vc

    if tables is not None:
        tables = tables.astype(jnp.int32)
        attn_paged = attn_pooled
    else:
        attn_paged = attn_dense_paged

    new_prefix_k, new_prefix_v = [], []
    for i, blk in enumerate(p.get("dense_prefix", [])):
        h = L.apply_norm(cfg, blk["ln1"], x)
        h, kc, vc = attn_paged(blk, h, cache.prefix_k[i], cache.prefix_v[i],
                               None)
        new_prefix_k.append(kc)
        new_prefix_v.append(vc)
        x = x + h
        h = L.apply_norm(cfg, blk["ln2"], x)
        x = x + L.mlp_apply(cfg, blk["mlp"], h)

    windows = jnp.asarray(window_schedule(cfg)[
        (cfg.moe.dense_layers if cfg.family == "moe" and cfg.moe else 0):])

    def body(x, xs):
        lp, win, kc, vc, sst, scv = xs
        return _decode_layer(cfg, lp, win, x, kc, vc, sst, scv, ctx,
                             attn_fn=attn_paged, bspec=bspec,
                             moe_cf=moe_cf, active=active)

    dummy = jnp.zeros((num_moe_layers(cfg) if cfg.family == "moe"
                       else cfg.num_layers,), jnp.float32)
    xs = (p["layers"],
          cache.k if cache.k is not None else dummy,
          cache.v if cache.v is not None else dummy,
          cache.ssm if cache.ssm is not None else dummy,
          cache.conv if cache.conv is not None else dummy)
    if can_qchunk(cfg):
        # regroup the stack so every scan position has a STATIC window
        # (the prefill/forward qchunked trick) — with a static window the
        # attention dispatcher can launch the Pallas paged kernel; a traced
        # window would force the dense XLA fallback on every layer.
        g = attn_group_size(cfg)
        xs_g = jax.tree.map(
            lambda t: t.reshape((t.shape[0] // g, g) + t.shape[1:]), xs)

        def gbody(x, xs_):
            lp_g, kcg, vcg, sstg, scvg = xs_
            acc = None
            for idx in range(g):
                lp = jax.tree.map(lambda t: t[idx], lp_g)
                win = static_window_for(cfg, idx, g)
                x, ys = body(x, (lp, win, kcg[idx], vcg[idx],
                                 sstg[idx], scvg[idx]))
                ys = jax.tree.map(lambda t: t[None] if t is not None else t,
                                  ys, is_leaf=lambda t: t is None)
                acc = ys if acc is None else jax.tree.map(
                    lambda a_, b_: (jnp.concatenate([a_, b_])
                                    if a_ is not None else None),
                    acc, ys, is_leaf=lambda t: t is None)
            return x, acc

        x, grouped = jax.lax.scan(gbody, x, xs_g)
        ks, vs, states, convs = jax.tree.map(
            lambda t: (t.reshape((-1,) + t.shape[2:])
                       if t is not None else None),
            grouped, is_leaf=lambda t: t is None)
    else:
        x, (ks, vs, states, convs) = jax.lax.scan(
            body, x, (xs[0], windows) + xs[1:])

    new_cache = Cache(
        k=ks if cache.k is not None else None,
        v=vs if cache.v is not None else None,
        ssm=states if cache.ssm is not None else None,
        conv=convs if cache.conv is not None else None,
        prefix_k=new_prefix_k or None,
        prefix_v=new_prefix_v or None,
        pos=jnp.maximum(cache.pos, jnp.max(seq_lens + act_i)),
    )
    x = L.apply_norm(cfg, p["final_norm"], x)
    logits = unembed(cfg, p, x)
    return logits[:, 0], new_cache, seq_lens + act_i


def decode_n(cfg: ModelConfig, p, cache: Cache, tokens, seq_lens, budget,
             ctx: ParallelContext = LOCAL, *, num_steps: int,
             greedy: bool = True, key=None, temperature: float = 1.0,
             salt=None, moe_cf=None, tables=None):
    """Advance all slots up to ``num_steps`` tokens in ONE dispatch.

    A ``lax.scan`` over ``decode_step_paged`` with on-device token selection
    (argmax, or temperature sampling when ``greedy=False``) and per-slot
    done-masking: slot b decodes exactly ``budget[b]`` tokens, then its
    cache/seq_len freeze and its emitted token repeats.  The host syncs once
    per chunk instead of once per token.

    Chunking is numerics-neutral: the scan body is the same program the
    per-token path runs, so greedy outputs are bitwise identical for any
    ``num_steps`` split of the same (tokens, seq_lens, budget) trajectory.
    (Across a serving session, MoE capacity coupling can still observe
    admission timing — see serve/engine.py.)  Sampling keys are folded per
    (salt, position) —
    ``salt`` (B,) int32 is a per-request value (the engine passes the
    request id; default: the slot index), constant for a request's lifetime
    — so sampled outputs are chunk-invariant AND decorrelated across slots
    and across requests reusing a slot.

    Returns (toks (num_steps, B) int32, cache, seq_lens, last_tokens).

    With ``tables`` (pooled cache from `init_kv_pool`), the chunk runs
    gather-once: each slot's logical KV view is gathered from the block
    pool ONE time, the ``num_steps`` scan advances on that contiguous view
    exactly like the per-slot dense path, and only the freshly decoded
    rows scatter back to the pool at chunk end.  Decode writes land
    strictly past the prompt — always in the slot's private (refcount-1)
    blocks — so the writeback can never touch a block another table
    shares, and per-step attention over the view is lane-for-lane the
    dense program: pooled decode stays bitwise-identical while paying the
    pool gather once per chunk instead of once per token.
    """
    budget = jnp.asarray(budget, jnp.int32)
    if not greedy and key is None:
        raise ValueError("sampling decode (greedy=False) needs a PRNG key")
    salt = (jnp.asarray(salt, jnp.int32) if salt is not None
            else jnp.arange(budget.shape[0], dtype=jnp.int32))

    pool_cache = None
    if tables is not None:
        tables = jnp.asarray(tables, jnp.int32)
        B = tables.shape[0]
        Ls, NB, bs = cache.k.shape[0], cache.k.shape[1], cache.k.shape[2]
        nb = tables.shape[1]
        W = nb * bs
        # OOB sentinel entries (unadmitted slots) clip for the GATHER only
        # — their view is garbage, their lanes are masked by seq_lens, and
        # their budget is 0 so nothing is written back
        gidx = ((jnp.clip(tables, 0, NB - 1) * bs)[:, :, None]
                + jnp.arange(bs)).reshape(-1)
        kf = cache.k.reshape((Ls, NB * bs) + cache.k.shape[3:])
        vf = cache.v.reshape((Ls, NB * bs) + cache.v.shape[3:])
        view = Cache(
            k=jnp.take(kf, gidx, axis=1).reshape(
                (Ls, B, W) + cache.k.shape[3:]),
            v=jnp.take(vf, gidx, axis=1).reshape(
                (Ls, B, W) + cache.v.shape[3:]),
            pos=cache.pos)
        pool_cache, cache = cache, view

    def select(logits, lens):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        lg = logits / jnp.asarray(max(temperature, 1e-6), logits.dtype)
        keys = jax.vmap(lambda b, s: jax.random.fold_in(
            jax.random.fold_in(key, b), s))(salt, lens)
        return jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)

    def step(carry, _):
        cache, toks, lens, produced = carry
        active = produced < budget
        logits, cache, lens = decode_step_paged(
            cfg, p, cache, toks, lens, active, ctx, moe_cf=moe_cf)
        nxt = jnp.where(active, select(logits, lens), toks)
        return (cache, nxt, lens, produced + active.astype(jnp.int32)), nxt

    lens0 = jnp.asarray(seq_lens, jnp.int32)
    init = (cache, jnp.asarray(tokens, jnp.int32), lens0,
            jnp.zeros_like(budget))
    (cache, last, seq_lens, _), toks = jax.lax.scan(
        step, init, None, length=num_steps)

    if pool_cache is not None:
        # writeback: slot b was active for exactly min(budget, num_steps)
        # steps, writing row lens0+i at step i (clamped to the last lane on
        # cache overflow, last write winning — same contract as the
        # per-step scatter).  Rows >= prompt length => block index past
        # every published block, so only private blocks are touched.
        nsteps = jnp.minimum(budget, num_steps)
        i = jnp.arange(num_steps)
        rows = lens0[:, None] + i[None, :]                    # (B, steps)
        rowc = jnp.minimum(rows, W - 1)
        keep = ((i[None, :] < nsteps[:, None])
                & ((rows < W - 1) | (i[None, :] == nsteps[:, None] - 1)))
        phys = jnp.take_along_axis(tables, rowc // bs, axis=1)
        dest = jnp.where(keep, phys * bs + rowc % bs, NB * bs).reshape(-1)
        ridx = rowc[None, :, :, None, None]
        newk = jnp.take_along_axis(cache.k, ridx, axis=2)
        newv = jnp.take_along_axis(cache.v, ridx, axis=2)
        kf = pool_cache.k.reshape((Ls, NB * bs) + pool_cache.k.shape[3:])
        vf = pool_cache.v.reshape((Ls, NB * bs) + pool_cache.v.shape[3:])
        kf = kf.at[:, dest].set(
            newk.reshape((Ls, -1) + newk.shape[3:]).astype(kf.dtype))
        vf = vf.at[:, dest].set(
            newv.reshape((Ls, -1) + newv.shape[3:]).astype(vf.dtype))
        cache = Cache(k=kf.reshape(pool_cache.k.shape),
                      v=vf.reshape(pool_cache.v.shape),
                      pos=cache.pos)
    return toks, cache, seq_lens, last


# ---------------------------------------------------------------------------
# Pooled prefix-shared KV (serve/kvpool.py block tables)
# ---------------------------------------------------------------------------
# The pooled layout replaces each slot's private (S, KH, hd) KV region with
# an indirection over a shared pool of fixed-size blocks: k/v are
# (Ls, NB, bs, KH, hd) and each slot carries a (nb,) physical-block table.
# Admissions sharing a prompt prefix map their leading table entries onto
# blocks another request already prefilled and prefill only the suffix —
# `prefill_suffix` is that fixed-width dispatch.  Attention always sees the
# LOGICAL view (lane index == token position), so pooled outputs are
# bitwise-identical whether a prefix is shared, freshly computed, or
# re-computed chunk by chunk: masked lanes contribute exact zeros
# (`layers.blocked_attention` / the paged kernels) and per-position math
# never depends on which physical block a lane lives in.


def init_kv_pool(cfg: ModelConfig, num_blocks: int, block_size: int,
                 dtype=jnp.bfloat16) -> Cache:
    """Pooled KV cache: k/v (Ls, NB, bs, KH, hd), indexed by block tables.

    Attention-only dense families (no ssm/conv state, no dense prefix, no
    vision prefix — their caches have no pooled layout yet)."""
    a = cfg.attention
    assert cfg.family == "dense" and a is not None and not cfg.vision_prefix, \
        f"pooled KV supports dense attention families, not {cfg.family}"
    kv = (cfg.num_layers, num_blocks, block_size, a.num_kv_heads, a.head_dim)
    return Cache(k=jnp.zeros(kv, dtype), v=jnp.zeros(kv, dtype),
                 pos=jnp.zeros((), jnp.int32))


def prefill_suffix(cfg: ModelConfig, p, cache: Cache, tokens, start, valid,
                   tables, ctx: ParallelContext = LOCAL
                   ) -> Tuple[jax.Array, Cache]:
    """Fixed-width suffix prefill over a pooled KV cache.

    tokens (B, T) int32 — row b holds suffix tokens for logical positions
    ``[start[b], start[b] + valid[b])``, left-aligned (lanes past ``valid``
    are padding — their KV is computed but dropped at the scatter);
    start (B,) int32 — logical position of ``tokens[:, 0]`` (the shared /
    already-prefilled prefix length for this chunk);
    valid (B,) int32 — valid suffix tokens this dispatch (0 = idle row);
    tables (B, nb) int32 — slot block tables (out-of-range = unadmitted).

    Each layer scatters the fresh suffix KV into its pool rows FIRST, then
    gathers the slot's full logical view (prefix blocks written by earlier
    dispatches + this chunk) and runs blocked attention with logical
    positions — masked lanes (unwritten tail, idle rows) use the kv_pos=-1
    sentinel and contribute exact zeros.  A long suffix prefills in
    ``ceil(len/T)`` chained dispatches of this ONE program.

    Returns (logits (B, V) at each row's last valid suffix position,
    updated pooled cache).
    """
    a = cfg.attention
    assert cfg.family == "dense" and not p.get("dense_prefix"), \
        "prefill_suffix supports dense attention families"
    B, T = tokens.shape
    _, NB, bs, KH, hd = cache.k.shape
    nb = tables.shape[1]
    W = nb * bs
    tables = tables.astype(jnp.int32)
    start = start.astype(jnp.int32)
    valid = valid.astype(jnp.int32)

    x = embed_tokens(cfg, p, tokens)
    positions = hint(start[:, None] + jnp.arange(T, dtype=jnp.int32)[None],
                     "batch", None)
    # logical lane positions of the slot's KV view; lanes at/after the
    # suffix end are unwritten — the -1 sentinel masks them exactly
    lane = jnp.arange(W, dtype=jnp.int32)[None, :]
    kv_pos = jnp.where(lane < (start + valid)[:, None], lane, -1)
    # gather map: logical lane -> flat pool row (OOB tables clamp; their
    # lanes are always masked)
    gidx = ((jnp.clip(tables, 0, NB - 1) * bs)[:, :, None]
            + jnp.arange(bs, dtype=jnp.int32)[None, None]).reshape(B, W)
    # scatter map: suffix token t -> flat pool row; padding lanes and idle
    # rows go out of bounds, which the scatter drops
    blk = positions // bs
    phys = jnp.take_along_axis(tables, jnp.clip(blk, 0, nb - 1), axis=1)
    dest = jnp.where(
        (jnp.arange(T, dtype=jnp.int32)[None] < valid[:, None]) & (blk < nb),
        phys * bs + positions % bs, NB * bs).reshape(-1)

    windows = jnp.asarray(window_schedule(cfg))

    def body(x, xs):
        lp, win, kp, vp = xs
        h = L.apply_norm(cfg, lp["ln1"], x)
        q, k, v = L.attention_qkv(lp["attn"], h, a, positions)
        kf = kp.reshape(NB * bs, KH, hd).at[dest].set(
            k.reshape(-1, KH, hd).astype(kp.dtype))
        vf = vp.reshape(NB * bs, KH, hd).at[dest].set(
            v.reshape(-1, KH, hd).astype(vp.dtype))
        kfull = jnp.take(kf, gidx.reshape(-1), axis=0).reshape(B, W, KH, hd)
        vfull = jnp.take(vf, gidx.reshape(-1), axis=0).reshape(B, W, KH, hd)
        o = L.blocked_attention(q, kfull, vfull, positions, kv_pos,
                                window=win, softcap=a.logit_softcap,
                                scale=a.attn_scale, kv_chunk=max(W, 1024))
        h = L.attention_out(lp["attn"], o)
        if cfg.post_norm:
            h = L.apply_norm(cfg, lp["post_ln1"], h)
        x = x + h
        h = L.apply_norm(cfg, lp["ln2"], x)
        h = L.mlp_apply(cfg, lp["mlp"], h)
        if cfg.post_norm:
            h = L.apply_norm(cfg, lp["post_ln2"], h)
        x = x + h
        return x, (kf.reshape(NB, bs, KH, hd), vf.reshape(NB, bs, KH, hd))

    x, (ks, vs) = jax.lax.scan(body, x, (p["layers"], windows,
                                         cache.k, cache.v))
    new_cache = Cache(k=ks, v=vs, pos=cache.pos)
    x = L.apply_norm(cfg, p["final_norm"], x)
    li = jnp.clip(valid - 1, 0, T - 1)
    xlast = jnp.take_along_axis(x, li[:, None, None], axis=1)   # (B, 1, D)
    logits = unembed(cfg, p, xlast)
    return logits[:, 0], new_cache
