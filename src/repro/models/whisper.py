"""Whisper-small backbone: transformer encoder + causal decoder w/ cross-attn.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings of shape (B, enc_len, d_model) (post-conv, i.e.
already at the encoder's hidden width).  Only the transformer backbone is
modelled.  RoPE replaces learned positions (backbone-only reproduction).

Shape semantics for the assigned cells (DESIGN.md §Arch-applicability):
  * train/prefill ``seq_len`` is split enc_len = dec_len = seq_len // 2 so the
    total processed positions equal seq_len.
  * decode: the KV length applies to the decoder self-attn cache; the encoder
    context uses enc_len = seq_len // 2.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.context import LOCAL, ParallelContext, hint


def split_seq(cfg: ModelConfig, seq_len: int) -> Tuple[int, int]:
    enc = max(2, seq_len // 2)
    dec = max(2, seq_len - enc)
    return enc, dec


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 12)
    Le, Ld = cfg.encoder_layers, cfg.num_layers
    p: Dict[str, Any] = {
        "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model),
        "enc_final_norm": L.norm_init(cfg, keys[1]),
        "final_norm": L.norm_init(cfg, keys[2]),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(keys[3], cfg.d_model, cfg.vocab_size)
    p["encoder"] = {
        "ln1": L.norm_init(cfg, keys[4], stacked=Le),
        "attn": L.attention_init(cfg, keys[5], stacked=Le),
        "ln2": L.norm_init(cfg, keys[6], stacked=Le),
        "mlp": L.mlp_init(cfg, keys[7], stacked=Le),
    }
    p["decoder"] = {
        "ln1": L.norm_init(cfg, keys[8], stacked=Ld),
        "attn": L.attention_init(cfg, keys[9], stacked=Ld),
        "ln_x": L.norm_init(cfg, keys[10], stacked=Ld),
        "xattn": L.attention_init(cfg, keys[11], stacked=Ld, cross=True),
        "ln2": L.norm_init(cfg, jax.random.fold_in(key, 20), stacked=Ld),
        "mlp": L.mlp_init(cfg, jax.random.fold_in(key, 21), stacked=Ld),
    }
    return p


def _encode(cfg: ModelConfig, p, frames, *, kv_chunk=1024):
    """frames: (B, S_enc, d_model) stub embeddings -> encoder output."""
    a = cfg.attention
    B, S, _ = frames.shape
    x = frames.astype(jnp.bfloat16)
    positions = hint(jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32), (B, S)), "batch", None)

    def body(x, lp):
        h = L.apply_norm(cfg, lp["ln1"], x)
        h = L.self_attention(lp["attn"], h, a, positions, causal=False,
                             kv_chunk=kv_chunk)
        x = x + h
        h = L.apply_norm(cfg, lp["ln2"], x)
        return x + L.mlp_apply(cfg, lp["mlp"], h), None

    x, _ = jax.lax.scan(body, x, p["encoder"])
    return L.apply_norm(cfg, p["enc_final_norm"], x)


def _cross_attention(cfg, lp, h, enc_kv, positions_q, *, kv_chunk=1024):
    """Cross-attn: q from decoder h; k/v precomputed from encoder output."""
    a = cfg.attention
    k, v, kv_pos = enc_kv
    q = jnp.einsum("btd,dhk->bthk", h, lp["wq"].astype(h.dtype))
    o = L.blocked_attention(q, k, v, positions_q, kv_pos, causal=False,
                            scale=a.attn_scale, kv_chunk=kv_chunk)
    return L.attention_out(lp, o)


def _enc_kv(cfg, p_x, enc_out):
    """Precompute cross-attention K/V from encoder output (per scanned layer
    stack: weights are stacked (L, ...) so this runs inside the scan)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p_x["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p_x["wv"].astype(enc_out.dtype))
    return k, v


def forward(cfg: ModelConfig, p, batch: Dict[str, Any],
            ctx: ParallelContext = LOCAL, *, kv_chunk: int = 1024,
            remat: bool = False):
    """Teacher-forced: batch = {frames (B,S_enc,D), tokens (B,T_dec)}."""
    a = cfg.attention
    enc_out = _encode(cfg, p, batch["frames"], kv_chunk=kv_chunk)
    tokens = batch["tokens"]
    B, T = tokens.shape
    S = enc_out.shape[1]
    x = jnp.take(p["embed"], tokens, axis=0).astype(jnp.bfloat16)
    positions = hint(jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32), (B, T)), "batch", None)
    kv_pos = hint(jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32), (B, S)), "batch", None)

    def body(x, lp):
        h = L.apply_norm(cfg, lp["ln1"], x)
        h = L.self_attention(lp["attn"], h, a, positions, causal=True,
                             kv_chunk=kv_chunk)
        x = x + h
        h = L.apply_norm(cfg, lp["ln_x"], x)
        k, v = _enc_kv(cfg, lp["xattn"], enc_out)
        h = _cross_attention(cfg, lp["xattn"], h, (k, v, kv_pos), positions,
                             kv_chunk=kv_chunk)
        x = x + h
        h = L.apply_norm(cfg, lp["ln2"], x)
        return x + L.mlp_apply(cfg, lp["mlp"], h), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, p["decoder"])
    x = L.apply_norm(cfg, p["final_norm"], x)
    w = p["embed"].T if cfg.tie_embeddings else p["head"]
    logits = jnp.einsum("btd,dv->btv", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


@dataclasses.dataclass
class WhisperCache:
    k: jax.Array            # (Ld, B, S_dec, KH, hd) decoder self-attn
    v: jax.Array
    xk: jax.Array           # (Ld, B, S_enc, KH, hd) cross-attn (static)
    xv: jax.Array
    pos: jax.Array


jax.tree_util.register_dataclass(
    WhisperCache, data_fields=["k", "v", "xk", "xv", "pos"], meta_fields=[])


def prefill(cfg: ModelConfig, p, batch: Dict[str, Any],
            ctx: ParallelContext = LOCAL, *, max_len: Optional[int] = None,
            kv_chunk: int = 1024):
    """Encode + run the decoder prompt, building both caches."""
    a = cfg.attention
    enc_out = _encode(cfg, p, batch["frames"], kv_chunk=kv_chunk)
    tokens = batch["tokens"]
    B, T = tokens.shape
    Senc = enc_out.shape[1]
    Smax = max_len or T
    x = jnp.take(p["embed"], tokens, axis=0).astype(jnp.bfloat16)
    positions = hint(jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32), (B, T)), "batch", None)
    kv_pos = hint(jnp.broadcast_to(
        jnp.arange(Senc, dtype=jnp.int32), (B, Senc)), "batch", None)

    def body(x, lp):
        h = L.apply_norm(cfg, lp["ln1"], x)
        q, k, v = L.attention_qkv(lp["attn"], h, a, positions)
        o = L.blocked_attention(q, k, v, positions, positions, causal=True,
                                scale=a.attn_scale, kv_chunk=kv_chunk)
        x = x + L.attention_out(lp["attn"], o)
        kc = jnp.pad(k, ((0, 0), (0, Smax - T), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, Smax - T), (0, 0), (0, 0)))
        h = L.apply_norm(cfg, lp["ln_x"], x)
        xk, xv = _enc_kv(cfg, lp["xattn"], enc_out)
        h = _cross_attention(cfg, lp["xattn"], h, (xk, xv, kv_pos), positions,
                             kv_chunk=kv_chunk)
        x = x + h
        h = L.apply_norm(cfg, lp["ln2"], x)
        return x + L.mlp_apply(cfg, lp["mlp"], h), (kc, vc, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, p["decoder"])
    x = L.apply_norm(cfg, p["final_norm"], x)
    w = p["embed"].T if cfg.tie_embeddings else p["head"]
    logits = jnp.einsum("bd,dv->bv", x[:, -1], w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    cache = WhisperCache(k=ks, v=vs, xk=xks, xv=xvs,
                         pos=jnp.asarray(T, jnp.int32))
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int,
               dtype=jnp.bfloat16) -> WhisperCache:
    a = cfg.attention
    Ld = cfg.num_layers
    kv = (Ld, batch, max_len, a.num_kv_heads, a.head_dim)
    xkv = (Ld, batch, enc_len, a.num_kv_heads, a.head_dim)
    return WhisperCache(k=jnp.zeros(kv, dtype), v=jnp.zeros(kv, dtype),
                        xk=jnp.zeros(xkv, dtype), xv=jnp.zeros(xkv, dtype),
                        pos=jnp.zeros((), jnp.int32))


def decode_step(cfg: ModelConfig, p, cache: WhisperCache, tokens,
                ctx: ParallelContext = LOCAL, *, kv_chunk: int = 2048):
    a = cfg.attention
    B = tokens.shape[0]
    pos = cache.pos
    x = jnp.take(p["embed"], tokens[:, None], axis=0).astype(jnp.bfloat16)
    q_pos = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    Senc = cache.xk.shape[2]
    xkv_pos = hint(jnp.broadcast_to(
        jnp.arange(Senc, dtype=jnp.int32), (B, Senc)), "batch", None)

    def body(x, xs):
        lp, kc, vc, xk, xv = xs
        S = kc.shape[1]
        h = L.apply_norm(cfg, lp["ln1"], x)
        q, k, v = L.attention_qkv(lp["attn"], h, a, q_pos)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, pos, 0, 0))
        kv_pos = hint(jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32), (B, S)), "batch", None)
        o = L.blocked_attention(q, kc, vc, q_pos, kv_pos,
                                scale=a.attn_scale, kv_chunk=kv_chunk)
        x = x + L.attention_out(lp["attn"], o)
        h = L.apply_norm(cfg, lp["ln_x"], x)
        h = _cross_attention(cfg, lp["xattn"], h, (xk, xv, xkv_pos), q_pos,
                             kv_chunk=kv_chunk)
        x = x + h
        h = L.apply_norm(cfg, lp["ln2"], x)
        x = x + L.mlp_apply(cfg, lp["mlp"], h)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (p["decoder"], cache.k, cache.v, cache.xk, cache.xv))
    x = L.apply_norm(cfg, p["final_norm"], x)
    w = p["embed"].T if cfg.tie_embeddings else p["head"]
    logits = jnp.einsum("bd,dv->bv", x[:, 0], w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits, WhisperCache(k=ks, v=vs, xk=cache.xk, xv=cache.xv,
                                pos=pos + 1)
