"""Schema-check every committed BENCH_*.json against docs/benchmarks.md.

The benchmarks doc is the schema: each ``## BENCH_<name>.json`` section
documents its artifact's fields as backticked paths in the first column of
a markdown table (dotted for nesting, ``*`` wildcards allowed, ``a / b``
and ``a``, ``b`` listing several fields in one row).  This checker keeps
doc and artifact from drifting:

  * every committed ``BENCH_*.json`` must have a doc section;
  * every documented field pattern must match at least one key path in
    the artifact it documents (a doc row pointing at nothing is stale).

Exit 0 = clean; 1 = drift, with one line per problem.

    python scripts/check_bench.py
"""
import fnmatch
import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC = ROOT / "docs" / "benchmarks.md"

_FIELD_RE = re.compile(r"`([^`]+)`")
_PATH_RE = re.compile(r"^[A-Za-z0-9_.*]+$")


def doc_sections(text):
    """``{artifact filename: [field patterns]}`` from the doc's tables."""
    sections = {}
    current = None
    for line in text.splitlines():
        m = re.match(r"^##\s+(BENCH_\w+\.json)\s*$", line)
        if m:
            current = sections.setdefault(m.group(1), [])
            continue
        if line.startswith("## "):
            current = None
            continue
        if current is None or not line.startswith("|"):
            continue
        first = line.split("|")[1].strip()
        if first in ("field", "") or set(first) <= {"-", " "}:
            continue
        for token in _FIELD_RE.findall(first):
            # one row may document several fields: "a / b", "a, b"
            for piece in re.split(r"[/,]", token):
                piece = piece.strip()
                if piece and _PATH_RE.match(piece):
                    current.append(piece)
    return sections


def key_paths(obj, prefix=""):
    """Every dotted key path in a JSON object, intermediate nodes
    included (lists are leaves)."""
    paths = []
    if isinstance(obj, dict):
        for k, v in obj.items():
            p = f"{prefix}.{k}" if prefix else str(k)
            paths.append(p)
            paths.extend(key_paths(v, p))
    return paths


def matches(pattern, paths):
    return any(fnmatch.fnmatchcase(p, pattern) for p in paths)


def main() -> int:
    sections = doc_sections(DOC.read_text())
    problems = []
    artifacts = sorted(ROOT.glob("BENCH_*.json"))
    if not artifacts:
        problems.append("no BENCH_*.json artifacts found at repo root")
    for art in artifacts:
        name = art.name
        if name not in sections:
            problems.append(f"{name}: no `## {name}` section in "
                            f"docs/benchmarks.md")
            continue
        if not sections[name]:
            problems.append(f"{name}: doc section documents no fields")
            continue
        try:
            data = json.loads(art.read_text())
        except ValueError as e:
            problems.append(f"{name}: unparseable JSON ({e})")
            continue
        paths = key_paths(data)
        for pattern in sections[name]:
            if not matches(pattern, paths):
                problems.append(
                    f"{name}: documented field `{pattern}` matches "
                    f"nothing in the artifact")
    for sec in sections:
        if not (ROOT / sec).exists():
            problems.append(f"docs/benchmarks.md documents {sec} but no "
                            f"such artifact is committed")
    for p in problems:
        print(f"DRIFT: {p}", file=sys.stderr)
    if not problems:
        print(f"check_bench: {len(artifacts)} artifacts match "
              f"docs/benchmarks.md")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
