"""Measure line coverage of src/repro under the test suite — stdlib only.

The container ships neither pytest-cov nor coverage.py, so this uses
`sys.settrace` scoped to repro frames: the global trace function returns
None for any frame whose code lives outside ``src/repro`` (no line-event
cost there — jax/XLA and test files run untraced), and records
``(file, line)`` hits inside it.  Executable lines come from compiling
each source file and walking its code objects' ``co_lines()`` tables, the
same basis coverage.py uses.

    python scripts/measure_coverage.py [pytest args...]
    python scripts/measure_coverage.py --fail-under 75 -x -q

Writes per-file and total percentages to stdout and the JSON summary to
``results/coverage.json``.  The measured total is the number the ci.sh
``--cov-fail-under`` ratchet is set from.
"""
import json
import pathlib
import sys
import threading

ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src" / "repro")


def executable_lines(path: pathlib.Path):
    """Line numbers the compiler would emit code for in one source file."""
    try:
        code = compile(path.read_text(), str(path), "exec")
    except SyntaxError:
        return set()
    lines = set()
    stack = [code]
    while stack:
        co = stack.pop()
        for _, _, line in co.co_lines():
            if line is not None:
                lines.add(line)
        for const in co.co_consts:
            if hasattr(const, "co_lines"):
                stack.append(const)
    return lines


class LineCollector:
    def __init__(self):
        self.hits = {}                      # filename -> set of lines

    def _local(self, frame, event, arg):
        if event == "line":
            self.hits[frame.f_code.co_filename].add(frame.f_lineno)
        return self._local

    def global_trace(self, frame, event, arg):
        if event != "call":
            return None
        fname = frame.f_code.co_filename
        if not fname.startswith(SRC):
            return None                     # untraced: no line-event cost
        if fname not in self.hits:
            self.hits[fname] = set()
        return self._local

    def install(self):
        sys.settrace(self.global_trace)
        threading.settrace(self.global_trace)

    def uninstall(self):
        sys.settrace(None)
        threading.settrace(None)


def main() -> int:
    args = sys.argv[1:]
    fail_under = None
    if "--fail-under" in args:
        i = args.index("--fail-under")
        fail_under = float(args[i + 1])
        del args[i:i + 2]
    pytest_args = args or ["-x", "-q"]

    import pytest
    collector = LineCollector()
    collector.install()
    try:
        rc = pytest.main(pytest_args)
    finally:
        collector.uninstall()
    if rc != 0:
        print(f"pytest exited {rc}; coverage not ratcheted", file=sys.stderr)
        return int(rc)

    per_file = {}
    total_exec = total_hit = 0
    for path in sorted(pathlib.Path(SRC).rglob("*.py")):
        exe = executable_lines(path)
        if not exe:
            continue
        hit = collector.hits.get(str(path), set()) & exe
        rel = str(path.relative_to(ROOT))
        per_file[rel] = {"lines": len(exe), "covered": len(hit),
                         "pct": round(100.0 * len(hit) / len(exe), 1)}
        total_exec += len(exe)
        total_hit += len(hit)

    total_pct = 100.0 * total_hit / max(total_exec, 1)
    width = max(len(f) for f in per_file) if per_file else 10
    for rel, row in sorted(per_file.items(), key=lambda kv: kv[1]["pct"]):
        print(f"{rel:<{width}}  {row['covered']:>5}/{row['lines']:<5} "
              f"{row['pct']:>6.1f}%")
    print(f"{'TOTAL':<{width}}  {total_hit:>5}/{total_exec:<5} "
          f"{total_pct:>6.1f}%")

    out = ROOT / "results" / "coverage.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps({
        "total_pct": round(total_pct, 2),
        "lines": total_exec, "covered": total_hit,
        "files": per_file,
    }, indent=1) + "\n")
    print(f"wrote {out}")

    if fail_under is not None and total_pct < fail_under:
        print(f"FAIL: coverage {total_pct:.1f}% < floor {fail_under}%",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
