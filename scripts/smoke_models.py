"""Dev harness: run reduced-config smoke for every arch (forward + prefill +
decode) on CPU.  Not a test file — used to iterate quickly during development.
"""
import sys
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.models import api

SMOKE_TRAIN = ShapeConfig("smoke_train", "train", 64, 2)
SMOKE_PREFILL = ShapeConfig("smoke_prefill", "prefill", 64, 2)
SMOKE_DECODE = ShapeConfig("smoke_decode", "decode", 64, 2)

ONLY = sys.argv[1:] if len(sys.argv) > 1 else None


def run(arch: str):
    cfg = registry.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    p = api.init_params(cfg, key)
    n = sum(x.size for x in jax.tree.leaves(p))
    batch = api.make_batch(cfg, SMOKE_TRAIN, key)
    batch.pop("labels", None)
    logits, aux = api.forward(cfg, p, batch)
    assert not bool(jnp.isnan(logits).any()), "nan in forward logits"
    if cfg.family == "dlrm":
        print(f"  {arch}: params={n:,} fwd={logits.shape} OK (no decode)")
        return
    pre_logits, cache = api.prefill(cfg, p, batch, max_len=SMOKE_DECODE.seq_len)
    assert not bool(jnp.isnan(pre_logits).any()), "nan in prefill"
    toks = jnp.zeros((SMOKE_DECODE.global_batch,), jnp.int32)
    dlogits, cache = api.decode_step(cfg, p, cache, toks)
    assert not bool(jnp.isnan(dlogits).any()), "nan in decode"
    print(f"  {arch}: params={n:,} fwd={logits.shape} "
          f"pre={pre_logits.shape} dec={dlogits.shape} OK")


fails = 0
for arch in (ONLY or registry.ALL_ARCHS):
    try:
        run(arch)
    except Exception:
        fails += 1
        print(f"  {arch}: FAIL")
        traceback.print_exc()
print("FAILURES:", fails)
sys.exit(1 if fails else 0)
