"""Assemble EXPERIMENTS.md: narrative + tables from results/dryrun.json."""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results"


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if b < 1024:
            return f"{b:.2f}{unit}"
        b /= 1024
    return f"{b:.2f}PiB"


def dryrun_table(data, tag, mesh=None):
    rows = [(k, v) for k, v in sorted(data.items())
            if k.startswith(tag + "/") and v.get("ok")
            and (mesh is None or k.endswith("/" + mesh))]
    out = ["| arch | shape | mesh | args/dev | temp/dev | FLOPs/dev | "
           "HBM B/dev | coll B/dev | compile |",
           "|---|---|---|---|---|---|---|---|---|"]
    for k, v in rows:
        _, arch, shape, _m = k.split("/")
        m = v["memory"]
        out.append(
            f"| {arch} | {shape} | {v['mesh']} | "
            f"{fmt_bytes(m['argument_bytes'])} | {fmt_bytes(m['temp_bytes'])} | "
            f"{v['flops_per_chip']:.2e} | {v['hbm_bytes_per_chip']:.2e} | "
            f"{v['collective_bytes_per_chip']:.2e} | {v['compile_s']:.0f}s |")
    return "\n".join(out), len(rows)


def roofline_table(data, tag):
    rows = [(k, v) for k, v in sorted(data.items())
            if k.startswith(tag + "/") and v.get("ok")
            and k.endswith("/single")]
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO FLOPs | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for k, v in rows:
        _, arch, shape, _ = k.split("/")
        out.append(
            f"| {arch} | {shape} | {v['compute_s']:.3f} | "
            f"{v['memory_s']:.3f} | {v['collective_s']:.3f} | "
            f"**{v['dominant']}** | {v['useful_flops_fraction']:.2f} | "
            f"{v['roofline_fraction']:.4f} |")
    return "\n".join(out), len(rows)


def compare_table(data):
    out = ["| cell | variant | compute s | memory s | collective s | "
           "useful | roofline frac | gain |",
           "|---|---|---|---|---|---|---|---|"]
    for k in sorted(data):
        if not k.startswith("optimized/") or not data[k].get("ok"):
            continue
        _, arch, shape, mesh = k.split("/")
        if mesh != "single":
            continue
        b = data.get(f"baseline/{arch}/{shape}/single", {})
        o = data[k]
        if not b.get("ok"):
            continue
        gain = o["roofline_fraction"] / max(b["roofline_fraction"], 1e-12)
        out.append(
            f"| {arch}/{shape} | baseline | {b['compute_s']:.2f} | "
            f"{b['memory_s']:.2f} | {b['collective_s']:.2f} | "
            f"{b['useful_flops_fraction']:.2f} | "
            f"{b['roofline_fraction']:.4f} | |")
        out.append(
            f"| | **optimized** | {o['compute_s']:.2f} | "
            f"{o['memory_s']:.2f} | {o['collective_s']:.2f} | "
            f"{o['useful_flops_fraction']:.2f} | "
            f"**{o['roofline_fraction']:.4f}** | **{gain:.1f}x** |")
    return "\n".join(out)


def cell(data, key):
    return data.get(key, {})


def main():
    data = json.loads((RESULTS / "dryrun.json").read_text())
    narrative = (ROOT / "scripts" / "experiments_narrative.md").read_text()
    dr_s, n_s = dryrun_table(data, "baseline", "single")
    dr_m, n_m = dryrun_table(data, "baseline", "multi")
    rf, _ = roofline_table(data, "baseline")
    rf_opt, _ = roofline_table(data, "optimized")
    cmp_tbl = compare_table(data)

    text = narrative
    text = text.replace("{{N_SINGLE}}", str(n_s))
    text = text.replace("{{N_MULTI}}", str(n_m))
    text = text.replace("{{DRYRUN_SINGLE}}", dr_s)
    text = text.replace("{{DRYRUN_MULTI}}", dr_m)
    text = text.replace("{{ROOFLINE_BASELINE}}", rf)
    text = text.replace("{{ROOFLINE_OPTIMIZED}}", rf_opt)
    text = text.replace("{{COMPARE}}", cmp_tbl)
    (ROOT / "EXPERIMENTS.md").write_text(text)
    print(f"EXPERIMENTS.md written ({n_s} single + {n_m} multi baseline cells)")


if __name__ == "__main__":
    main()
