"""Relative-link checker for the docs tree.

Scans markdown files for ``[text](target)`` links, ignores absolute URLs
and pure anchors, and verifies every relative target resolves to a real
file or directory (anchors within a target are stripped).  Exits non-zero
listing the broken links — the `docs` stage of scripts/ci.sh runs this over
docs/*.md and README.md so the paper→code map cannot rot silently.

    python scripts/check_links.py README.md docs/*.md
"""
import pathlib
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(path: pathlib.Path):
    broken = []
    for m in LINK_RE.finditer(path.read_text()):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            broken.append((path, target))
    return broken


def main(argv):
    files = [pathlib.Path(a) for a in argv] or [pathlib.Path("README.md")]
    broken = []
    checked = 0
    for f in files:
        if not f.exists():
            broken.append((f, "<file itself missing>"))
            continue
        checked += 1
        broken.extend(check_file(f))
    if broken:
        for path, target in broken:
            print(f"BROKEN LINK: {path}: {target}", file=sys.stderr)
        return 1
    print(f"link check OK: {checked} files, all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
