#!/usr/bin/env bash
# CI entry point: tier-1 tests (+ coverage floor when pytest-cov is
# available) + quickstart smoke + benchmarks, with BENCH_*.json archived.
#
#   bash scripts/ci.sh            # full gate
#   bash scripts/ci.sh --fast     # tests only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
# Coverage floor: the container image ships neither pytest-cov nor
# coverage, so the floor could not be measured when this stage landed —
# 80 is a provisional start; the first pytest-cov-equipped run should
# replace it with the measured baseline and ratchet from there.  Plain
# pytest remains the hard gate either way.
if python -c "import pytest_cov" >/dev/null 2>&1; then
    python -m pytest -x -q --cov=repro --cov-report=term \
        --cov-fail-under=80
else
    echo "(pytest-cov not installed; running without the coverage floor)"
    python -m pytest -x -q
fi

if [[ "${1:-}" != "--fast" ]]; then
    echo "== quickstart smoke (CPU) =="
    python examples/quickstart.py

    echo "== cluster serve benchmark -> BENCH_cluster.json =="
    python - <<'PY'
import sys
sys.path.insert(0, ".")
from benchmarks import cluster_session
for name, us, derived in cluster_session.run():
    print(f"{name},{us:.1f},{derived}")
PY

    echo "== sparsecore pipeline benchmark -> BENCH_sparsecore.json =="
    python benchmarks/sparsecore_pipeline.py

    echo "== archive benchmark artifacts =="
    mkdir -p artifacts
    cp BENCH_*.json artifacts/
    ls -l artifacts/
fi

echo "CI OK"
