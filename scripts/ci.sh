#!/usr/bin/env bash
# CI entry point: tier-1 tests (+ coverage floor when pytest-cov is
# available) + quickstart smoke + benchmarks, with BENCH_*.json archived.
#
#   bash scripts/ci.sh            # full gate
#   bash scripts/ci.sh --fast     # tests only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests + coverage floor =="
# Coverage floor: measured at 83.9% over the full suite by the stdlib
# tracer (scripts/measure_coverage.py — settrace line coverage of
# src/repro, executable lines from co_lines(); results/coverage.json has
# the per-file table).  The floor ratchets just below the measurement:
# raise it as tests grow.  measure_coverage runs pytest in-process with
# the same -x -q args and propagates its exit code, so the test gate is
# unchanged; pytest-cov takes over if the image ever gains it.
if python -c "import pytest_cov" >/dev/null 2>&1; then
    python -m pytest -x -q --cov=repro --cov-report=term \
        --cov-fail-under=83
else
    python scripts/measure_coverage.py --fail-under 83 -x -q
fi

if [[ "${1:-}" != "--fast" ]]; then
    echo "== docs stage: quickstart smoke + link check =="
    python examples/quickstart.py
    python scripts/check_links.py README.md docs/*.md

    echo "== serve stage: fast-path benchmark -> BENCH_cluster.json =="
    # before/after harness: per-token vs chunked decode on the PR-1 config;
    # exits nonzero on the 1.5x-vs-PR-1 throughput gate or if chunked
    # greedy outputs diverge from the per-token path
    python benchmarks/cluster_session.py --quick

    echo "== sparsecore pipeline benchmark -> BENCH_sparsecore.json =="
    python benchmarks/sparsecore_pipeline.py

    echo "== fleet stage: fleet serving benchmark -> BENCH_fleet.json =="
    # gates: 2-replica aggregate throughput >= 1.8x single replica,
    # zero lost requests across a mid-serve block failure (in-flight work
    # migrates to survivors), and the autoscaler exercises up AND down
    python benchmarks/fleet_serving.py --quick

    echo "== tenancy stage: mixed train+serve benchmark -> BENCH_tenancy.json =="
    # gates: elastic arm beats the static partition on combined
    # (train steps, serve SLO-goodput) through a diurnal day + block loss;
    # zero lost requests in both arms; the elastic arm preempts AND
    # resumes training; preempt -> resume-on-a-different-slice-shape loss
    # curve matches the uninterrupted run
    python benchmarks/mixed_tenancy.py --quick

    echo "== kvprefix stage: prefix-shared KV benchmark -> BENCH_kvprefix.json =="
    # gates: shared vs unshared greedy outputs bitwise-identical with zero
    # leaked pool blocks, >= 2x aggregate prefill-FLOPs reduction AND
    # >= 1.3x aggregate fleet tokens/s on the shared-header mix, and
    # prefix_affinity routing beats least_eta on prefix hit-rate
    python benchmarks/kv_prefix.py --quick

    echo "== quant stage: quantized fast path benchmark -> BENCH_quant.json =="
    # gates: int8-storage vs materialized-dequant greedy outputs bitwise
    # identical; int8 vs full-width token divergence <= 1%; >= 1.25x decode
    # tokens/s OR >= 1.8x lower weight-HBM bytes/token; grad int8 payload
    # ~4x below fp32 (payload-only accounting) with final loss within 5%
    python benchmarks/quantization.py --quick

    echo "== predict stage: predictive fleet benchmark -> BENCH_predict.json =="
    # gates: vectorized traffic generation >= 100x the legacy per-request
    # generator (with small-trace bitwise equivalence); the forecasting
    # autoscaler matches-or-beats reactive watermarks on SLO-goodput
    # through a diurnal day-with-failures and shrinks the burst-edge p95
    # TTFT >= 30%; the straggler detector fires >= 1 spare swap that
    # recovers step time under an injected 2x-slow block
    python benchmarks/predictive_fleet.py --quick

    echo "== obs stage: telemetry benchmark -> BENCH_obs.json =="
    # gates: traced fleet overhead <= 3% (min-of-N A/B or priced records,
    # whichever is less noisy); disabled-tracer serve run bitwise-identical
    # to the uninstrumented one; the diurnal day-with-failures replay
    # reconstructed exactly from the trace (failures, migrations,
    # predictive ups, straggler swaps) with a postmortem on the slice loss
    python benchmarks/observability.py --quick

    echo "== hetfleet stage: multi-generation fleet -> BENCH_hetfleet.json =="
    # gates: generation-aware placement (perf/Watt scale-ups, slo_tiered
    # routing, shrink-first capacity pressure) beats the generation-blind
    # baseline on fleet perf/Watt goodput; >= 1 cooperative partial shrink
    # (trainer hands back blocks, keeps running); zero dropped requests in
    # both arms; the shrink drill's loss curve is bitwise-identical to an
    # uninterrupted run.  Plus the seeded cross-machine soak (conservation
    # + leak-free pooled KV through random fail/repair/scale churn).
    python benchmarks/het_fleet.py --quick
    python -m pytest tests/test_hetfleet.py::TestCrossMachineSoak -q

    # doc/artifact drift: every committed BENCH_*.json must match its
    # schema section in docs/benchmarks.md
    python scripts/check_bench.py

    echo "== archive benchmark artifacts =="
    mkdir -p artifacts
    cp BENCH_*.json artifacts/
    ls -l artifacts/
fi

echo "CI OK"
