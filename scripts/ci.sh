#!/usr/bin/env bash
# CI entry point: tier-1 tests + quickstart smoke + cluster serve benchmark.
#
#   bash scripts/ci.sh            # full gate
#   bash scripts/ci.sh --fast     # tests only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    echo "== quickstart smoke (CPU) =="
    python examples/quickstart.py

    echo "== cluster serve benchmark -> BENCH_cluster.json =="
    python - <<'PY'
import sys
sys.path.insert(0, ".")
from benchmarks import cluster_session
for name, us, derived in cluster_session.run():
    print(f"{name},{us:.1f},{derived}")
PY
fi

echo "CI OK"
