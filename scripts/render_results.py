"""Render results/dryrun.json into the EXPERIMENTS.md tables, or a
`MetricsRegistry.dump()` flat metrics file into a readable table:

    python scripts/render_results.py [tag]          # dry-run tables
    python scripts/render_results.py metrics <file> # telemetry dump
"""
import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if b < 1024:
            return f"{b:.2f}{unit}"
        b /= 1024
    return f"{b:.2f}PiB"


def render_metrics(path):
    """Render one flat `MetricsRegistry.dump()` JSON (the exporter's
    ``name{label=value,...}: value`` keys) grouped by subsystem prefix.
    Histogram summaries and Series marker counts render as compact
    ``k=v`` strings."""
    data = json.loads(pathlib.Path(path).read_text())
    groups = {}
    for key, val in sorted(data.items()):
        prefix = key.split(".", 1)[0] if "." in key else "(other)"
        groups.setdefault(prefix, []).append((key, val))
    for prefix, rows in groups.items():
        print(f"\n### {prefix}\n")
        print("| metric | value |")
        print("|---|---|")
        for key, val in rows:
            if isinstance(val, dict):
                val = " ".join(f"{k}={v}" for k, v in val.items())
            print(f"| `{key}` | {val} |")


def main(tag="baseline", *rest):
    if tag == "metrics":
        render_metrics(rest[0])
        return
    data = json.loads((RESULTS / "dryrun.json").read_text())
    rows = [(k, v) for k, v in sorted(data.items())
            if k.startswith(tag + "/") and v.get("ok")]

    print(f"### Dry-run table (tag={tag}) — {len(rows)} cells\n")
    print("| arch | shape | mesh | args/dev | temp/dev | flops/dev | "
          "HBM bytes/dev | coll bytes/dev | compile |")
    print("|---|---|---|---|---|---|---|---|---|")
    for k, v in rows:
        _, arch, shape, mesh = k.split("/")
        m = v["memory"]
        print(f"| {arch} | {shape} | {v['mesh']} | "
              f"{fmt_bytes(m['argument_bytes'])} | "
              f"{fmt_bytes(m['temp_bytes'])} | "
              f"{v['flops_per_chip']:.2e} | "
              f"{v['hbm_bytes_per_chip']:.2e} | "
              f"{v['collective_bytes_per_chip']:.2e} | "
              f"{v['compile_s']:.0f}s |")

    print(f"\n### Roofline table (tag={tag}, single-pod 16x16, v5e terms)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant |"
          " useful-FLOPs frac | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    singles = [(k, v) for k, v in rows if k.endswith("/single")]
    for k, v in singles:
        _, arch, shape, _ = k.split("/")
        print(f"| {arch} | {shape} | {v['compute_s']:.3f} | "
              f"{v['memory_s']:.3f} | {v['collective_s']:.3f} | "
              f"**{v['dominant']}** | {v['useful_flops_fraction']:.2f} | "
              f"{v['roofline_fraction']:.3f} |")

    # pick hillclimb candidates
    print("\n### Hillclimb candidate analysis\n")
    worst = min(singles, key=lambda kv: kv[1]["roofline_fraction"]
                if kv[1]["flops_per_chip"] > 1e12 else 1)
    coll = max(singles, key=lambda kv: kv[1]["collective_s"]
               / max(kv[1]["compute_s"], 1e-9))
    print("worst roofline fraction (with real compute):", worst[0],
          worst[1]["roofline_fraction"])
    print("most collective-bound:", coll[0],
          coll[1]["collective_s"] / max(coll[1]["compute_s"], 1e-9))


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
