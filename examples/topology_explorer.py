"""Explore slice topologies through the `repro.cluster` API: geometries,
twisting, bisection, collective costs, goodput, and the autotopo search —
the OCS's §2 benefits, interactive.

Each geometry is genuinely allocated on the machine (exercising OCS port
accounting), probed via the slice's bound cost model, and freed.

    PYTHONPATH=src python examples/topology_explorer.py --chips 512
    PYTHONPATH=src python examples/topology_explorer.py --chips 128 --search
"""
import argparse

from repro.cluster import Supercomputer
from repro.core.autotopo import ModelProfile
from repro.core.topology import is_twistable


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=512)
    ap.add_argument("--search", action="store_true")
    args = ap.parse_args()

    sc = Supercomputer()
    print(f"geometries for {args.chips} chips "
          f"(slices are 4i x 4j x 4k, paper §2.5):")
    print(f"{'geometry':>12s} {'twist':>6s} {'bisec':>6s} {'diam':>5s} "
          f"{'AR(1GiB)':>9s} {'A2A(1GiB)':>10s}")
    for dims in sc.geometries(args.chips):
        for tw in ([False, True] if is_twistable(dims) else [False]):
            if tw and dims[0] * dims[1] * dims[2] > 1024:
                continue
            with sc.allocate(dims, twisted=tw) as sl:
                topo = sl.topology
                ar = sl.cost.all_reduce(2 ** 30) * 1e3
                a2a = (sl.cost.all_to_all(2 ** 30) * 1e3
                       if sl.num_chips <= 512 else float("nan"))
                diam, _ = (topo.diameter_and_avg_hops()
                           if sl.num_chips <= 512 else (-1, 0))
                print(f"{sl.describe():>12s} {str(tw):>6s} "
                      f"{topo.bisection_links():>6d} {diam:>5d} "
                      f"{ar:>8.1f}m {a2a:>9.1f}m")

    print(f"\ngoodput at this slice size (Fig 4):")
    for av in (0.99, 0.995, 0.999):
        g_ocs = sc.expected_goodput(args.chips, av, mode="ocs", trials=1000)
        g_static = sc.expected_goodput(args.chips, av, mode="static",
                                       trials=200)
        print(f"  availability {av}: OCS {g_ocs:.2f}  static {g_static:.2f}")

    if args.search:
        prof = ModelProfile("explorer-llm", params=70e9, layers=80,
                            d_model=8192, seq_len=2048, global_batch=32)
        print("\nautotopo search (Table 3):")
        with sc.allocate(args.chips) as sl:
            print(f"  holding {sl.describe()}; best on THIS slice: "
                  f"{sl.dryrun(prof).spec.label()}")
            for ev in sl.autotopo(prof, top_k=5):
                print(f"  {ev.geometry} {ev.spec.label()}: "
                      f"{ev.step_time * 1e3:.1f} ms/step "
                      f"(compute {ev.terms['compute'] * 1e3:.1f}m, "
                      f"tp {ev.terms['tp'] * 1e3:.1f}m, "
                      f"dp {ev.terms['dp'] * 1e3:.1f}m)")


if __name__ == "__main__":
    main()
