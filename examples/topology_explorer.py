"""Explore slice topologies: geometries, twisting, bisection, collective
costs, goodput, and the autotopo search — the OCS's §2 benefits, interactive.

    PYTHONPATH=src python examples/topology_explorer.py --chips 512
    PYTHONPATH=src python examples/topology_explorer.py --chips 128 --search
"""
import argparse

from repro.core.autotopo import ModelProfile, search
from repro.core.costmodel import CollectiveCostModel, TPU_V4
from repro.core.goodput import goodput_ocs, goodput_static
from repro.core.topology import SliceTopology, geometries_for, is_twistable


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=512)
    ap.add_argument("--search", action="store_true")
    args = ap.parse_args()

    cm = CollectiveCostModel(TPU_V4)
    print(f"geometries for {args.chips} chips "
          f"(slices are 4i x 4j x 4k, paper §2.5):")
    print(f"{'geometry':>12s} {'twist':>6s} {'bisec':>6s} {'diam':>5s} "
          f"{'AR(1GiB)':>9s} {'A2A(1GiB)':>10s}")
    for dims in geometries_for(args.chips):
        for tw in ([False, True] if is_twistable(dims) else [False]):
            t = SliceTopology(dims, twisted=tw)
            if t.num_chips > 1024 and tw:
                continue
            ar = cm.all_reduce(t, 2 ** 30) * 1e3
            a2a = (cm.all_to_all(t, 2 ** 30) * 1e3
                   if t.num_chips <= 512 else float("nan"))
            diam, _ = (t.diameter_and_avg_hops() if t.num_chips <= 512
                       else (-1, 0))
            print(f"{t.describe():>12s} {str(tw):>6s} "
                  f"{t.bisection_links():>6d} {diam:>5d} {ar:>8.1f}m "
                  f"{a2a:>9.1f}m")

    print(f"\ngoodput at this slice size (Fig 4):")
    for av in (0.99, 0.995, 0.999):
        print(f"  availability {av}: OCS "
              f"{goodput_ocs(args.chips, av, trials=1000):.2f}  static "
              f"{goodput_static(args.chips, av, trials=200):.2f}")

    if args.search:
        prof = ModelProfile("explorer-llm", params=70e9, layers=80,
                            d_model=8192, seq_len=2048, global_batch=32)
        print("\nautotopo search (Table 3):")
        for ev in search(prof, args.chips, top_k=5):
            print(f"  {ev.geometry} {ev.spec.label()}: "
                  f"{ev.step_time * 1e3:.1f} ms/step "
                  f"(compute {ev.terms['compute'] * 1e3:.1f}m, "
                  f"tp {ev.terms['tp'] * 1e3:.1f}m, "
                  f"dp {ev.terms['dp'] * 1e3:.1f}m)")


if __name__ == "__main__":
    main()
