"""Serve a small LM with batched requests through a cluster serve session.

    PYTHONPATH=src python examples/serve_lm.py --arch olmo-1b --requests 8

``--chunk`` picks the multi-step decode width (tokens per device dispatch);
1 is the per-token path with identical greedy output.
"""
import argparse

import jax
import numpy as np

from repro.cluster import SliceSpec, Supercomputer
from repro.configs import registry
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b",
                    choices=list(registry.ALL_ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=8)
    args = ap.parse_args()

    cfg = registry.get_reduced(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    sc = Supercomputer()
    with sc.allocate((4, 4, 8)) as sl:
        session = sl.serve(cfg, params,
                           SliceSpec(slots=args.slots, max_len=128,
                                     prompt_len=16, chunk=args.chunk))
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=rng.integers(4, 12))
            session.submit(prompt, max_new_tokens=args.new_tokens)
        stats = session.run()
        print(f"arch={args.arch} slice={sl.describe()} slots={args.slots}")
        for k, v in stats.items():
            print(f"  {k}: {v:.3f}" if isinstance(v, float)
                  else f"  {k}: {v}")
        for r in session.engine.queue[:3]:
            print(f"  req{r.rid}: prompt={list(r.prompt)[:6]}... "
                  f"-> {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
