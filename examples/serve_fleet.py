"""Elastic fleet serving demo: bursty traffic, autoscaling, a block failure.

    PYTHONPATH=src python examples/serve_fleet.py

One `Supercomputer` hosts an autoscaled pool of serve replicas behind an
SLO-aware router.  A bursty open-loop trace forces at least one scale-up
(new slice allocated through the OCS fabric) and, once the burst passes, a
drain + scale-down (slice freed) — both visible in `Supercomputer.events`.
Mid-run a serving block fails with no spare available: the replica's
in-flight requests re-route to the survivors and finish there.

Time here is virtual (fixed per-chunk cost) so the dynamics are
deterministic; the decoded tokens are real.
"""
import argparse

import jax

from repro.cluster import SliceSpec, Supercomputer
from repro.configs import registry
from repro.fleet import (AutoscalerConfig, FleetService, RouterConfig,
                         TrafficSpec, generate)
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b",
                    choices=list(registry.ALL_ARCHS))
    ap.add_argument("--duration", type=float, default=4.0)
    ap.add_argument("--policy", default="least_eta",
                    choices=("least_loaded", "least_eta", "round_robin"))
    ap.add_argument("--fail-at", type=float, default=2.2,
                    help="virtual time of the injected block failure "
                         "(mid-burst: the busiest replica dies)")
    args = ap.parse_args()

    cfg = registry.get_reduced(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    sc = Supercomputer(num_blocks=3)        # small machine: failures bite
    svc = FleetService(
        sc, cfg, params,
        SliceSpec(slots=4, max_len=64, prompt_len=16, chunk=8),
        geometry=(4, 4, 4),
        router=RouterConfig(policy=args.policy),
        autoscale=AutoscalerConfig(min_replicas=1, max_replicas=3,
                                   tick_s=0.05, cooldown_s=0.3,
                                   scale_up_backlog=3.0,
                                   scale_down_backlog=0.5,
                                   provision_s=0.1),
        timing=0.05)

    trace = generate(TrafficSpec(
        duration_s=args.duration, rate_rps=4.0, pattern="bursty",
        burst_x=10.0, burst_period_s=2.0, burst_len_s=0.5,
        new_tokens_choices=(8, 16, 32),
        new_tokens_weights=(0.5, 0.35, 0.15), prompt_len_max=12), seed=2)
    print(f"offered: {len(trace)} requests over {args.duration}s "
          f"(bursty), policy={args.policy}")

    # burn any idle spare just before killing the busiest replica's block,
    # so the loss cannot be absorbed by a swap: the slice goes LOST and its
    # in-flight requests must migrate to the survivors
    report = svc.run(trace,
                     fail_plan=[(args.fail_at - 0.05, "spare"),
                                (args.fail_at, "busiest")],
                     settle_s=3.0)

    print("\n-- fleet log --")
    for line in report.log:
        print("  " + line)
    print("\n-- machine events (Supercomputer.events) --")
    for e in sc.events:
        print("  " + e)

    print("\n-- report --")
    for k, v in report.to_dict().items():
        print(f"  {k}: {v}")

    ups = sum("scale-up: replica" in line or "undrained" in line
              for line in report.log)
    downs = sum("scale-down" in line for line in report.log)
    assert ups >= 1 and downs >= 1, "demo must scale up AND drain down"
    assert report.completed + report.dropped == report.offered
    print(f"\nOK: {ups} scale-up(s), {downs} drain+scale-down(s), "
          f"{report.migrated} migrated, {report.completed} completed")


if __name__ == "__main__":
    main()
