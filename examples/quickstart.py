"""Quickstart: allocate a slice from the supercomputer, train, then serve.

Everything goes through the `repro.cluster` session API — no manual mesh,
fabric, or scheduler wiring.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.cluster import SliceSpec, Supercomputer
from repro.configs import (OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)


def main():
    sc = Supercomputer()                       # 64 blocks = 4096 chips
    sl = sc.allocate((8, 8, 8))                # 512-chip slice, any blocks
    print(f"allocated {sl.describe()} on blocks {sl.blocks}")
    print(f"  all-reduce(1 GiB) estimate: "
          f"{sl.cost.all_reduce(2 ** 30) * 1e3:.1f} ms")

    run = RunConfig(
        model=registry.get_reduced("olmo-1b"),
        shape=ShapeConfig("quick", "train", 64, 8),
        parallel=ParallelConfig(remat="none"),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=5))

    with tempfile.TemporaryDirectory() as ckpt:
        train = sl.train(run, 30, ckpt_dir=ckpt, ckpt_every=10, log_every=5)
        print("\ntraining log:")
        for m in train.metrics_log:
            print(f"  step {m['step']:3d}  loss {m.get('loss', 0):.4f}")

        print("\nserving 4 requests on the trained weights:")
        serve = sl.serve(run.model, train.params,
                         SliceSpec(slots=2, max_len=96, prompt_len=16))
        for i in range(4):
            serve.submit(np.arange(8) + i, max_new_tokens=8)
        stats = serve.run()
        print(f"  {stats['requests_done']} requests, "
              f"{stats['tokens']} tokens, "
              f"{stats['tokens_per_s']:.1f} tok/s")

    sl.free()
    print(f"\nslice freed; machine utilization {sc.utilization():.2f}")


if __name__ == "__main__":
    main()
