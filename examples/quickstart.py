"""Quickstart: train a small LM for 30 steps, checkpoint, and decode.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import (OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)
from repro.models import api
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer


def main():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    run = RunConfig(
        model=registry.get_reduced("olmo-1b"),
        shape=ShapeConfig("quick", "train", 64, 8),
        parallel=ParallelConfig(remat="none"),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=5))

    with tempfile.TemporaryDirectory() as ckpt:
        trainer = Trainer(run, mesh, ckpt_dir=ckpt, ckpt_every=10)
        state = trainer.train(30, log_every=5)
        print("\ntraining log:")
        for m in trainer.metrics_log:
            print(f"  step {m['step']:3d}  loss {m.get('loss', 0):.4f}")

        print("\nserving 4 requests on the trained weights:")
        eng = ServeEngine(run.model, state.params, slots=2, max_len=96,
                          prompt_len=16)
        for i in range(4):
            eng.submit(np.arange(8) + i, max_new_tokens=8)
        stats = eng.run()
        print(f"  {stats['requests_done']} requests, "
              f"{stats['tokens']} tokens, "
              f"{stats['tokens_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
