"""Fault-tolerant training demo: block failure -> OCS re-route -> restore.

Reproduces the paper's §2.3 availability story end to end at container
scale through the `repro.cluster` API: two slices coexist on one machine
(a faulted run and a clean reference), a block dies mid-run, the
supercomputer swaps a spare in, and the training session restores from its
last checkpoint and finishes with bit-identical losses.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile

import numpy as np

from repro.cluster import Supercomputer
from repro.configs import (OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)


def main():
    run = RunConfig(
        model=registry.get_reduced("olmo-1b"),
        shape=ShapeConfig("ft", "train", 32, 8),
        parallel=ParallelConfig(remat="none"),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2))

    sc = Supercomputer()
    faulted = sc.allocate((8, 8, 8))
    reference = sc.allocate((8, 8, 8))
    print(f"faulted run on {faulted.describe()} blocks {faulted.blocks}")
    print(f"reference on   {reference.describe()} blocks {reference.blocks}")

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        ref = reference.train(run, 12, ckpt_dir=d2, ckpt_every=4,
                              log_every=1)
        sess = faulted.train(run, 12, ckpt_dir=d1, ckpt_every=4,
                             fail_at=7, log_every=1)

    print("\nmachine events:")
    for e in sc.events:
        print("  ", e)
    print("\nsession interruptions:")
    for ev in sess.interruptions:
        print(f"   {ev.kind}: {ev.detail} ({ev.circuits_moved} circuits, "
              f"{ev.downtime_s * 1e3:.0f} ms)")

    restarts = sum(1 for m in sess.metrics_log if m.get("event"))
    losses = {m["step"]: m["loss"] for m in sess.metrics_log if "loss" in m}
    ref_losses = {m["step"]: m["loss"] for m in ref.metrics_log
                  if "loss" in m}
    final = max(losses)
    print(f"\nsteps run:         {sess.state.step}")
    print(f"restarts:          {restarts}")
    print(f"final loss:        {losses[final]:.4f}")
    print(f"matches clean run: "
          f"{bool(np.isclose(losses[final], ref_losses[final], rtol=1e-5))}")

    faulted.free()
    reference.free()


if __name__ == "__main__":
    main()
