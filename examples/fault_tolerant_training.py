"""Fault-tolerant training demo: block failure -> OCS re-route -> restore.

Reproduces the paper's §2.3 availability story end to end at container
scale, and verifies the post-restore run matches an uninterrupted run.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import jax

from repro.configs import (OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)
from repro.train.fault import run_fault_drill


def main():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    run = RunConfig(
        model=registry.get_reduced("olmo-1b"),
        shape=ShapeConfig("ft", "train", 32, 8),
        parallel=ParallelConfig(remat="none"),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2))
    rep = run_fault_drill(run, mesh, total_steps=12, fail_at=7,
                          ckpt_every=4)
    print("scheduler events:")
    for e in rep.events:
        print("  ", e)
    print(f"\nsteps run:        {rep.steps_run}")
    print(f"restarts:         {rep.restarts}")
    print(f"circuits moved:   {rep.circuits_moved} (in "
          f"{rep.reroute_seconds * 1e3:.0f} ms — OCS MEMS switch time)")
    print(f"final loss:       {rep.final_loss:.4f}")
    print(f"matches clean run: {rep.losses_match_clean_run}")


if __name__ == "__main__":
    main()
