"""Mixed train+serve cluster: preemptible training under a diurnal fleet.

One `Supercomputer`, two tenants.  A serving fleet (priority 1) autoscales
against a diurnal traffic curve and — when the machine is full — evicts the
elastic training tenant (priority 0) through the scheduler: the trainer
checkpoints, frees its blocks, and resumes at the trough on whatever
geometry then fits, continuing the exact same loss curve.

    PYTHONPATH=src python examples/mixed_cluster.py
"""
import tempfile

import jax

from repro.cluster import (ElasticTrainJob, MixedTenancyDriver, SliceSpec,
                           Supercomputer, TrainTenantSpec)
from repro.configs import (OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)
from repro.fleet import AutoscalerConfig, FleetService, TrafficSpec, generate
from repro.models import api


def main():
    cfg = registry.get_reduced("olmo-1b")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("mixed", "train", 32, 4),
                    parallel=ParallelConfig(remat="none"),
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2))

    sc = Supercomputer(num_blocks=4)            # a small 256-chip machine
    svc = FleetService(
        sc, cfg, params,
        SliceSpec(slots=4, max_len=64, prompt_len=16, chunk=8),
        geometry=(4, 4, 4), initial_replicas=1, timing=0.15,
        autoscale=AutoscalerConfig(min_replicas=1, max_replicas=3,
                                   tick_s=0.05, cooldown_s=0.3,
                                   scale_up_backlog=3.0,
                                   scale_down_backlog=0.5,
                                   provision_s=0.1),
        priority=1, preempt_on_allocate=True)   # bursts may evict training

    with tempfile.TemporaryDirectory() as ckpt:
        job = ElasticTrainJob(sc, TrainTenantSpec(
            run=run, target_steps=60, ckpt_dir=ckpt,
            geometries=((4, 4, 12), (4, 4, 8), (4, 4, 4)),
            priority=0, base_step_s=0.25))
        job.try_start(0.0)
        print(f"training starts on {job.slice.dims} "
              f"(blocks {job.slice.blocks})")

        trace = generate(TrafficSpec(
            duration_s=4.0, rate_rps=14.0, pattern="diurnal",
            trough_frac=0.1, diurnal_period_s=4.0,
            new_tokens_choices=(16, 32), new_tokens_weights=(0.5, 0.5),
            prompt_len_max=8), seed=5)
        print(f"serving a diurnal day of {len(trace)} requests...")

        drv = MixedTenancyDriver(svc, job, window_s=0.5)
        rep = drv.run(trace, extra_windows=6)
        svc.close()

        print(f"\nserve : {rep.serve['completed']}/{rep.serve['offered']} "
              f"requests, slo_goodput={rep.serve['slo_goodput']:.2f}, "
              f"scale_ups={rep.serve['scale_ups']}, "
              f"scale_downs={rep.serve['scale_downs']}")
        print(f"train : {rep.train_steps}/{rep.train_target} steps, "
              f"{rep.train_preemptions} preemptions, "
              f"{rep.train_resumes} resumes, {rep.train_grows} grows")
        print(f"combined score: {rep.combined_score}")
        print("\ntraining odyssey:")
        for line in job.log:
            print(f"  {line}")


if __name__ == "__main__":
    main()
