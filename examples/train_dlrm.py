"""End-to-end driver: train a DLRM with the SparseCore embedding engine.

The paper's own workload (DLRM0: sparse embedding stack + dense tower), run
through the unified cluster API: allocate a slice on the `Supercomputer`,
open a training session on it, and let the pipelined multi-group embedding
executor (fused descriptor-stream lookups, software-pipelined exchanges)
drive the sparse stack — the default since the SparseCore pipeline v2.

``--scale full`` uses the real 20B-embedding config (needs a TPU pod);
``--scale demo`` (default) is a container-sized version with the same
structure: multiple multivalent zipf-skewed tables over several widths,
dedup'd lookups, dense interaction tower, Adam, checkpoints.

    PYTHONPATH=src python examples/train_dlrm.py --steps 150
"""
import argparse
import tempfile


from repro.cluster import Supercomputer
from repro.configs import (DLRMConfig, EmbeddingTableConfig, ModelConfig,
                           OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)


def demo_config(tables: int = 12, vocab: int = 5000):
    """Zipf-ish demo tables spread over three widths so the fused
    descriptor stream covers several width-groups."""
    dims = [16, 8, 32]
    specs = tuple(
        EmbeddingTableConfig(
            name=f"table_{i:02d}", vocab_size=vocab * (1 + i % 3),
            dim=dims[i % 3],
            avg_valency=[1.0, 4.0, 16.0][i % 3],
            max_valency=[1, 8, 32][i % 3],
            combiner="sum" if i % 2 == 0 else "mean")
        for i in range(tables))
    return ModelConfig(
        name="dlrm-demo", family="dlrm", num_layers=0, d_model=64, d_ff=0,
        vocab_size=0,
        dlrm=DLRMConfig(tables=specs, bottom_mlp=(64, 32),
                        top_mlp=(256, 128, 1), dense_features=13,
                        interaction="cat"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--scale", choices=["demo", "full"], default="demo")
    ap.add_argument("--pergroup", action="store_true",
                    help="disable the pipelined executor (legacy dataflow)")
    args = ap.parse_args()

    cfg = (registry.get_config("dlrm0") if args.scale == "full"
           else demo_config())
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("dlrm", "train", 1, args.batch),
        parallel=ParallelConfig(remat="none",
                                emb_pipeline=not args.pergroup),
        optimizer=OptimizerConfig(lr=3e-4, warmup_steps=20))

    sc = Supercomputer(num_blocks=8)
    with tempfile.TemporaryDirectory() as ckpt, \
            sc.allocate((4, 4, 4)) as slice_:
        print(f"slice: {slice_.describe()}")
        session = slice_.train(run, ckpt_dir=ckpt, ckpt_every=50)
        session.run(args.steps, log_every=10)
        print("\nstep   bce-loss")
        for m in session.metrics_log:
            if "loss" in m:
                print(f"{m['step']:5d}  {m['loss']:.4f}")
        losses = [m["loss"] for m in session.metrics_log if "loss" in m]
        first, last = losses[0], losses[-1]
        print(f"\nloss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
