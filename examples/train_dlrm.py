"""End-to-end driver: train a DLRM with the SparseCore embedding engine.

The paper's own workload (DLRM0: sparse embedding stack + dense tower).
``--scale full`` uses the real 20B-embedding config (needs a TPU pod);
``--scale demo`` (default) is a container-sized version with the same
structure: multiple multivalent zipf-skewed tables, dedup'd lookups, dense
interaction tower, Adam, checkpoints.

    PYTHONPATH=src python examples/train_dlrm.py --steps 150
"""
import argparse
import tempfile


from repro.configs import (DLRMConfig, EmbeddingTableConfig, ModelConfig,
                           OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)
from repro.train.trainer import Trainer


def demo_config(tables: int = 12, vocab: int = 5000, dim: int = 16):
    specs = tuple(
        EmbeddingTableConfig(
            name=f"table_{i:02d}", vocab_size=vocab * (1 + i % 3), dim=dim,
            avg_valency=[1.0, 4.0, 16.0][i % 3],
            max_valency=[1, 8, 32][i % 3],
            combiner="sum" if i % 2 == 0 else "mean")
        for i in range(tables))
    return ModelConfig(
        name="dlrm-demo", family="dlrm", num_layers=0, d_model=64, d_ff=0,
        vocab_size=0,
        dlrm=DLRMConfig(tables=specs, bottom_mlp=(64, 32),
                        top_mlp=(256, 128, 1), dense_features=13,
                        interaction="cat"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--scale", choices=["demo", "full"], default="demo")
    args = ap.parse_args()

    cfg = (registry.get_config("dlrm0") if args.scale == "full"
           else demo_config())
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("dlrm", "train", 1, args.batch),
        parallel=ParallelConfig(remat="none"),
        optimizer=OptimizerConfig(lr=3e-4, warmup_steps=20))

    with tempfile.TemporaryDirectory() as ckpt:
        trainer = Trainer(run, mesh, ckpt_dir=ckpt, ckpt_every=50)
        trainer.train(args.steps, log_every=10)
        print("\nstep   bce-loss")
        for m in trainer.metrics_log:
            if "loss" in m:
                print(f"{m['step']:5d}  {m['loss']:.4f}")
        first = next(m["loss"] for m in trainer.metrics_log if "loss" in m)
        last = [m["loss"] for m in trainer.metrics_log if "loss" in m][-1]
        print(f"\nloss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
