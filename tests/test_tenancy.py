"""Elastic mixed tenancy: scheduler priorities/preemption, cooperative
trainer eviction, checkpoint-elastic resume on a different slice shape
(bitwise loss-curve pin), and the mixed-workload driver."""
import tempfile

import numpy as np
import pytest

from repro.cluster import (CapacityError, ElasticTrainJob, Supercomputer,
                           TrainTenantSpec)
from repro.configs import (OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)
from repro.core.scheduler import SliceScheduler


def _run(arch="olmo-1b", gb=4, T=32, seed=0):
    return RunConfig(
        model=registry.get_reduced(arch),
        shape=ShapeConfig("t", "train", T, gb),
        parallel=ParallelConfig(remat="none"),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
        seed=seed)


class TestSchedulerPriorities:
    def test_jobs_carry_priority(self):
        s = SliceScheduler(num_blocks=4)
        j = s.allocate((4, 4, 4), priority=3)
        assert j.priority == 3

    def test_victims_lowest_priority_first(self):
        s = SliceScheduler(num_blocks=4)
        lo = s.allocate((4, 4, 4), priority=0)
        mid = s.allocate((4, 4, 4), priority=1)
        s.allocate((4, 4, 8), priority=2)           # 2 blocks, high
        victims = s.preemption_victims((4, 4, 8), priority=2)
        assert [v.job_id for v in victims] == [lo.job_id, mid.job_id]

    def test_no_victims_needed_when_fits(self):
        s = SliceScheduler(num_blocks=4)
        s.allocate((4, 4, 4), priority=0)
        assert s.preemption_victims((4, 4, 8), priority=1) == []

    def test_equal_priority_never_preempted(self):
        s = SliceScheduler(num_blocks=2)
        s.allocate((4, 4, 4), priority=1)
        s.allocate((4, 4, 4), priority=1)
        assert s.preemption_victims((4, 4, 4), priority=1) is None

    def test_contiguous_mode_offers_no_preemption(self):
        s = SliceScheduler(num_blocks=8, contiguous=True)
        s.allocate((4, 4, 4), priority=0)
        assert s.preemption_victims((8, 8, 8), priority=5) is None

    def test_fewest_blocks_evicted(self):
        s = SliceScheduler(num_blocks=6)
        big = s.allocate((4, 4, 16), priority=0)     # 4 blocks
        small = s.allocate((4, 4, 8), priority=0)    # 2 blocks
        victims = s.preemption_victims((4, 4, 8), priority=1)
        assert [v.job_id for v in victims] == [small.job_id]
        assert big.job_id in s.jobs


class TestFacadePreemption:
    def test_cooperative_tenant_is_evicted(self):
        sc = Supercomputer(num_blocks=2)
        victim = sc.allocate((4, 4, 8), priority=0)
        sess = victim.train(_run())                  # session, no steps yet

        freed = []

        def cooperate(_session, ev):
            if ev.kind == "preempt":
                victim.free()
                freed.append(ev)

        sess.add_listener(cooperate)
        winner = sc.allocate((4, 4, 8), priority=1, preempt=True)
        assert winner is not None and len(freed) == 1
        assert victim.status == "freed"
        winner.free()

    def test_uncooperative_tenant_keeps_running(self):
        sc = Supercomputer(num_blocks=2)
        squatter = sc.allocate((4, 4, 8), priority=0)
        with pytest.raises(CapacityError):
            sc.allocate((4, 4, 8), priority=1, preempt=True)
        assert squatter.status == "active"

    def test_preempt_never_evicts_higher_priority(self):
        sc = Supercomputer(num_blocks=2)
        sc.allocate((4, 4, 8), priority=5)
        assert sc.allocate((4, 4, 8), priority=1, preempt=True,
                           required=False) is None

    def test_run_pending_priority_order(self):
        sc = Supercomputer(num_blocks=2)
        order = []
        sc.submit((4, 4, 8), lambda sl: order.append("lo"), priority=0)
        sc.submit((4, 4, 8), lambda sl: order.append("hi"), priority=9)
        done = sc.run_pending()
        assert order == ["hi", "lo"]
        assert all(t.status == "done" for t in done)


class TestTrainerPreemption:
    def test_preempt_checkpoints_and_stops(self, tmp_path):
        sc = Supercomputer(num_blocks=8)
        sl = sc.allocate((4, 4, 8))
        sess = sl.train(_run(), ckpt_dir=str(tmp_path), ckpt_every=1000)
        state = sess.trainer.train(10, preempt_at=4, log_every=1)
        assert sess.preempted
        assert state.step == 4
        from repro.train import checkpoint as CKPT
        assert CKPT.latest_step(str(tmp_path)) == 4
        extra = CKPT.read_manifest(str(tmp_path))["extra"]
        assert extra["step"] == 4 and extra["data_seed"] == 0
        assert extra["slice_dims"] == [4, 4, 8]
        sl.free()

    def test_preempt_event_reaches_trainer(self, tmp_path):
        sc = Supercomputer(num_blocks=8)
        sl = sc.allocate((4, 4, 8))
        sess = sl.train(_run(), ckpt_dir=str(tmp_path))
        sl.request_preempt("test eviction")
        assert sess.trainer.preempt_requested
        # the flag makes the next run() checkpoint immediately and stop
        state = sess.run(10, log_every=1)
        assert sess.preempted and state.step == 0
        sl.free()

    def test_preempt_with_no_steps_left_still_serviced(self, tmp_path):
        """A preempt request entering `train` at step >= num_steps must
        still checkpoint and report preempted — and must not leak the flag
        into the next call."""
        sc = Supercomputer(num_blocks=8)
        sl = sc.allocate((4, 4, 4))
        sess = sl.train(_run(), ckpt_dir=str(tmp_path), ckpt_every=1000)
        state = sess.run(3, log_every=1)
        sess.trainer.request_preempt()
        state = sess.run(3, log_every=1)         # zero steps to run
        assert sess.preempted and state.step == 3
        from repro.train import checkpoint as CKPT
        assert CKPT.latest_step(str(tmp_path)) == 3
        # flag consumed: the next run makes real progress
        state = sess.run(5, log_every=1)
        assert not sess.preempted and state.step == 5
        sl.free()

    def test_resume_on_different_shape_bitwise(self, tmp_path):
        """THE elastic-checkpoint contract: preempt mid-run, resume on a
        slice with a different block count, and the loss curve is BITWISE
        equal to an uninterrupted run at the same global batch."""
        sc = Supercomputer(num_blocks=8)
        ref_slice = sc.allocate((4, 4, 8))
        ref = ref_slice.train(_run(), 8, log_every=1)
        ref_losses = {m["step"]: m["loss"] for m in ref.metrics_log
                      if "loss" in m}
        ref_slice.free()

        a = sc.allocate((4, 4, 8))                   # 2 blocks
        sess_a = a.train(_run(), ckpt_dir=str(tmp_path), ckpt_every=1000)
        state = sess_a.trainer.train(8, preempt_at=4, log_every=1)
        assert sess_a.preempted and state.step == 4
        got = {m["step"]: m["loss"] for m in sess_a.metrics_log
               if "loss" in m}
        a.free()

        b = sc.allocate((4, 4, 4))                   # 1 block: NEW shape
        sess_b = b.train(_run(), ckpt_dir=str(tmp_path), ckpt_every=1000)
        sess_b.run(8, log_every=1)
        got.update({m["step"]: m["loss"] for m in sess_b.metrics_log
                    if "loss" in m})
        b.free()

        assert set(got) >= set(ref_losses)
        for step, loss in ref_losses.items():
            assert got[step] == loss, (step, got[step], loss)

    def test_mismatched_data_seed_refuses_resume(self, tmp_path):
        sc = Supercomputer(num_blocks=8)
        sl = sc.allocate((4, 4, 4))
        sess = sl.train(_run(seed=0), ckpt_dir=str(tmp_path), ckpt_every=2)
        sess.run(2, log_every=1)
        sl.free()
        sl2 = sc.allocate((4, 4, 4))
        sess2 = sl2.train(_run(seed=1), ckpt_dir=str(tmp_path))
        with pytest.raises(AssertionError, match="data stream"):
            sess2.run(4)
        sl2.free()


class TestElasticTrainJob:
    def _spec(self, d, **kw):
        kw.setdefault("geometries", ((4, 4, 8), (4, 4, 4)))
        kw.setdefault("target_steps", 6)
        kw.setdefault("base_step_s", 0.25)
        return TrainTenantSpec(run=_run(), ckpt_dir=d, **kw)

    def test_preempt_resume_grow_lifecycle(self):
        with tempfile.TemporaryDirectory() as d:
            sc = Supercomputer(num_blocks=2)
            job = ElasticTrainJob(sc, self._spec(d, target_steps=20))
            assert job.try_start(0.0)
            assert job.slice.dims == (4, 4, 8)       # largest fits
            assert job.run_quantum(0.5) > 0

            # a priority-1 tenant takes the machine: cooperative eviction
            hi = sc.allocate((4, 4, 8), priority=1, preempt=True)
            assert job.state == "preempted" and job.preemptions == 1
            assert job.blocks_held == 0

            # machine still full: resume fails cleanly
            assert not job.try_start(1.0)
            hi.free()

            # resume; then the whole machine frees and the job grows
            sc2_busy = sc.allocate((4, 4, 4), priority=1)
            assert job.try_start(2.0)
            assert job.slice.dims == (4, 4, 4)       # squeezed to 1 block
            assert job.resumes == 1
            steps_small = job.run_quantum(0.5)
            sc2_busy.free()
            assert job.maybe_grow(3.0)
            assert job.slice.dims == (4, 4, 8) and job.grows == 1
            steps_big = job.run_quantum(0.5)
            assert steps_big > steps_small           # more blocks, more steps

    def test_quantum_scales_with_blocks(self):
        with tempfile.TemporaryDirectory() as d:
            sc = Supercomputer(num_blocks=4)
            job = ElasticTrainJob(sc, self._spec(
                d, target_steps=1000, geometries=((4, 4, 8),)))
            assert job.try_start()
            assert job.steps_in(0.5) == 4            # 2 blocks / 0.25s
            job.state = "done"                       # skip actual training

    def test_completion_frees_blocks(self):
        with tempfile.TemporaryDirectory() as d:
            sc = Supercomputer(num_blocks=2)
            job = ElasticTrainJob(sc, self._spec(d, target_steps=2))
            assert job.try_start()
            while job.state == "running":
                job.run_quantum(0.5)
            assert job.state == "done" and job.steps_done == 2
            assert len(sc.scheduler.free) == 2       # everything returned


class TestMixedDriver:
    def test_serve_burst_evicts_and_training_recovers(self):
        """A minimal end-to-end co-tenancy run: the serving burst forces a
        preemption through the scheduler, every request completes, and
        training still finishes its steps in the trough."""
        import jax

        from repro.cluster import MixedTenancyDriver, SliceSpec
        from repro.fleet import (AutoscalerConfig, FleetService,
                                 uniform_burst)
        from repro.models import api

        cfg = registry.get_reduced("olmo-1b")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            sc = Supercomputer(num_blocks=2)
            svc = FleetService(
                sc, cfg, params,
                SliceSpec(slots=2, max_len=48, prompt_len=8, chunk=4),
                geometry=(4, 4, 4), initial_replicas=1, timing=0.2,
                autoscale=AutoscalerConfig(
                    min_replicas=1, max_replicas=2, tick_s=0.1,
                    cooldown_s=0.2, scale_up_backlog=1.5,
                    scale_down_backlog=0.25, provision_s=0.05),
                priority=1, preempt_on_allocate=True)
            job = ElasticTrainJob(sc, TrainTenantSpec(
                run=_run(), target_steps=10, ckpt_dir=d,
                geometries=((4, 4, 4),), base_step_s=0.25))
            assert job.try_start(0.0)
            drv = MixedTenancyDriver(svc, job, window_s=0.5)
            burst = uniform_burst(8, new_tokens=8, prompt_len=6,
                                  t_arrival=0.25)
            rep = drv.run(burst, extra_windows=6, arm="elastic")
            svc.close()
            assert rep.serve["completed"] == 8
            assert rep.serve["dropped"] == 0
            assert rep.train_preemptions >= 1        # burst evicted training
            assert rep.train_resumes >= 1            # and it came back
            assert rep.train_steps == 10             # and finished
            assert rep.combined_score > 1.0
