"""Tests for the unified telemetry stack (`repro.obs`).

Pins the PR-9 contracts: span nesting/ordering under an injected virtual
clock, flight-ring overflow semantics, the no-op tracer's bitwise
non-interference with a pinned serve run, and the Perfetto JSON schema
round-trip.
"""
import json

import jax
import numpy as np
import pytest

from repro.cluster import SliceSpec
from repro.configs import registry
from repro.models import api
from repro.obs import (NOOP_TRACER, FlightRecorder, MetricsRegistry,
                       NoopTracer, Telemetry, Tracer, VirtualClock,
                       from_chrome_trace, to_chrome_trace)


@pytest.fixture(scope="module")
def small_model():
    cfg = registry.get_reduced("olmo-1b")
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


# -- tracer: nesting and ordering on a virtual clock --------------------------

class TestTracerVirtualClock:
    def test_span_nesting_parent_ids(self):
        clk = VirtualClock()
        tr = Tracer(clk)
        with tr.span("outer", track="t") as outer:
            clk.advance(1.0)
            with tr.span("inner", track="t") as inner:
                clk.advance(2.0)
        assert inner.parent == outer.sid
        assert outer.parent is None
        # children close first, record order follows completion order
        assert [s.name for s in tr.spans] == ["inner", "outer"]
        assert (outer.t0, outer.t1) == (0.0, 2.0)
        assert (inner.t0, inner.t1) == (1.0, 2.0)

    def test_nesting_is_per_track(self):
        clk = VirtualClock()
        tr = Tracer(clk)
        a = tr.begin("a", track="track_a")
        b = tr.begin("b", track="track_b")
        assert b.parent is None            # different lane, no nesting
        tr.end(b)
        tr.end(a)

    def test_end_closes_dangling_children(self):
        clk = VirtualClock()
        tr = Tracer(clk)
        outer = tr.begin("outer", track="t")
        clk.advance(1.0)
        tr.begin("leaked", track="t")      # never explicitly ended
        clk.advance(3.0)
        tr.end(outer)
        leaked = tr.find("leaked")[0]
        assert leaked.t1 == outer.t1 == 3.0
        assert not tr.open_spans()

    def test_complete_explicit_timestamps(self):
        tr = Tracer(VirtualClock())
        # virtual-time loops emit these out of order; read side sorts
        tr.complete("chunk", 5.0, 6.0, track="replica:0")
        tr.complete("chunk", 1.0, 2.0, track="replica:0")
        assert [s.t0 for s in tr.find("chunk")] == [5.0, 1.0]

    def test_events_time_ordered_on_read(self):
        tr = Tracer(VirtualClock())
        tr.event("late", t=9.0)
        tr.event("early", t=1.0)
        assert [e.name for e in tr.find_events()] == ["early", "late"]

    def test_retention_bounds_count_drops(self):
        tr = Tracer(VirtualClock(), max_spans=2, max_events=1)
        for i in range(4):
            tr.complete(f"s{i}", 0.0, 1.0)
            tr.event(f"e{i}", t=float(i))
        assert len(tr.spans) == 2 and tr.dropped_spans == 2
        assert len(tr.events) == 1 and tr.dropped_events == 3

    def test_virtual_clock_never_rewinds(self):
        clk = VirtualClock(5.0)
        clk.advance(3.0)
        assert clk() == 5.0
        clk.advance(7.0)
        assert clk() == 7.0


# -- flight recorder: ring overflow and postmortems ---------------------------

class TestFlightRecorder:
    def test_ring_overflow_keeps_newest(self):
        fr = FlightRecorder(capacity=3)
        for i in range(10):
            fr.record("event", f"e{i}", float(i))
        window = fr.snapshot()
        assert [r["name"] for r in window] == ["e7", "e8", "e9"]
        assert fr.total_records == 10
        # seq numbers survive the overflow (no renumbering)
        assert [r["seq"] for r in window] == [7, 8, 9]

    def test_last_n(self):
        fr = FlightRecorder(capacity=5)
        for i in range(5):
            fr.record("event", f"e{i}", float(i))
        assert [r["name"] for r in fr.last(2)] == ["e3", "e4"]
        assert fr.last(0) == []

    def test_postmortem_snapshots_window(self):
        fr = FlightRecorder(capacity=4)
        for i in range(6):
            fr.record("event", f"e{i}", float(i))
        pm = fr.postmortem("drill", t=6.0, job=3)
        assert [r["name"] for r in pm["window"]] == ["e2", "e3", "e4", "e5"]
        assert pm["detail"] == {"job": 3}
        # the snapshot is a copy: later records don't mutate it
        fr.record("event", "after", 7.0)
        assert [r["name"] for r in pm["window"]][-1] == "e5"

    def test_postmortem_cap_counts_drops(self):
        fr = FlightRecorder(capacity=2, max_postmortems=2)
        assert fr.postmortem("a") is not None
        assert fr.postmortem("b") is not None
        assert fr.postmortem("c") is None
        assert len(fr.postmortems) == 2 and fr.postmortems_dropped == 1

    def test_dump_postmortems(self, tmp_path):
        fr = FlightRecorder(capacity=2)
        fr.record("event", "boom", 1.0)
        fr.postmortem("lost", t=1.0)
        path = tmp_path / "pm.json"
        fr.dump_postmortems(str(path))
        data = json.loads(path.read_text())
        assert data["postmortems"][0]["reason"] == "lost"
        assert data["postmortems"][0]["window"][0]["name"] == "boom"


# -- telemetry facade ---------------------------------------------------------

class TestTelemetry:
    def test_event_lands_in_ring_exactly_once_enabled(self):
        obs = Telemetry(tracing=True, clock=VirtualClock())
        obs.event("machine.fail", cat="failure", block=3, t=1.0)
        assert len(obs.tracer.events) == 1
        assert len(obs.recorder.ring) == 1      # mirrored once, not twice

    def test_event_lands_in_ring_when_disabled(self):
        obs = Telemetry(tracing=False)
        obs.event("machine.fail", cat="failure", block=3, t=1.0)
        assert obs.tracer is NOOP_TRACER
        assert [r["name"] for r in obs.recorder.snapshot()] \
            == ["machine.fail"]

    def test_spans_mirror_into_ring(self):
        obs = Telemetry(tracing=True, clock=VirtualClock())
        with obs.span("work", track="t"):
            pass
        assert [r["kind"] for r in obs.recorder.snapshot()] == ["span"]

    def test_noop_default_is_shared_and_inert(self):
        obs = Telemetry()
        assert obs.tracer is NOOP_TRACER
        assert not obs.tracing
        ctx = obs.span("anything")
        assert ctx is NOOP_TRACER.span("x")     # one shared null context
        with ctx:
            pass
        assert NoopTracer.spans == [] and NoopTracer.events == []


# -- metrics registry ---------------------------------------------------------

class TestMetricsRegistry:
    def test_get_or_create_identity(self):
        reg = MetricsRegistry()
        c1 = reg.counter("fleet.drops", reason="stranded")
        c2 = reg.counter("fleet.drops", reason="stranded")
        c3 = reg.counter("fleet.drops", reason="wait_queue_full")
        assert c1 is c2 and c1 is not c3
        c1.inc(2)
        assert reg.value("fleet.drops", reason="stranded") == 2

    def test_dump_flat_keys(self):
        reg = MetricsRegistry()
        reg.counter("a.n", k="v").inc()
        reg.gauge("a.g").set(2.5)
        reg.histogram("a.h").observe(1.0)
        d = reg.dump()
        assert d["a.n{k=v}"] == 1
        assert d["a.g"] == 2.5
        assert d["a.h"]["count"] == 1

    def test_histogram_summary(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat")
        for v in range(1, 101):
            h.observe(float(v))
        s = h.summary()
        assert s["count"] == 100 and s["min"] == 1.0 and s["max"] == 100.0
        assert 45.0 <= s["p50"] <= 55.0
        assert 90.0 <= s["p95"] <= 100.0

    def test_series_cap_drops_oldest(self):
        reg = MetricsRegistry()
        s = reg.series("train.metrics", cap=4)
        for i in range(6):
            s.append({"step": i})
        assert s.dropped > 0
        assert s.samples[-1]["step"] == 5


# -- no-op non-interference: pinned serve run ---------------------------------

class TestNonInterference:
    def test_serve_tokens_bitwise_equal_with_and_without_obs(
            self, small_model):
        from repro.serve.engine import ServeEngine
        cfg, params = small_model
        spec = SliceSpec(slots=2, max_len=32, prompt_len=8, chunk=4)

        def run(obs):
            rng = np.random.default_rng(7)
            eng = ServeEngine(cfg, params, spec, obs=obs)
            reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=6,
                                            dtype=np.int32),
                               max_new_tokens=8) for _ in range(3)]
            eng.run(max_steps=100)
            return [list(map(int, r.out_tokens)) for r in reqs]

        base = run(None)
        traced = run(Telemetry(tracing=True, clock=VirtualClock()))
        assert base == traced
        assert all(len(t) == 8 for t in base)

    def test_engine_counter_views_match_registry(self, small_model):
        from repro.serve.engine import ServeEngine
        cfg, params = small_model
        obs = Telemetry()
        eng = ServeEngine(cfg, params,
                          SliceSpec(slots=1, max_len=32, prompt_len=8,
                                    chunk=4),
                          obs=obs)
        eng.submit(np.arange(6, dtype=np.int32), max_new_tokens=4)
        eng.run(max_steps=50)
        assert eng.prefill_flops_proxy > 0
        assert eng.prefill_flops_proxy == \
            obs.metrics.value("serve.prefill_flops_proxy")
        assert eng.kv_stats()["prefill_flops_proxy"] \
            == eng.prefill_flops_proxy


# -- Perfetto export round-trip -----------------------------------------------

class TestPerfettoRoundTrip:
    def _tracer(self):
        clk = VirtualClock()
        tr = Tracer(clk)
        tr.complete("chunk", 0.5, 0.75, cat="serve", track="replica:0",
                    stall_s=0.0)
        with tr.span("step", cat="train", track="train", step=3):
            clk.advance(1.25)
        tr.event("fail", cat="failure", track="replica:0", t=2.0, block=4)
        return tr

    def test_schema_shape(self):
        obj = to_chrome_trace(self._tracer(), process_name="p",
                              metrics={"fleet.routed": 3})
        te = obj["traceEvents"]
        meta = [e for e in te if e["ph"] == "M"]
        assert {"process_name", "thread_name"} <= {e["name"] for e in meta}
        xs = [e for e in te if e["ph"] == "X"]
        instants = [e for e in te if e["ph"] == "i"]
        assert len(xs) == 2 and len(instants) == 1
        assert instants[0]["s"] == "t"
        # ts/dur on the wire are microseconds
        chunk = next(e for e in xs if e["name"] == "chunk")
        assert chunk["ts"] == pytest.approx(0.5e6)
        assert chunk["dur"] == pytest.approx(0.25e6)
        assert obj["otherData"]["metrics"] == {"fleet.routed": 3}
        assert obj["otherData"]["dropped_spans"] == 0
        json.dumps(obj)                      # serializable as-is

    def test_round_trip_restores_seconds_and_tracks(self):
        tr = self._tracer()
        text = json.dumps(to_chrome_trace(tr))
        back = from_chrome_trace(text)
        spans = {s["name"]: s for s in back["spans"]}
        assert spans["chunk"]["track"] == "replica:0"
        assert spans["chunk"]["t0"] == pytest.approx(0.5)
        assert spans["chunk"]["dur"] == pytest.approx(0.25)
        assert spans["step"]["args"]["step"] == 3
        (ev,) = back["events"]
        assert (ev["name"], ev["track"], ev["t0"]) \
            == ("fail", "replica:0", pytest.approx(2.0))
        assert ev["args"]["block"] == 4
        assert sorted(back["tracks"].values()) \
            == ["replica:0", "train"]

    def test_telemetry_write_trace(self, tmp_path):
        obs = Telemetry(tracing=True, clock=VirtualClock())
        obs.metrics.counter("n").inc()
        with obs.span("w", track="t"):
            pass
        path = tmp_path / "trace.json"
        obs.write_trace(str(path))
        back = from_chrome_trace(str(path))
        assert [s["name"] for s in back["spans"]] == ["w"]
        assert back["otherData"]["metrics"]["n"] == 1
