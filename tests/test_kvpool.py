"""KV block pool + prefix trie: refcount conservation property tests.

Pins the PR-6 kvpool contract:
  * insert/match/release conserve references — after ANY interleaving of
    admit / retire (release) / migrate (release + re-admit elsewhere) /
    publish, every block is exactly free xor referenced and the reference
    total equals slot-table references + trie nodes (``KVPool.check``);
  * ``close`` always reaches zero allocated blocks (no leak), and the
    ``BlockPool`` primitives reject double-free / stray incref;
  * sharing caps: at least one suffix token stays private, the table's
    final block is never shared, and a matched prefix returns the SAME
    physical blocks that published it.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.serve.kvpool import BlockPool, KVPool, PrefixTrie


class TestBlockPool:
    def test_alloc_free_cycle(self):
        p = BlockPool(4, 8)
        blocks = [p.alloc() for _ in range(4)]
        assert sorted(blocks) == [0, 1, 2, 3] and p.alloc() is None
        assert p.free_blocks == 0 and p.allocated_blocks == 4
        for b in blocks:
            assert p.decref(b)
        assert p.free_blocks == 4
        p.check()

    def test_double_free_rejected(self):
        p = BlockPool(2, 4)
        b = p.alloc()
        p.decref(b)
        with pytest.raises(AssertionError):
            p.decref(b)

    def test_incref_of_free_block_rejected(self):
        p = BlockPool(2, 4)
        with pytest.raises(AssertionError):
            p.incref(0)

    def test_refcounted_sharing(self):
        p = BlockPool(2, 4)
        b = p.alloc()
        p.incref(b)
        assert not p.decref(b)          # still held
        assert p.decref(b)              # now freed
        p.check()


class TestTrieSharing:
    def test_publish_then_match_returns_same_blocks(self):
        kv = KVPool(num_blocks=16, block_size=4, slots=2, blocks_per_slot=4)
        prompt = np.arange(13, dtype=np.int32)       # 3 full blocks + 1
        t0, m0 = kv.admit(0, prompt)
        assert m0 == 0                               # cold trie
        kv.publish(0)
        t1, m1 = kv.admit(1, prompt)
        assert m1 == 3                               # all full blocks shared
        assert list(t1[:3]) == list(t0[:3])          # the SAME physical blocks
        assert set(t1[3:]).isdisjoint(set(t0[3:]))   # private remainder
        kv.check()
        kv.close()

    def test_at_least_one_suffix_token(self):
        """A prompt of exactly N full blocks shares at most N-1 of them —
        the admission still needs the last position's logits."""
        kv = KVPool(num_blocks=16, block_size=4, slots=2, blocks_per_slot=4)
        prompt = np.arange(8, dtype=np.int32)        # exactly 2 full blocks
        kv.admit(0, prompt)
        kv.publish(0)
        _, m = kv.admit(1, prompt)
        assert m == 1
        assert kv.match_len(prompt) == 1
        kv.close()

    def test_final_table_block_never_shared(self):
        """Overflow decode writes clamp into the last table block, so it
        must stay private even when the prompt could fill the table."""
        kv = KVPool(num_blocks=16, block_size=4, slots=2, blocks_per_slot=2)
        prompt = np.arange(8, dtype=np.int32)        # would fill both blocks
        kv.admit(0, prompt)
        kv.publish(0)
        _, m = kv.admit(1, prompt)
        assert m <= 1                                # block 1 of 2 private
        kv.close()

    def test_divergent_suffix_shares_common_prefix_only(self):
        kv = KVPool(num_blocks=32, block_size=4, slots=2, blocks_per_slot=4)
        a = np.concatenate([np.arange(8), np.full(5, 7)]).astype(np.int32)
        b = np.concatenate([np.arange(8), np.full(5, 9)]).astype(np.int32)
        kv.admit(0, a)
        kv.publish(0)
        _, m = kv.admit(1, b)
        assert m == 2                                # shared header only
        kv.close()

    def test_eviction_frees_trie_only_blocks(self):
        kv = KVPool(num_blocks=8, block_size=4, slots=2, blocks_per_slot=4)
        kv.admit(0, np.arange(16, dtype=np.int32))
        kv.publish(0)
        kv.release(0)                                 # trie-only now
        assert kv.pool.allocated_blocks > 0
        # both slot tables demand all 8 blocks: the trie must yield
        kv.admit(0, np.full(16, 3, np.int32), share=False)
        kv.admit(1, np.full(16, 5, np.int32), share=False)
        kv.check()
        kv.close()


class TestRefcountConservation:
    """Property tests over randomized admit/retire/migrate/publish
    interleavings: no block leaked or double-freed, ever."""

    @settings(max_examples=12, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3),      # op
                              st.integers(0, 3),      # slot
                              st.integers(0, 2),      # header id
                              st.integers(1, 17)),    # prompt len
                    min_size=1, max_size=40))
    def test_any_interleaving_conserves_blocks(self, ops):
        slots, bs, nb = 4, 4, 4
        kv = KVPool(num_blocks=2 * slots * nb, block_size=bs, slots=slots,
                    blocks_per_slot=nb)
        published = [False] * slots
        for op, slot, header, plen in ops:
            if op == 0:                               # admit (shared)
                prompt = np.concatenate([
                    np.full(8, 100 + header), np.arange(plen)]).astype(
                        np.int32)[:nb * bs]
                kv.admit(slot, prompt)
                published[slot] = False
            elif op == 1 and kv.table(slot) is not None:   # publish
                if not published[slot]:
                    kv.publish(slot)
                    published[slot] = True
            elif op == 2:                             # retire / export
                kv.release(slot)
                published[slot] = False
            else:                                     # migrate: re-admit
                dst = (slot + 1) % slots
                toks = kv._tokens[slot]
                kv.release(slot)
                published[slot] = False
                if toks is not None:
                    kv.admit(dst, toks)
                    published[dst] = False
            kv.check()                                # invariant after EVERY op
        kv.close()                                    # and zero blocks leaked

    @settings(max_examples=8, deadline=None)
    @given(st.lists(st.integers(0, 4), min_size=1, max_size=12))
    def test_shared_blocks_survive_publisher_exit(self, headers):
        """The publisher releasing its table must not free blocks a later
        admission still maps (trie holds them); dropping the trie too must
        free everything."""
        kv = KVPool(num_blocks=24, block_size=4, slots=3, blocks_per_slot=4)
        for h in headers:
            prompt = np.concatenate(
                [np.full(8, 50 + h), np.arange(6)]).astype(np.int32)
            kv.admit(0, prompt)
            kv.publish(0)
            t1, m1 = kv.admit(1, prompt)
            kv.release(0)                 # publisher gone
            if m1:
                for b in t1[:m1]:
                    assert kv.pool.refcount(b) >= 2   # table + trie
            kv.check()
            kv.release(1)
        kv.trie.drop_all()
        kv.check()
        assert kv.pool.allocated_blocks == 0

    def test_close_after_heavy_churn_is_leak_free(self):
        rng = np.random.RandomState(0)
        kv = KVPool(num_blocks=32, block_size=4, slots=4, blocks_per_slot=4)
        for i in range(200):
            slot = int(rng.randint(4))
            if rng.rand() < 0.25:
                kv.release(slot)
                continue
            header = int(rng.randint(3))
            prompt = np.concatenate([
                np.full(8, 200 + header),
                rng.randint(0, 99, size=int(rng.randint(1, 9)))]).astype(
                    np.int32)
            kv.admit(slot, prompt)
            if rng.rand() < 0.8:
                kv.publish(slot)
        kv.check()
        kv.close()
        assert kv.pool.free_blocks == 32


class TestTrieLRU:
    def test_evict_prefers_least_recent(self):
        pool = BlockPool(8, 2)
        trie = PrefixTrie(pool)
        a, b = pool.alloc(), pool.alloc()
        trie.insert(np.asarray([1, 2], np.int32), [a])
        trie.insert(np.asarray([3, 4], np.int32), [b])
        pool.decref(a)
        pool.decref(b)                   # both now trie-only
        trie.match(np.asarray([1, 2], np.int32))      # touch a (and incref)
        pool.decref(a)                   # give the match ref back
        assert trie.evict(1) == 1
        assert trie.n_nodes == 1
        # the stale chain [3,4] was evicted, the touched one survives
        assert trie.match_len(np.asarray([1, 2], np.int32)) == 1
        assert trie.match_len(np.asarray([3, 4], np.int32)) == 0
