"""Paged decode attention: Pallas kernel (interpret) vs the dense XLA
reference, across seq_lens / GQA / softcap / window — plus the dispatcher
policy (interpret auto-detect, impl selection) the serve fast path relies
on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import (
    paged_decode_attention_kernel_call, resolve_interpret)
from repro.kernels.flash_attention import flash_attention


def _qkv(key, B, H, KH, S, d, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, d)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KH, d)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KH, d)).astype(dtype)
    return q, k, v


class TestPagedDecodeKernel:
    @pytest.mark.parametrize("B,H,KH,S,d", [
        (1, 2, 2, 32, 16),           # MHA
        (2, 4, 2, 64, 32),           # GQA 2:1
        (3, 8, 1, 48, 8),            # MQA, non-pow2 batch
        (2, 4, 4, 40, 64),           # S not a multiple of bk (pad path)
    ])
    @pytest.mark.parametrize("kw", [
        dict(),
        dict(window=16),
        dict(softcap=30.0),
        dict(window=8, softcap=10.0),
    ])
    def test_matches_ref(self, B, H, KH, S, d, kw):
        key = jax.random.PRNGKey(B * S + H)
        q, k, v = _qkv(key, B, H, KH, S, d)
        lens = jax.random.randint(jax.random.fold_in(key, 7), (B,), 1, S + 1,
                                  jnp.int32)
        got = paged_decode_attention_kernel_call(q, k, v, lens, bk=16,
                                                 interpret=True, **kw)
        want = ref.paged_decode_attention_ref(q, k, v, lens, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_heterogeneous_lens_isolated_per_slot(self):
        """Each slot must see ONLY its own valid prefix: computing a slot
        alone (len rows, batch of 1) equals computing it in the mixed
        batch."""
        key = jax.random.PRNGKey(0)
        B, H, KH, S, d = 4, 4, 2, 64, 16
        q, k, v = _qkv(key, B, H, KH, S, d)
        lens = jnp.asarray([1, 17, 40, 64], jnp.int32)
        batched = paged_decode_attention_kernel_call(q, k, v, lens, bk=16,
                                                     interpret=True)
        for b in range(B):
            solo = ref.paged_decode_attention_ref(
                q[b:b + 1], k[b:b + 1], v[b:b + 1], lens[b:b + 1])
            np.testing.assert_allclose(np.asarray(batched[b]),
                                       np.asarray(solo[0]),
                                       rtol=2e-3, atol=2e-3)

    def test_rows_past_seq_len_ignored(self):
        """Garbage in the cache tail (stale rows of retired requests) must
        not leak into the output."""
        key = jax.random.PRNGKey(3)
        B, H, KH, S, d = 2, 2, 2, 32, 8
        q, k, v = _qkv(key, B, H, KH, S, d)
        lens = jnp.asarray([10, 20], jnp.int32)
        out1 = paged_decode_attention_kernel_call(q, k, v, lens, bk=8,
                                                  interpret=True)
        mask = (jnp.arange(S)[None, :, None, None]
                >= lens[:, None, None, None])
        k2 = jnp.where(mask, 1e9, k)
        v2 = jnp.where(mask, -1e9, v)
        out2 = paged_decode_attention_kernel_call(q, k2, v2, lens, bk=8,
                                                  interpret=True)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6, atol=1e-6)

    def test_zero_len_slot_returns_zeros(self):
        key = jax.random.PRNGKey(5)
        q, k, v = _qkv(key, 2, 2, 2, 16, 8)
        lens = jnp.asarray([0, 16], jnp.int32)
        out = paged_decode_attention_kernel_call(q, k, v, lens, bk=8,
                                                 interpret=True)
        np.testing.assert_allclose(np.asarray(out[0]), 0.0)
        assert np.abs(np.asarray(out[1])).sum() > 0

    def test_block_size_independence(self):
        key = jax.random.PRNGKey(11)
        q, k, v = _qkv(key, 2, 4, 2, 64, 16)
        lens = jnp.asarray([13, 57], jnp.int32)
        outs = [paged_decode_attention_kernel_call(q, k, v, lens, bk=bk,
                                                   interpret=True)
                for bk in (8, 16, 64)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       rtol=1e-5, atol=1e-5)

    def test_bf16(self):
        key = jax.random.PRNGKey(7)
        q, k, v = _qkv(key, 2, 2, 2, 32, 16, jnp.bfloat16)
        lens = jnp.asarray([9, 31], jnp.int32)
        got = paged_decode_attention_kernel_call(q, k, v, lens, bk=16,
                                                 interpret=True)
        want = ref.paged_decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_matches_dense_decode_semantics(self):
        """At full length the paged ref equals last-row causal flash
        attention — the dense decode it replaces."""
        key = jax.random.PRNGKey(9)
        B, H, KH, S, d = 2, 4, 2, 32, 16
        ks = jax.random.split(key, 3)
        qfull = jax.random.normal(ks[0], (B, H, S, d), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KH, d), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KH, d), jnp.float32)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        dense = ref.flash_attention_ref(qfull, kt, vt, causal=True)
        lens = jnp.full((B,), S, jnp.int32)
        paged = ref.paged_decode_attention_ref(qfull[:, :, -1], k, v, lens)
        np.testing.assert_allclose(np.asarray(paged),
                                   np.asarray(dense[:, :, -1]),
                                   rtol=1e-5, atol=1e-5)


class TestBlockTableKernel:
    """Block-table-indexed variant (pooled prefix-shared KV): the kernel
    reads the SAME logical view the gather-based reference materialises."""

    def _pooled(self, key, B, H, KH, NB, bs, nb, d, dtype=jnp.float32):
        ks = jax.random.split(key, 4)
        q = jax.random.normal(ks[0], (B, H, d)).astype(dtype)
        k = jax.random.normal(ks[1], (NB, bs, KH, d)).astype(dtype)
        v = jax.random.normal(ks[2], (NB, bs, KH, d)).astype(dtype)
        # random permutation tables: slots map disjoint-or-shared physical
        # blocks in arbitrary order, exactly what the pool hands out
        perm = jax.random.permutation(ks[3], NB)[:B * nb]
        tables = perm.reshape(B, nb).astype(jnp.int32)
        return q, k, v, tables

    @pytest.mark.parametrize("kw", [
        dict(),
        dict(window=16),
        dict(softcap=20.0),
    ])
    def test_matches_bt_ref(self, kw):
        from repro.kernels.decode_attention import (
            paged_decode_attention_bt_kernel_call)
        key = jax.random.PRNGKey(21)
        B, H, KH, NB, bs, nb, d = 3, 4, 2, 16, 8, 4, 16
        q, k, v, tables = self._pooled(key, B, H, KH, NB, bs, nb, d)
        lens = jnp.asarray([1, 13, 32], jnp.int32)
        got = paged_decode_attention_bt_kernel_call(
            q, k, v, lens, tables, interpret=True, **kw)
        want = ref.paged_decode_attention_bt_ref(q, k, v, lens, tables, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_shared_block_equals_private_copy(self):
        """Two slots mapping the SAME physical prefix block must read the
        same lanes a private copy would — sharing is invisible to the
        math."""
        key = jax.random.PRNGKey(22)
        B, H, KH, NB, bs, nb, d = 2, 2, 2, 8, 4, 2, 8
        q, k, v, _ = self._pooled(key, B, H, KH, NB, bs, nb, d)
        shared = jnp.asarray([[0, 1], [0, 2]], jnp.int32)   # block 0 shared
        lens = jnp.asarray([6, 6], jnp.int32)
        got = ref.paged_decode_attention_bt_ref(q, k, v, lens, shared)
        # materialise each slot's logical view densely
        for b, tb in enumerate([[0, 1], [0, 2]]):
            kc = jnp.concatenate([k[t] for t in tb])[None]
            vc = jnp.concatenate([v[t] for t in tb])[None]
            solo = ref.paged_decode_attention_ref(
                q[b:b + 1], kc, vc, lens[b:b + 1])
            np.testing.assert_allclose(np.asarray(got[b]),
                                       np.asarray(solo[0]),
                                       rtol=1e-6, atol=1e-6)

    def test_stale_pool_blocks_ignored(self):
        """Unmapped pool blocks and lanes past seq_len may hold garbage
        (retired requests, in-flight prefills) without leaking in."""
        from repro.kernels.decode_attention import (
            paged_decode_attention_bt_kernel_call)
        key = jax.random.PRNGKey(23)
        B, H, KH, NB, bs, nb, d = 2, 2, 2, 8, 4, 2, 8
        q, k, v, _ = self._pooled(key, B, H, KH, NB, bs, nb, d)
        tables = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
        lens = jnp.asarray([5, 7], jnp.int32)
        out1 = paged_decode_attention_bt_kernel_call(q, k, v, lens, tables,
                                                     interpret=True)
        # poison every unmapped block and every lane past each seq_len
        k2, v2 = k.at[4:].set(1e9), v.at[4:].set(-1e9)
        k2 = k2.at[1, 1:].set(1e9)       # slot 0 lanes [5, 8)
        v2 = v2.at[1, 1:].set(-1e9)
        k2 = k2.at[3, 3:].set(1e9)       # slot 1 lane 7
        v2 = v2.at[3, 3:].set(-1e9)
        out2 = paged_decode_attention_bt_kernel_call(q, k2, v2, lens, tables,
                                                     interpret=True)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6, atol=1e-6)

    def test_ops_bt_dispatcher(self):
        key = jax.random.PRNGKey(24)
        B, H, KH, NB, bs, nb, d = 2, 4, 2, 16, 8, 4, 16
        q, k, v, tables = self._pooled(key, B, H, KH, NB, bs, nb, d)
        lens = jnp.asarray([9, 27], jnp.int32)
        got = ops.paged_decode_attention_bt(q, k, v, lens, tables,
                                            impl="auto")
        want = ref.paged_decode_attention_bt_ref(q, k, v, lens, tables)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


class TestDispatchPolicy:
    def test_interpret_auto_detect(self):
        """interpret=None resolves by backend: interpret mode off-TPU."""
        assert resolve_interpret(None) == (jax.default_backend() != "tpu")
        assert resolve_interpret(True) is True
        assert resolve_interpret(False) is False

    def test_flash_attention_interpret_default_auto(self):
        """flash_attention(interpret=None) must run on the host backend
        (auto-selecting interpret mode) and match the oracle."""
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, 2, 32, 16), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 32, 16), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 32, 16), jnp.float32)
        got = flash_attention(q, k, v, bq=16, bk=16)       # interpret=None
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("impl", ["auto", "xla"])
    def test_ops_dispatcher(self, impl):
        key = jax.random.PRNGKey(2)
        q, k, v = _qkv(key, 2, 4, 2, 32, 16)
        lens = jnp.asarray([5, 29], jnp.int32)
        got = ops.paged_decode_attention(q, k, v, lens, impl=impl)
        want = ref.paged_decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_kernel_reachable_from_model_decode(self):
        """The serve decode path must be able to launch the Pallas kernel:
        with a static-window layer grouping, forcing decode_attn="paged"
        runs the kernel in-model (interpret here) and matches the dense
        path's logits bit-for-bit down to kernel tolerance."""
        import dataclasses as dc

        from repro.configs import registry
        from repro.models import api
        from repro.parallel.context import LOCAL

        cfg = registry.get_reduced("olmo-1b")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                  cfg.vocab_size, jnp.int32)
        _, cache = api.prefill(cfg, params, {"tokens": toks}, max_len=32)
        lens = jnp.full((2,), 8, jnp.int32)
        budget = jnp.full((2,), 2, jnp.int32)
        last = jnp.zeros((2,), jnp.int32)
        outs = {}
        for impl in ("dense", "paged"):
            ctx = dc.replace(LOCAL, decode_attn=impl, decode_kv_block=16)
            t, *_ = api.decode_n(cfg, params, cache, last, lens, budget,
                                 ctx, num_steps=2)
            outs[impl] = np.asarray(t)
        np.testing.assert_array_equal(outs["dense"], outs["paged"])

    def test_dispatcher_traced_window_falls_back_to_xla(self):
        """A traced (per-layer scanned) window must lower through the XLA
        path even when the kernel is forced."""
        key = jax.random.PRNGKey(4)
        q, k, v = _qkv(key, 2, 2, 2, 32, 8)
        lens = jnp.asarray([10, 30], jnp.int32)

        @jax.jit
        def f(win):
            return ops.paged_decode_attention(q, k, v, lens, window=win,
                                              impl="pallas")

        got = f(jnp.asarray(8, jnp.int32))
        want = ref.paged_decode_attention_ref(q, k, v, lens, window=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
