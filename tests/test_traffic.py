"""Vectorized traffic engine invariants.

The structure-of-arrays generator (`generate_trace`) must be a *bitwise*
drop-in for the per-request legacy generator (`generate_legacy`) — same
(spec, seed) in, same arrivals, prompts, tiers, and deadlines out, down to
the float — because the fleet benchmarks compare runs across both forms
and any drift would silently unpin every downstream artifact.  The
per-column RNG substreams make that equivalence structural (array fills
and scalar draws consume the same bits); these tests are the lock on it.
"""
import numpy as np
import pytest

from repro.fleet import TrafficSpec, generate, generate_trace
from repro.fleet.traffic import generate_legacy

SPECS = {
    "poisson": TrafficSpec(duration_s=20.0, rate_rps=8.0),
    "bursty": TrafficSpec(duration_s=16.0, rate_rps=6.0, pattern="bursty",
                          burst_x=4.0, burst_period_s=4.0, burst_len_s=1.0),
    "diurnal": TrafficSpec(duration_s=16.0, rate_rps=8.0, pattern="diurnal",
                           diurnal_period_s=8.0, trough_frac=0.25),
    "header_fewshot": TrafficSpec(duration_s=10.0, rate_rps=10.0,
                                  header_len=6, fewshot_len=8,
                                  fewshot_pool=3, fewshot_prob=0.5),
}


def _assert_request_equal(a, b):
    assert a.fid == b.fid
    assert a.t_arrival == b.t_arrival        # bitwise float, no tolerance
    assert a.max_new_tokens == b.max_new_tokens
    assert a.tier == b.tier
    assert a.ttft_slo_s == b.ttft_slo_s
    assert a.prompt.dtype == b.prompt.dtype == np.int32
    assert np.array_equal(a.prompt, b.prompt)


class TestBitwisePin:
    @pytest.mark.parametrize("name", sorted(SPECS))
    def test_trace_matches_legacy_bitwise(self, name):
        spec = SPECS[name]
        trace = generate_trace(spec, seed=17)
        legacy = generate_legacy(spec, seed=17)
        assert len(trace) == len(legacy) > 0
        for a, b in zip(trace.materialize(), legacy):
            _assert_request_equal(a, b)

    def test_generate_is_materialized_trace(self):
        spec = SPECS["bursty"]
        for a, b in zip(generate(spec, seed=4),
                        generate_trace(spec, seed=4).materialize()):
            _assert_request_equal(a, b)

    def test_lazy_request_matches_materialize(self):
        trace = generate_trace(SPECS["poisson"], seed=9)
        mat = trace.materialize()
        for i in (0, len(trace) // 2, len(trace) - 1):
            _assert_request_equal(trace.request(i), mat[i])


class TestTraceColumns:
    def test_sorted_and_consistent(self):
        spec = SPECS["diurnal"]
        trace = generate_trace(spec, seed=2)
        n = len(trace)
        assert np.all(np.diff(trace.t_arrival) >= 0)
        assert float(trace.t_arrival[-1]) < spec.duration_s
        # flat token buffer: offsets are the exclusive prefix sum of lengths
        off = np.zeros(n, dtype=np.int64)
        np.cumsum(trace.prompt_len[:-1], dtype=np.int64, out=off[1:])
        assert np.array_equal(trace.prompt_off, off)
        assert trace.tail_tokens.size == int(trace.prompt_len.sum())
        assert trace.tokens_offered == int(trace.new_tokens.sum())
        assert np.all((trace.tier_idx >= 0)
                      & (trace.tier_idx < len(spec.tiers)))

    def test_prompt_slicing(self):
        trace = generate_trace(SPECS["poisson"], seed=6)
        i = len(trace) // 3
        p = trace.prompt(i)
        o = int(trace.prompt_off[i])
        assert np.array_equal(
            p[-int(trace.prompt_len[i]):],
            trace.tail_tokens[o:o + int(trace.prompt_len[i])])


class TestVectorizedRate:
    @pytest.mark.parametrize("name", sorted(SPECS))
    def test_rate_at_array_matches_scalar(self, name):
        spec = SPECS[name]
        ts = np.linspace(0.0, spec.duration_s, 101)
        vec = spec.rate_at(ts)
        assert isinstance(vec, np.ndarray) and vec.dtype == np.float64
        scalars = np.array([spec.rate_at(float(t)) for t in ts])
        assert np.array_equal(vec, scalars)
        assert isinstance(spec.rate_at(0.0), float)
        assert float(np.max(vec)) <= spec.rate_max + 1e-12

    def test_mean_offered_tokens_per_s(self):
        spec = TrafficSpec(duration_s=10.0, rate_rps=4.0)
        got = spec.mean_offered_tokens_per_s()
        assert got == pytest.approx(4.0 * spec.mean_new_tokens())

    def test_thinning_tracks_diurnal_shape(self):
        """Arrivals must be denser at the diurnal peak than the trough —
        the thinning is against the true rate, not the peak envelope."""
        spec = TrafficSpec(duration_s=400.0, rate_rps=8.0,
                           pattern="diurnal", diurnal_period_s=8.0,
                           trough_frac=0.1)
        ts = generate_trace(spec, seed=1).t_arrival
        phase = ts % spec.diurnal_period_s
        near_peak = np.sum(np.abs(phase - 4.0) < 1.0)
        near_trough = np.sum((phase < 1.0) | (phase > 7.0))
        assert near_peak > 3 * near_trough
