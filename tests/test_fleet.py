"""Fleet serving invariants: conservation, drain-before-free, scaling.

The heavyweight guarantees of `repro.fleet`:

  * **conservation** — every submitted request completes exactly once or is
    reported dropped, including across replica failure and drain
    (migrated requests finish on survivors with their full token budget);
  * **drain-before-free** — the autoscaler never frees a replica that still
    owes tokens (`ServeReplica.free` hard-errors, and full autoscaled runs
    finish without tripping it);
  * **throughput scaling** — N replicas deliver ≥ 0.9·N× one replica's
    aggregate tokens/s on uniform load (virtual time, fixed chunk cost).

Deterministic mode (``timing=<float>``) replaces measured chunk latency
with a constant on the virtual clock, so these tests are exact and fast
while still decoding real tokens through the real engines.
"""
import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.cluster import SliceError, SliceSpec, Supercomputer
from repro.configs import registry
from repro.core.goodput import goodput_ocs, goodput_static, served_goodput
from repro.fleet import (Autoscaler, AutoscalerConfig, FleetService,
                         ForecastConfig, RateForecaster, ReplicaError,
                         RouterConfig, TrafficSpec, generate,
                         generate_trace, uniform_burst)
from repro.models import api

CHUNK_S = 0.01                      # fixed virtual chunk cost (deterministic)
SPEC = SliceSpec(slots=2, max_len=48, prompt_len=8, chunk=4)


_MODEL = {}


def _model():
    """Module-memoized tiny model (plain function so the hypothesis-shim
    property tests can use it too — the shim can't mix fixtures with
    strategy arguments)."""
    if "m" not in _MODEL:
        cfg = registry.get_reduced("olmo-1b")
        _MODEL["m"] = (cfg, api.init_params(cfg, jax.random.PRNGKey(0)))
    return _MODEL["m"]


@pytest.fixture(scope="module")
def small_model():
    return _model()


def _service(small_model, *, num_blocks=8, replicas=1, autoscale=None,
             router=None, timing=CHUNK_S, **kw):
    cfg, params = small_model
    sc = Supercomputer(num_blocks=num_blocks)
    return sc, FleetService(sc, cfg, params, SPEC, geometry=(4, 4, 4),
                            initial_replicas=replicas, autoscale=autoscale,
                            router=router, timing=timing, **kw)


def _assert_conserved(requests, report):
    """Every request terminal exactly once; done => full token budget."""
    assert report.completed + report.dropped == len(requests)
    for r in requests:
        assert r.status in ("done", "dropped"), (r.fid, r.status)
        if r.status == "done":
            assert len(r.out_tokens) == r.max_new_tokens, \
                (r.fid, len(r.out_tokens), r.max_new_tokens)
            assert r.t_first is not None and r.t_done is not None
            assert r.t_arrival <= r.t_first <= r.t_done
        else:
            assert r.t_done is None


class TestTraffic:
    def test_deterministic_and_sorted(self):
        spec = TrafficSpec(duration_s=4.0, rate_rps=6.0, pattern="bursty")
        a, b = generate(spec, seed=3), generate(spec, seed=3)
        assert len(a) == len(b) > 0
        assert all(x.t_arrival == y.t_arrival for x, y in zip(a, b))
        ts = [r.t_arrival for r in a]
        assert ts == sorted(ts)
        assert all(0 <= t < spec.duration_s for t in ts)

    def test_mean_rate_tracks_spec(self):
        spec = TrafficSpec(duration_s=50.0, rate_rps=8.0)
        n = len(generate(spec, seed=0))
        assert abs(n - 400) < 100          # ~4 sigma for Poisson(400)

    def test_bursty_rate_peaks(self):
        spec = TrafficSpec(pattern="bursty", rate_rps=2.0, burst_x=5.0,
                           burst_period_s=4.0, burst_len_s=1.0)
        assert spec.rate_at(0.5) == 10.0
        assert spec.rate_at(2.0) == 2.0
        assert spec.rate_max == 10.0

    def test_diurnal_rate_between_trough_and_peak(self):
        spec = TrafficSpec(pattern="diurnal", rate_rps=8.0, trough_frac=0.25,
                           diurnal_period_s=8.0)
        assert np.isclose(spec.rate_at(0.0), 2.0)
        assert np.isclose(spec.rate_at(4.0), 8.0)
        for t in np.linspace(0, 8, 33):
            assert 2.0 - 1e-9 <= spec.rate_at(t) <= 8.0 + 1e-9

    def test_slo_tiers_assigned(self):
        reqs = generate(TrafficSpec(duration_s=30.0, rate_rps=5.0), seed=1)
        tiers = {r.tier for r in reqs}
        assert tiers == {"interactive", "batch"}
        assert all(r.ttft_slo_s > 0 for r in reqs)


class TestRoutingConservation:
    def test_uniform_load_all_complete(self, small_model):
        _, svc = _service(small_model, replicas=2)
        reqs = uniform_burst(8, new_tokens=6, prompt_len=6)
        rep = svc.run(reqs)
        _assert_conserved(reqs, rep)
        assert rep.dropped == 0
        assert rep.tokens_served == 8 * 6

    def test_conserved_across_replica_failure(self, small_model):
        """fail_block with no spares kills a replica mid-serve: its in-flight
        requests must complete on the survivor, not error or vanish."""
        sc, svc = _service(small_model, num_blocks=2, replicas=2)
        reqs = uniform_burst(8, new_tokens=8, prompt_len=6)
        rep = svc.run(reqs, fail_plan=[(2.5 * CHUNK_S, "replica:0")])
        _assert_conserved(reqs, rep)
        assert rep.dropped == 0, "survivor had headroom; nothing may drop"
        assert rep.failures == 1
        assert rep.migrated > 0, "the failed replica held in-flight work"
        migrated = [r for r in reqs if r.migrations > 0]
        assert all(len(r.replicas) >= 2 for r in migrated)
        assert rep.slo_attainment > 0

    @settings(max_examples=5, deadline=None)
    @given(st.integers(min_value=3, max_value=10),
           st.integers(min_value=1, max_value=7),
           st.sampled_from(["least_loaded", "least_eta", "round_robin"]))
    def test_conservation_property(self, n_requests, fail_chunk, policy):
        """Random load size × failure timing × policy: requests are conserved
        whether the failure lands during prefill waves, mid-decode, or after
        the work already drained."""
        cfg, params = _model()
        sc = Supercomputer(num_blocks=2)
        svc = FleetService(sc, cfg, params, SPEC, geometry=(4, 4, 4),
                           initial_replicas=2, timing=CHUNK_S,
                           router=RouterConfig(policy=policy))
        reqs = uniform_burst(n_requests, new_tokens=5, prompt_len=4,
                             seed=n_requests)
        rep = svc.run(reqs, fail_plan=[(fail_chunk * CHUNK_S, "replica:0")])
        _assert_conserved(reqs, rep)
        assert rep.dropped == 0

    def test_stranded_requests_dropped_when_capacity_never_returns(
            self, small_model):
        """Every block dies with no repairs scheduled: even with an
        autoscaler wanting to grow, the loop must terminate and report the
        unfinishable requests as dropped — not spin ticks to max_iters."""
        cfg, params = small_model
        sc = Supercomputer(num_blocks=2)
        svc = FleetService(
            sc, cfg, params, SPEC, geometry=(4, 4, 4), initial_replicas=2,
            timing=CHUNK_S,
            autoscale=AutoscalerConfig(min_replicas=1, max_replicas=2,
                                       tick_s=5 * CHUNK_S,
                                       cooldown_s=10 * CHUNK_S,
                                       provision_s=0.0))
        reqs = uniform_burst(6, new_tokens=8, prompt_len=4)
        rep = svc.run(reqs, fail_plan=[(1.5 * CHUNK_S, "replica:0"),
                                       (2.5 * CHUNK_S, "replica:1")])
        _assert_conserved(reqs, rep)
        assert rep.dropped > 0
        assert rep.failures == 2

    def test_backpressure_drops_are_reported(self, small_model):
        """Open-loop overload with a tiny wait queue: drops happen, are
        counted, and completed+dropped still covers every request."""
        _, svc = _service(small_model, replicas=1,
                          router=RouterConfig(max_queue_per_replica=2),
                          max_wait_queue=2)
        reqs = uniform_burst(12, new_tokens=6, prompt_len=6)
        rep = svc.run(reqs)
        _assert_conserved(reqs, rep)
        assert rep.dropped > 0
        assert rep.served_goodput < 1.0


class TestDrainBeforeFree:
    def test_free_with_inflight_raises(self, small_model):
        _, svc = _service(small_model, replicas=1)
        rep = svc.replicas[0]
        rep.dispatch(uniform_burst(1, new_tokens=4, prompt_len=4)[0])
        with pytest.raises(ReplicaError):
            rep.free()

    def test_draining_session_rejects_submits(self, small_model):
        _, svc = _service(small_model, replicas=1)
        rep = svc.replicas[0]
        rep.drain()
        with pytest.raises(SliceError):
            rep.session.submit(np.arange(4), max_new_tokens=2)

    def test_autoscaled_run_never_frees_inflight(self, small_model):
        """A full bursty autoscaled run exercises drain+free repeatedly;
        `ServeReplica.free` raises on any in-flight work, so finishing
        cleanly IS the invariant check — plus every freed slice went
        through the drained state."""
        # chunk cost 0.05s virtual => ~160 tok/s per replica; the bursts
        # offer ~400 tok/s, so backlog forces scale-ups, and the quiet
        # phases force drains
        sc, svc = _service(
            small_model, num_blocks=16, replicas=1, timing=0.05,
            autoscale=AutoscalerConfig(min_replicas=1, max_replicas=3,
                                       tick_s=0.05, cooldown_s=0.3,
                                       scale_up_backlog=3.0,
                                       scale_down_backlog=0.5,
                                       provision_s=0.1))
        trace = generate(TrafficSpec(
            duration_s=4.0, rate_rps=4.0, pattern="bursty", burst_x=10.0,
            burst_period_s=2.0, burst_len_s=0.5, prompt_len_max=8,
            new_tokens_choices=(8, 16), new_tokens_weights=(0.6, 0.4)),
            seed=2)
        rep = svc.run(trace, settle_s=3.0)
        _assert_conserved(trace, rep)
        assert rep.scale_ups >= 1 and rep.scale_downs >= 1
        freed = [r for r in svc.retired if r.state == "freed"]
        assert freed, "scale-downs must have retired freed replicas"
        for r in freed:
            assert not r._assigned
        # alloc/free visible at machine level
        assert any(e.startswith("alloc") for e in sc.events)
        assert any(e.startswith("release") for e in sc.events)


class TestAutoscalerDecisions:
    def test_scale_to_zero_holds_at_zero_when_idle(self):
        """Regression: with scale_to_zero, an empty idle pool must HOLD —
        the grow rule uses the same floor as the down rule, else the pair
        oscillates allocate/free forever."""
        asc = Autoscaler(AutoscalerConfig(min_replicas=1,
                                          scale_to_zero=True))
        action, victim = asc.decide(10.0, [], wait_len=0, p95_ttft_s=None)
        assert action == "hold" and victim is None

    def test_empty_pool_grows_on_backlog(self):
        asc = Autoscaler(AutoscalerConfig(min_replicas=0,
                                          scale_to_zero=True))
        action, _ = asc.decide(0.0, [], wait_len=3, p95_ttft_s=None)
        assert action == "up"

    def test_floor_enforced_without_scale_to_zero(self):
        asc = Autoscaler(AutoscalerConfig(min_replicas=2))
        action, _ = asc.decide(0.0, [], wait_len=0, p95_ttft_s=None)
        assert action == "up"


class TestTraceRun:
    def test_trace_and_list_runs_match(self, small_model):
        """`run(FleetTrace)` (lazy materialization, cursor arrivals) and
        `run(list)` of the SAME trace must produce the same report — the
        structure-of-arrays path changes cost, never behavior."""
        spec = TrafficSpec(duration_s=3.0, rate_rps=6.0, pattern="bursty")
        reports = {}
        for form in ("trace", "list"):
            _, svc = _service(small_model, replicas=2)
            trace = generate_trace(spec, seed=13)
            arrivals = trace if form == "trace" else trace.materialize()
            reports[form] = svc.run(arrivals).to_dict()
        assert reports["trace"] == reports["list"]

    def test_unsorted_list_still_served(self, small_model):
        """A caller-shuffled request list is re-sorted once (the O(n)
        sortedness scan catches it); nothing is lost."""
        _, svc = _service(small_model, replicas=1)
        reqs = generate(TrafficSpec(duration_s=2.0, rate_rps=6.0), seed=3)
        shuffled = list(reversed(reqs))
        rep = svc.run(shuffled)
        _assert_conserved(reqs, rep)
        assert rep.offered == len(reqs)

    def test_trace_stranded_counted_without_materializing(self, small_model):
        """Kill all capacity mid-trace: arrivals never admitted must still
        be counted as dropped even though they were never materialized."""
        cfg, params = small_model
        sc = Supercomputer(num_blocks=1)
        svc = FleetService(sc, cfg, params, SPEC, geometry=(4, 4, 4),
                           initial_replicas=1, timing=CHUNK_S)
        trace = generate_trace(
            TrafficSpec(duration_s=4.0, rate_rps=8.0), seed=5)
        rep = svc.run(trace, fail_plan=[(2 * CHUNK_S, "replica:0")])
        assert rep.offered == len(trace)
        assert rep.completed + rep.dropped == rep.offered
        assert rep.dropped > 0
        assert len(svc.requests) < len(trace), \
            "stranded arrivals must not be materialized just to be dropped"


class TestForecaster:
    def test_abstains_before_min_history(self):
        f = RateForecaster(ForecastConfig(bin_s=0.25, min_history_s=2.0))
        f.observe(0.1)
        assert f.forecast_peak(1.0, 1.0, 1.5) is None

    def test_persistence_tracks_recent_rate(self):
        f = RateForecaster(ForecastConfig(bin_s=0.25, recent_window_s=1.0,
                                          min_history_s=1.0))
        for i in range(40):                  # 10 rps over 4 seconds
            f.observe(i * 0.1)
        got = f.forecast_peak(4.0, 4.0, 4.5)
        assert got == pytest.approx(10.0)

    def test_periodic_fold_predicts_peak_from_past_cycles(self):
        """Square-wave traffic with period 4: after two cycles the fold
        must predict the upcoming peak from the same phase of history —
        BEFORE the rate actually rises."""
        cfg = ForecastConfig(bin_s=0.25, period_s=4.0, min_history_s=1.0)
        f = RateForecaster(cfg)
        rng = np.random.default_rng(0)
        for cycle in range(2):
            base = cycle * 4.0
            for t in sorted(rng.uniform(0, 2, 8)):     # 4 rps quiet half
                f.observe(base + t)
            for t in sorted(rng.uniform(2, 4, 64)):    # 32 rps peak half
                f.observe(base + t)
        # now at the START of cycle 3's quiet half, look ahead into the
        # peak half: the fold must see the historical peak coming
        pred = f.forecast_peak(8.1, 10.0, 10.5)
        assert pred is not None and pred > 16.0
        # while a look-ahead into the quiet phase stays low
        low = f.forecast_peak(8.1, 8.5, 9.0)
        assert low is not None and low < pred / 2

    def test_predictive_up_bypasses_cooldown(self, small_model):
        """decide() returns "up" on a forecast-implied target even inside
        the reactive cooldown window, and record() counts it."""
        _, svc = _service(small_model, replicas=1)
        live = list(svc.replicas)
        asc = Autoscaler(AutoscalerConfig(min_replicas=1, max_replicas=4,
                                          cooldown_s=100.0, tick_s=0.25,
                                          provision_s=0.75),
                         forecast=ForecastConfig(bin_s=0.25,
                                                 min_history_s=0.5,
                                                 recent_window_s=1.0))
        for i in range(80):                  # 20 rps sustained
            asc.observe_arrival(2.0 + i * 0.05)
        asc.record("up", 6.0)                # cooldown just started
        action, _ = asc.decide(6.1, live, wait_len=0, p95_ttft_s=None,
                               capacity_rps=4.0)   # needs ceil(20*1.15/4)=6
        assert action == "up"
        asc.record("up", 6.1)
        assert asc.predictive_ups == 1

    def test_forecast_holds_capacity_through_predicted_peak(self):
        """The down rule must not drain into a predicted peak."""
        asc = Autoscaler(AutoscalerConfig(min_replicas=1, max_replicas=4,
                                          cooldown_s=0.0, tick_s=0.25),
                         forecast=ForecastConfig(bin_s=0.25,
                                                 min_history_s=0.5,
                                                 recent_window_s=1.0))
        for i in range(80):
            asc.observe_arrival(2.0 + i * 0.05)

        class _Idle:
            state = "active"
            depth = 0
            rep_id = 0

            def tokens_owed(self):
                return 0
        live = [_Idle(), _Idle(), _Idle()]
        live[1].rep_id, live[2].rep_id = 1, 2
        # forecast wants ceil(20*1.15/8)=3 replicas: no victim
        action, victim = asc.decide(6.0, live, wait_len=0, p95_ttft_s=None,
                                    capacity_rps=8.0)
        assert action == "hold" and victim is None
        # with capacity to spare (forecast wants 1), the drain proceeds
        action, victim = asc.decide(6.0, live, wait_len=0, p95_ttft_s=None,
                                    capacity_rps=30.0)
        assert action == "down" and victim is not None


class TestThroughputScaling:
    def _tps(self, small_model, n_replicas, n_requests):
        _, svc = _service(small_model, replicas=n_replicas)
        reqs = uniform_burst(n_requests, new_tokens=8, prompt_len=6)
        rep = svc.run(reqs)
        _assert_conserved(reqs, rep)
        assert rep.dropped == 0
        return rep.aggregate_tokens_per_s

    def test_two_replicas_scale(self, small_model):
        one = self._tps(small_model, 1, 8)
        two = self._tps(small_model, 2, 8)
        assert two >= 0.9 * 2 * one, (one, two)

    def test_four_replicas_scale(self, small_model):
        one = self._tps(small_model, 1, 16)
        four = self._tps(small_model, 4, 16)
        assert four >= 0.9 * 4 * one, (one, four)


class TestRouterPolicies:
    def test_least_loaded_balances(self, small_model):
        _, svc = _service(small_model, replicas=2)
        reqs = uniform_burst(8, new_tokens=4, prompt_len=4)
        svc.run(reqs)
        first = [r.replicas[0] for r in reqs]
        assert sorted(first.count(rep.rep_id)
                      for rep in svc.replicas) == [4, 4]

    def test_round_robin_alternates(self, small_model):
        _, svc = _service(small_model, replicas=2,
                          router=RouterConfig(policy="round_robin"))
        reqs = uniform_burst(6, new_tokens=4, prompt_len=4)
        svc.run(reqs)
        first = [r.replicas[0] for r in reqs]
        assert first == [0, 1, 0, 1, 0, 1]

    def test_least_eta_prefers_idle_replica(self, small_model):
        """A replica owing a long queue loses to an idle one under ETA."""
        _, svc = _service(small_model, replicas=2,
                          router=RouterConfig(policy="least_eta"))
        r0, r1 = svc.replicas
        for q in uniform_burst(4, new_tokens=16, prompt_len=4):
            r0.dispatch(q)
        assert r1.eta_s(0.0) < r0.eta_s(0.0)
        assert svc.router.pick(svc.replicas, 0.0) is r1


class TestServiceLifecycle:
    def test_close_frees_replicas_and_unsubscribes(self, small_model):
        cfg, params = small_model
        sc = Supercomputer(num_blocks=8)
        svc = FleetService(sc, cfg, params, SPEC, geometry=(4, 4, 4),
                           initial_replicas=2, timing=CHUNK_S)
        reqs = uniform_burst(4, new_tokens=4, prompt_len=4)
        svc.run(reqs)
        n_subs = len(sc._subscribers)
        svc.close()
        assert len(sc._subscribers) == n_subs - 1
        assert not svc.replicas and len(svc.retired) == 2
        assert sc.utilization() == 0.0
        # retired replicas keep stats but drop engine/cache references
        for r in svc.retired:
            assert r.session is None and r.slice is None
            assert r.stats()["state"] == "freed"

    def test_migration_within_prompt_window_is_not_truncated(self,
                                                             small_model):
        """SPEC.prompt_len=8 covers prompt(4)+new(5): the failure-migrated
        continuations stay inside the re-prefill window."""
        cfg, params = small_model
        sc = Supercomputer(num_blocks=2)
        svc = FleetService(sc, cfg, params, SPEC, geometry=(4, 4, 4),
                           initial_replicas=2, timing=CHUNK_S)
        reqs = uniform_burst(6, new_tokens=5, prompt_len=3)
        rep = svc.run(reqs, fail_plan=[(1.5 * CHUNK_S, "replica:0")])
        _assert_conserved(reqs, rep)
        stats = {s["rep_id"]: s for s in rep.replica_stats}
        assert all(s["truncated_migrations"] == 0 for s in stats.values())


class TestServedGoodput:
    def test_demand_one_matches_scheduled(self):
        for mode, sched in (("ocs", goodput_ocs), ("static", goodput_static)):
            got = served_goodput(512, 0.99, 1.0, mode=mode, trials=300,
                                 seed=0)
            want = sched(512, 0.99, trials=300, seed=0)
            assert np.isclose(got, want), (mode, got, want)

    def test_low_demand_ocs_serves_everything(self):
        assert served_goodput(512, 0.99, 0.25, trials=300) == 1.0

    def test_monotone_in_demand(self):
        vals = [served_goodput(3072, 0.99, d, trials=300)
                for d in (0.25, 0.5, 0.75, 1.0)]
        assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:])), vals

    def test_ocs_beats_static_at_fleet_level(self):
        ocs = served_goodput(512, 0.99, 0.75, mode="ocs", trials=200)
        static = served_goodput(512, 0.99, 0.75, mode="static", trials=200)
        assert ocs > static
