"""`repro.cluster` session API: allocate -> train -> serve, slice reuse
after free(), job queue, and block-failure propagation into live sessions."""
import warnings

import jax
import numpy as np
import pytest

from repro.cluster import (CapacityError, SliceError, SliceSpec,
                           Supercomputer)
from repro.configs import (OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)
from repro.core.autotopo import ModelProfile, ParallelSpec
from repro.models import api


def _run(arch="olmo-1b", gb=2, T=16):
    return RunConfig(
        model=registry.get_reduced(arch),
        shape=ShapeConfig("t", "train", T, gb),
        parallel=ParallelConfig(remat="none"),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2))


@pytest.fixture(scope="module")
def served_params():
    cfg = registry.get_reduced("olmo-1b")
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


class TestAllocation:
    def test_allocate_by_dims_and_chips(self):
        sc = Supercomputer()
        sl = sc.allocate((4, 8, 8))
        assert sl.dims == (4, 8, 8) and sl.num_chips == 256
        cube = sc.allocate(512)              # picks the max-bisection cube
        assert cube.dims == (8, 8, 8)
        assert sc.utilization() == pytest.approx(12 / 64)

    def test_reuse_after_free(self):
        sc = Supercomputer(num_blocks=8)
        sl = sc.allocate((8, 8, 8))          # whole machine
        with pytest.raises(CapacityError):
            sc.allocate((4, 4, 4))
        blocks = sl.blocks
        sl.free()
        assert sl.status == "freed"
        with pytest.raises(SliceError):
            sl.dryrun(ModelProfile("x", 1e9, 12, 1024, 128, 64))
        # same blocks and OCS ports are allocatable again
        sl2 = sc.allocate((8, 8, 8))
        assert sl2.blocks == blocks
        assert sc.utilization() == pytest.approx(1.0)

    def test_context_manager_frees(self):
        sc = Supercomputer()
        with sc.allocate((4, 4, 4)) as sl:
            assert sc.utilization() > 0
        assert sl.status == "freed" and sc.utilization() == 0.0

    def test_twisted_allocation_and_retwist(self):
        sc = Supercomputer()
        sl = sc.allocate((4, 4, 8), twisted=True)
        assert sl.topology.twisted and sl.describe() == "4x4x8_T"
        moved = sl.retwist(False)
        assert moved > 0 and not sl.twisted
        assert sl.retwist(False) == 0        # no-op
        with pytest.raises(ValueError):
            sc.allocate((4, 4, 4)).retwist(True)   # not twistable


class TestAnalytics:
    def test_dryrun_uses_slice_geometry(self):
        sc = Supercomputer()
        sl = sc.allocate((4, 4, 8))
        prof = ModelProfile("p", params=1e9, layers=12, d_model=1024,
                            seq_len=128, global_batch=64)
        ev = sl.dryrun(prof)
        assert ev.geometry == (4, 4, 8) and ev.step_time > 0
        pinned = sl.dryrun(prof, ParallelSpec(1, 4, 4, 8))
        assert pinned.spec.total == sl.num_chips

    def test_autotopo_searches_all_geometries(self):
        sc = Supercomputer()
        sl = sc.allocate((4, 4, 8))
        prof = ModelProfile("p", params=1e9, layers=12, d_model=1024,
                            seq_len=128, global_batch=64)
        evs = sl.autotopo(prof, top_k=4)
        assert evs and evs[0].step_time <= evs[-1].step_time
        assert {e.geometry for e in evs} <= set(sc.geometries(128))

    def test_bound_cost_model(self):
        sc = Supercomputer()
        sl = sc.allocate((4, 4, 8))
        topo = sl.topology
        assert sl.cost.all_reduce(2 ** 30) == pytest.approx(
            sc.costs.all_reduce(topo, 2 ** 30))
        assert sl.cost.all_to_all(2 ** 20) == pytest.approx(
            sc.costs.all_to_all(topo, 2 ** 20))

    def test_expected_goodput_modes(self):
        sc = Supercomputer()
        ocs = sc.expected_goodput(1024, 0.99, trials=500)
        static = sc.expected_goodput(1024, 0.99, mode="static", trials=100)
        assert ocs > static


class TestFailurePropagation:
    def test_failure_reroutes_and_notifies_session(self, served_params):
        cfg, params = served_params
        sc = Supercomputer()
        sl = sc.allocate((8, 8, 8))
        session = sl.serve(cfg, params,
                           SliceSpec(slots=2, max_len=32, prompt_len=8))
        for i in range(3):
            session.submit(np.arange(4) + i, max_new_tokens=4)
        sc.fail_block(sl.blocks[0])          # swapped for a spare
        assert sl.status == "active"
        assert [e.kind for e in session.interruptions] == ["reconfigure"]
        assert session.interruptions[0].circuits_moved > 0
        stats = session.run()
        assert not stats["aborted"]
        assert stats["requests_done"] == 3
        assert stats["interruptions"] == 1
        assert stats["reconfig_stall_s"] > 0

    def test_failure_without_spare_loses_slice(self, served_params):
        cfg, params = served_params
        sc = Supercomputer(num_blocks=1)
        sl = sc.allocate((4, 4, 4))
        session = sl.serve(cfg, params,
                           SliceSpec(slots=1, max_len=32, prompt_len=8))
        sc.fail_block(sl.blocks[0])
        assert sl.status == "lost"
        assert session.lost
        with pytest.raises(SliceError):
            session.submit(np.arange(4))
        stats = session.run()
        assert stats["aborted"]
        # failure-path stats expose the same keys as a normal run
        for k in ("requests_done", "tokens", "wall_s", "tokens_per_s",
                  "mean_ttft_s", "decode_steps"):
            assert k in stats

    def test_sessions_unusable_after_free(self, served_params):
        cfg, params = served_params
        sc = Supercomputer()
        sl = sc.allocate((4, 4, 4))
        serve = sl.serve(cfg, params,
                         SliceSpec(slots=1, max_len=32, prompt_len=8))
        train = sl.train(_run())
        sl.free()
        assert serve.closed and train.closed and not serve.lost
        with pytest.raises(SliceError):
            serve.submit(np.arange(4))
        with pytest.raises(SliceError):
            serve.run()
        with pytest.raises(SliceError):
            train.run(2)

    def test_idle_block_failure_touches_no_slice(self):
        sc = Supercomputer()
        sl = sc.allocate((4, 4, 4))
        free_block = max(sc.scheduler.free)
        sc.fail_block(free_block)
        assert sl.status == "active" and len(sl.events) == 1

    def test_straggler_swap_event(self):
        sc = Supercomputer()
        sl = sc.allocate((8, 8, 8))
        slow = sl.blocks[2]
        ev = sl.swap_straggler(slow)
        assert ev.kind == "straggler" and slow not in sl.blocks


class TestJobQueue:
    def test_fifo_drain(self):
        sc = Supercomputer(num_blocks=2)
        for i in range(3):
            sc.submit((4, 4, 8), lambda s, i=i: (i, s.describe()))
        done = sc.run_pending()
        assert [t.result for t in done] == [
            (0, "4x4x8"), (1, "4x4x8"), (2, "4x4x8")]
        assert not sc.queue and sc.utilization() == 0.0

    def test_backfill_around_blocked_head(self):
        sc = Supercomputer(num_blocks=2)
        hold = sc.allocate((4, 4, 4))
        sc.submit((4, 4, 8), lambda s: "big")      # needs both blocks
        sc.submit((4, 4, 4), lambda s: "small")    # fits now
        done = sc.run_pending()
        assert [t.result for t in done] == ["small"]
        hold.free()
        assert [t.result for t in sc.run_pending()] == ["big"]

    def test_submit_rejects_bad_geometry(self):
        sc = Supercomputer(num_blocks=2)
        with pytest.raises(ValueError):
            sc.submit((8, 8, 8), lambda s: None, twisted=True)
        with pytest.raises(ValueError):
            sc.submit((16, 16, 16), lambda s: None)   # > machine capacity
        assert not sc.queue

    def test_failed_job_keeps_queue_draining(self):
        sc = Supercomputer(num_blocks=2)
        sc.submit((4, 4, 4), lambda s: 1 / 0)
        sc.submit((4, 4, 4), lambda s: "ok")
        done = sc.run_pending()
        assert done[0].status == "failed" and "ZeroDivisionError" in done[0].error
        assert done[1].result == "ok"
        assert sc.utilization() == 0.0             # failed job's slice freed


class TestTrainServe:
    def test_train_then_serve_on_one_slice(self, tmp_path):
        sc = Supercomputer()
        sl = sc.allocate((4, 4, 4))
        run = _run()
        train = sl.train(run, 4, ckpt_dir=str(tmp_path), ckpt_every=2,
                         log_every=2)
        assert train.state.step == 4
        losses = [m["loss"] for m in train.metrics_log if "loss" in m]
        assert losses
        session = sl.serve(run.model, train.params,
                           SliceSpec(slots=2, max_len=32, prompt_len=8))
        session.submit(np.arange(4), max_new_tokens=4)
        stats = session.run()
        assert stats["requests_done"] == 1 and stats["tokens"] == 4
        sl.free()

    def test_block_failure_during_training_session(self, tmp_path):
        """The §2.3 story through the facade: fail mid-run, swap a spare,
        restore from checkpoint, finish — session records the event."""
        sc = Supercomputer()
        sl = sc.allocate((8, 8, 8))
        sess = sl.train(_run(), 6, ckpt_dir=str(tmp_path), ckpt_every=2,
                        fail_at=4, log_every=1)
        assert sess.state.step == 6
        assert [e.kind for e in sess.interruptions] == ["reconfigure"]
        assert sess.interruptions[0].circuits_moved > 0
        assert sl.status == "active"
        assert all(b in sc.scheduler.healthy for b in sl.blocks)
        restarts = sum(1 for m in sess.metrics_log if m.get("event"))
        assert restarts == 1


class TestServeEngineShim:
    def test_legacy_kwargs_raise_typeerror(self, served_params):
        """PR-4 removed the PR-1 kwargs shim: only SliceSpec constructs."""
        cfg, params = served_params
        from repro.serve.engine import ServeEngine
        with pytest.raises(TypeError):
            ServeEngine(cfg, params, slots=2, max_len=48, prompt_len=8)

    def test_spec_construction_no_warning(self, served_params):
        cfg, params = served_params
        from repro.serve.engine import ServeEngine
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            eng = ServeEngine(cfg, params, SliceSpec(slots=3))
        assert not [x for x in w
                    if issubclass(x.category, DeprecationWarning)]
        assert eng.slots == 3
