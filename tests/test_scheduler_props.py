"""Property suite for the block scheduler under adversarial op sequences.

Random allocate / free / preempt / shrink / fail / repair programs are run
against a small `Supercomputer` with cooperative dummy tenants (free on
"preempt", partial-shrink on "shrink_request" via the elastic trainer's
`shrink_target` policy), checking after EVERY op that

  * blocks are conserved: every block is free, owned by exactly one job,
    or failed — never two of those at once, never lost;
  * allocations only ever use healthy blocks;
  * victim selection respects priority ordering (victims are exactly the
    cheapest strictly-lower-priority prefix, and `request_capacity` at
    priority p never shrinks or evicts a tenant at priority >= p);
  * partial shrink never strands a gang below its minimum geometry.

Runs on real `hypothesis` when installed, else the deterministic shim in
`_hypothesis_compat` (seeded random examples, same properties).
"""
import sys

import pytest
from _hypothesis_compat import given, settings, st

from repro.cluster import Supercomputer
from repro.cluster.tenancy import shrink_target

NUM_BLOCKS = 8
# geometry ladders a dummy tenant may occupy, largest first (chip dims;
# blocks = product/64).  Every ladder bottoms out at one (4,4,4) block.
LADDERS = (
    ((4, 4, 16), (4, 4, 8), (4, 4, 4)),
    ((4, 8, 8), (4, 4, 8), (4, 4, 4)),
    ((4, 4, 8), (4, 4, 4)),
    ((4, 4, 4),),
)


def _blocks(dims):
    a, b, c = dims
    return (a // 4) * (b // 4) * (c // 4)


class _Tenant:
    """Cooperative dummy tenant: frees on preempt, partial-shrinks on
    shrink_request using the same `shrink_target` policy as the elastic
    trainer (never below the ladder's minimum geometry)."""

    def __init__(self, sl, ladder, priority):
        self.sl = sl
        self.ladder = ladder
        self.priority = priority
        self.preempted = False
        self.shrinks = 0

    def on_event(self, ev):
        if ev.kind == "preempt" and self.sl.status == "active":
            self.preempted = True
            self.sl.free()
        elif ev.kind == "shrink_request" and self.sl.status == "active":
            held = len(self.sl._job.blocks)
            tgt = shrink_target(self.ladder, held, ev.blocks_needed)
            if tgt is not None:
                self.sl.shrink(tgt)
                self.shrinks += 1


class _Harness:
    """One machine + tenant bookkeeping + the invariant checks."""

    def __init__(self):
        self.sc = Supercomputer(num_blocks=NUM_BLOCKS)
        self.tenants = {}               # job_id -> _Tenant
        self.failed = []                # fail-injection order
        self.sc.subscribe(self._on_machine_event)

    def _on_machine_event(self, sl, ev):
        t = self.tenants.get(sl.job_id)
        if t is not None and t.sl is sl:
            t.on_event(ev)

    # -- ops ----------------------------------------------------------------
    def op_allocate(self, arg):
        ladder = LADDERS[arg % len(LADDERS)]
        priority = (arg // len(LADDERS)) % 3
        preempt = ("shrink", True, False)[(arg // 16) % 3]
        sl = self.sc.allocate(ladder[0], required=False, priority=priority,
                              preempt=preempt)
        if sl is not None:
            self.tenants[sl.job_id] = _Tenant(sl, ladder, priority)

    def op_free(self, arg):
        live = self._live()
        if live:
            live[arg % len(live)].sl.free()

    def op_fail(self, arg):
        block = arg % NUM_BLOCKS
        if block in self.sc.scheduler.healthy:
            self.sc.fail_block(block)
            self.failed.append(block)

    def op_repair(self, arg):
        bad = sorted(set(range(NUM_BLOCKS)) - self.sc.scheduler.healthy)
        if bad:
            self.sc.repair_block(bad[arg % len(bad)])

    def op_request_capacity(self, arg):
        dims = ((4, 4, 4), (4, 4, 8), (4, 4, 16))[arg % 3]
        priority = 1 + arg % 3
        before = {j: (t.priority, len(t.sl._job.blocks), t.sl.status)
                  for j, t in self.tenants.items()
                  if t.sl.status == "active"}
        self.sc.request_capacity(dims, priority)
        # priority ordering: capacity pressure at `priority` may only have
        # touched strictly-lower-priority tenants
        for j, (prio, nblocks, _) in before.items():
            t = self.tenants[j]
            if prio >= priority:
                assert t.sl.status == "active", \
                    f"job{j} prio {prio} evicted by prio {priority}"
                assert len(t.sl._job.blocks) == nblocks, \
                    f"job{j} prio {prio} shrunk by prio {priority}"

    def _live(self):
        return [t for t in self.tenants.values()
                if t.sl.status == "active"]

    # -- invariants ---------------------------------------------------------
    def check(self):
        sched = self.sc.scheduler
        allb = set(range(NUM_BLOCKS))
        owned = []
        for job in sched.jobs.values():
            owned.extend(job.blocks)
        assert len(owned) == len(set(owned)), \
            f"block owned by two jobs: {sorted(owned)}"
        owned = set(owned)
        assert not (sched.free & owned), \
            f"blocks both free and owned: {sorted(sched.free & owned)}"
        failed = allb - sched.healthy
        assert sched.free | owned | failed == allb, \
            "leaked blocks: " \
            f"{sorted(allb - (sched.free | owned | failed))}"
        # live tenants sit on a ladder geometry, never below the minimum
        for t in self._live():
            dims = tuple(t.sl.dims)
            assert dims in t.ladder, (dims, t.ladder)
            assert len(t.sl._job.blocks) >= _blocks(t.ladder[-1])

    def check_victims(self, arg):
        """preemption_victims returns the cheapest strictly-lower-priority
        prefix of the candidate ordering (and None only when even evicting
        everyone below would not fit the request)."""
        sched = self.sc.scheduler
        dims = ((4, 4, 8), (4, 4, 16))[arg % 2]
        priority = 1 + arg % 3
        victims = sched.preemption_victims(dims, priority)
        cands = sorted((j for j in sched.jobs.values()
                        if j.priority < priority),
                       key=lambda j: (j.priority, len(j.blocks), -j.job_id))
        if victims is None:
            have = len(sched.free & sched.healthy) + sum(
                sum(1 for b in j.blocks if b in sched.healthy)
                for j in cands)
            assert have < sched.blocks_needed(dims)
            return
        assert all(j.priority < priority for j in victims)
        assert victims == cands[:len(victims)], \
            "victims are not the cheapest lower-priority prefix"


OPS = ("allocate", "free", "fail", "repair", "request_capacity")


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, len(OPS) - 1),
                          st.integers(0, 10 ** 6)),
                min_size=1, max_size=40))
def test_op_sequences_conserve_blocks(program):
    h = _Harness()
    for opcode, arg in program:
        getattr(h, f"op_{OPS[opcode]}")(arg)
        h.check()
        h.check_victims(arg)
    # teardown frees everything and the machine is whole again
    for t in h._live():
        t.sl.free()
    h.check()
    assert h.sc.scheduler.free | (set(range(NUM_BLOCKS))
                                  - h.sc.scheduler.healthy) \
        == set(range(NUM_BLOCKS))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, len(LADDERS) - 1), st.integers(1, 8))
def test_shrink_target_never_strands(ladder_i, need):
    """`shrink_target` only ever proposes geometries from the ladder,
    strictly smaller than what is held, and returns None (refuse) rather
    than dropping below the minimum geometry."""
    ladder = LADDERS[ladder_i]
    for dims in ladder:
        held = _blocks(dims)
        tgt = shrink_target(ladder, held, need)
        if dims == ladder[-1]:
            assert tgt is None, "shrink below the minimum geometry"
            continue
        if tgt is None:
            continue
        assert tgt in ladder
        assert _blocks(tgt) < held
        freed = held - _blocks(tgt)
        possible = held - _blocks(ladder[-1])
        # best-effort: frees the full request when any ladder rung can,
        # otherwise the most it can without stranding the gang
        if need <= possible:
            assert freed >= min(need, possible)


def test_cooperative_shrink_prefers_partial_over_preempt():
    """A shrink-capable low-priority tenant loses blocks, not its slice."""
    h = _Harness()
    sl = h.sc.allocate((4, 4, 16), priority=0)       # 4 of 8 blocks
    h.tenants[sl.job_id] = _Tenant(sl, LADDERS[0], 0)
    filler = h.sc.allocate((4, 4, 12), priority=0)   # 3 more: 1 block free
    assert h.sc.request_capacity((4, 4, 8), priority=1)
    h.check()
    t = h.tenants[sl.job_id]
    assert t.shrinks >= 1 and not t.preempted
    assert sl.status == "active"
    assert tuple(sl.dims) in LADDERS[0]
    taken = h.sc.allocate((4, 4, 8), priority=1)
    h.check()
    for s in (taken, filler, sl):
        s.free()
    h.check()


def test_preempt_falls_back_when_shrink_cannot_cover():
    """When every ladder rung is too small to cover the deficit, pass 2
    (full preemption) evicts the lowest-priority tenant — and the blocks
    still balance."""
    h = _Harness()
    a = h.sc.allocate((4, 4, 4), priority=0)         # min geometry: no shrink
    h.tenants[a.job_id] = _Tenant(a, LADDERS[3], 0)
    b = h.sc.allocate((4, 4, 16), priority=3)        # above the requester
    h.tenants[b.job_id] = _Tenant(b, LADDERS[0], 3)
    c = h.sc.allocate((4, 4, 12), priority=2)        # machine now full
    assert h.sc.request_capacity((4, 4, 4), priority=3)
    h.check()
    assert h.tenants[a.job_id].preempted, "min-geometry tenant must evict"
    assert b.status == "active", "higher-priority tenant untouched or shrunk"
    for s in (b, c):
        if s.status == "active":
            s.free()
    h.check()


def test_failed_block_is_not_reallocated_until_repair():
    h = _Harness()
    h.sc.fail_block(0)
    h.check()
    seen = set()
    slices = []
    for _ in range(NUM_BLOCKS - 1):
        sl = h.sc.allocate((4, 4, 4), required=False)
        if sl is None:
            break
        seen.update(sl._job.blocks)
        slices.append(sl)
    assert 0 not in seen
    assert h.sc.allocate((4, 4, 4), required=False) is None
    h.sc.repair_block(0)
    sl = h.sc.allocate((4, 4, 4), required=False)
    assert sl is not None and 0 in sl._job.blocks
    for s in slices + [sl]:
        s.free()
    h.check()


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-v"]))
