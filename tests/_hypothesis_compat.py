"""Property-test compatibility layer.

Uses the real `hypothesis` when installed (the `repro[test]` extra pins it);
otherwise provides a deterministic mini-shim covering the small strategy
surface these tests use (sampled_from / integers / floats / lists / tuples),
so the suite still collects and exercises every property with seeded random
examples instead of failing at import.
"""
try:
    from hypothesis import given, settings, strategies as st

except ModuleNotFoundError:
    import functools
    import inspect
    import random

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

        @staticmethod
        def integers(min_value=0, max_value=2 ** 16):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elements.example(rng)
                for _ in range(rng.randint(min_size, max_size))])

        @staticmethod
        def tuples(*strats):
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strats))

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(0x5EED)
                for _ in range(n):
                    fn(*args, *(s.example(rng) for s in strats), **kwargs)
            # hide the strategy-filled trailing params from pytest, which
            # would otherwise look for fixtures with those names
            params = list(inspect.signature(fn).parameters.values())
            wrapper.__signature__ = inspect.Signature(
                params[:len(params) - len(strats)])
            del wrapper.__wrapped__
            wrapper._max_examples = getattr(fn, "_max_examples",
                                            _DEFAULT_EXAMPLES)
            return wrapper
        return deco

__all__ = ["given", "settings", "st"]
