"""Topology invariants + the paper's Figure 6 / Table 2 / §2.9 claims."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.topology import (SliceTopology, geometries_for, is_twistable)

DIMS = st.tuples(st.sampled_from([4, 8]), st.sampled_from([4, 8]),
                 st.sampled_from([4, 8, 12]))


class TestTorusStructure:
    @settings(max_examples=12, deadline=None)
    @given(DIMS)
    def test_degree_is_six(self, dims):
        topo = SliceTopology(tuple(sorted(dims)))
        degs = {len(a) for a in topo.adjacency()}
        assert degs == {6}

    @settings(max_examples=8, deadline=None)
    @given(DIMS)
    def test_edge_count(self, dims):
        topo = SliceTopology(tuple(sorted(dims)))
        assert len(topo.edges()) == 3 * topo.num_chips

    def test_twisted_regular_degree(self):
        for dims in [(4, 4, 8), (4, 8, 8)]:
            t = SliceTopology(dims, twisted=True)
            assert {len(a) for a in t.adjacency()} == {6}
            assert len(t.edges()) == 3 * t.num_chips

    def test_twist_requires_legal_geometry(self):
        with pytest.raises(AssertionError):
            SliceTopology((4, 4, 4), twisted=True)
        with pytest.raises(AssertionError):
            SliceTopology((4, 8, 16), twisted=True)

    def test_twistable_predicate(self):
        assert is_twistable((4, 4, 8))
        assert is_twistable((4, 8, 8))
        assert is_twistable((8, 8, 16))
        assert is_twistable((8, 16, 16))
        assert not is_twistable((4, 4, 4))
        assert not is_twistable((8, 8, 8))
        assert not is_twistable((2, 2, 4))     # n >= 4 required
        assert not is_twistable((4, 8, 12))


class TestPaperClaims:
    def test_fig6_twisted_alltoall_gains(self):
        """Fig 6: twisted vs regular all-to-all = 1.63x (4x4x8), 1.31x
        (4x8x8).  Our ideal-routing model must land within +-15%."""
        for dims, measured in [((4, 4, 8), 1.63), ((4, 8, 8), 1.31)]:
            reg = SliceTopology(dims).alltoall_max_load()
            twi = SliceTopology(dims, twisted=True).alltoall_max_load()
            gain = reg / twi
            assert abs(gain - measured) / measured < 0.15, (dims, gain)

    def test_twist_doubles_bisection(self):
        for dims in [(4, 4, 8), (4, 8, 8)]:
            b_reg = SliceTopology(dims).bisection_links()
            b_twi = SliceTopology(dims, twisted=True).bisection_links()
            assert b_twi == 2 * b_reg

    def test_twist_reduces_diameter_and_hops(self):
        for dims in [(4, 4, 8), (4, 8, 8)]:
            dr, ar = SliceTopology(dims).diameter_and_avg_hops()
            dt, at = SliceTopology(dims, twisted=True).diameter_and_avg_hops()
            assert dt < dr
            assert at < ar

    def test_3d_beats_2d_bisection(self):
        """§2: the 3D torus motivator — N^(2/3) vs N^(1/2) scaling."""
        b3 = SliceTopology((4, 4, 8)).bisection_links()
        b2 = SliceTopology((8, 16, 1)).bisection_links()
        assert b3 / b2 >= 2.0

    def test_table2_geometries_enumerable(self):
        """Every >=64-chip geometry in Table 2 is a 4i x 4j x 4k slice."""
        table2 = [(4, 4, 4), (4, 4, 8), (4, 8, 8), (4, 4, 12), (4, 4, 16),
                  (4, 8, 12), (8, 8, 8), (4, 8, 16), (4, 4, 32), (8, 8, 12),
                  (8, 8, 16), (4, 16, 16), (4, 4, 64), (4, 8, 32),
                  (8, 12, 16), (4, 4, 96), (8, 8, 24), (8, 16, 16),
                  (12, 16, 16)]
        for dims in table2:
            n = dims[0] * dims[1] * dims[2]
            assert tuple(sorted(dims)) in geometries_for(n), dims


class TestGeometryEnumeration:
    @settings(max_examples=10, deadline=None)
    @given(st.sampled_from([64, 128, 256, 512, 1024, 2048, 4096]))
    def test_all_products_match(self, n):
        for dims in geometries_for(n):
            a, b, c = dims
            assert a * b * c == n
            assert a <= b <= c
            assert a % 4 == b % 4 == c % 4 == 0
