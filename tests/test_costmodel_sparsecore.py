"""Collective cost model + SparseCore timing model vs the paper's numbers."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.costmodel import (CollectiveCostModel, TPU_V3, TPU_V4,
                                  TPU_V5E)
from repro.core.sparsecore import (cpu_step_time, dlrm_step_time,
                                   pa_nas_balance, sc_step_time,
                                   tc_step_time)
from repro.core.topology import SliceTopology


class TestCollectiveCosts:
    def setup_method(self):
        self.cm = CollectiveCostModel(TPU_V4)
        self.topo = SliceTopology((4, 4, 8))

    def test_allreduce_scales_with_bytes(self):
        t1 = self.cm.all_reduce(self.topo, 1e9)
        t2 = self.cm.all_reduce(self.topo, 2e9)
        assert t2 == pytest.approx(2 * t1)

    @settings(max_examples=10, deadline=None)
    @given(st.floats(min_value=1e6, max_value=1e10))
    def test_alltoall_at_least_bisection_bound(self, nbytes):
        t = self.cm.all_to_all(self.topo, nbytes)
        bound = self.cm.all_to_all_bisection_bound(self.topo, nbytes)
        assert t >= 0.5 * bound

    def test_twisted_alltoall_faster(self):
        twi = SliceTopology((4, 4, 8), twisted=True)
        assert (self.cm.all_to_all(twi, 1e9)
                < self.cm.all_to_all(self.topo, 1e9))

    def test_single_chip_free(self):
        t = SliceTopology((1, 1, 1))
        assert self.cm.all_reduce(t, 1e9) == 0.0
        assert self.cm.all_to_all(t, 1e9) == 0.0

    def test_hw_presets(self):
        assert TPU_V5E.peak_flops_bf16 == 197e12
        assert TPU_V5E.hbm_bw == 819e9
        assert TPU_V5E.link_bw == 50e9
        assert TPU_V4.peak_flops_bf16 == 275e12


class TestSparseCoreModel:
    def setup_method(self):
        self.dlrm = get_config("dlrm0").dlrm
        self.topo = SliceTopology((4, 4, 8))

    def test_fig9_cpu_slowdown_5_to_7x(self):
        sc = sc_step_time(self.dlrm, 4096, self.topo, TPU_V4)["total"]
        cpu = cpu_step_time(self.dlrm, 4096, self.topo)["total"]
        assert 5.0 <= cpu / sc <= 8.0, cpu / sc

    def test_fig8_bisection_sensitivity_band(self):
        """3D vs 2D at the same chip count: emb speedup 1.1x-2.0x
        (N <= 256, where the paper's band applies)."""
        for n, d3, d2 in [(64, (4, 4, 4), (8, 8, 1)),
                          (128, (4, 4, 8), (8, 16, 1)),
                          (256, (4, 8, 8), (16, 16, 1))]:
            t3 = sc_step_time(self.dlrm, 32 * n, SliceTopology(d3),
                              TPU_V4)["total"]
            t2 = sc_step_time(self.dlrm, 32 * n, SliceTopology(d2),
                              TPU_V4)["total"]
            assert 1.1 <= t2 / t3 <= 2.0, (n, t2 / t3)

    def test_v4_beats_v3(self):
        v4 = dlrm_step_time(get_config("dlrm0"), 4096,
                            SliceTopology((4, 4, 8)), TPU_V4)["total"]
        v3 = dlrm_step_time(get_config("dlrm0"), 4096,
                            SliceTopology((8, 16, 1)), TPU_V3)["total"]
        assert v3 / v4 > 1.3

    def test_dedup_reduces_time(self):
        t_full = sc_step_time(self.dlrm, 4096, self.topo, TPU_V4,
                              dedup_factor=1.0)["total"]
        t_dedup = sc_step_time(self.dlrm, 4096, self.topo, TPU_V4,
                               dedup_factor=0.7)["total"]
        assert t_dedup < t_full

    def test_pa_nas_balance_gain(self):
        """Fig 10: imbalanced SC/TC -> balance search gives >10%."""
        out = pa_nas_balance(0.75, 1.0)
        assert out["gain"] > 1.10
        # already balanced -> no gain
        out2 = pa_nas_balance(1.0, 1.0)
        assert out2["gain"] == pytest.approx(1.0, abs=0.02)
