"""Per-arch smoke tests (reduced configs) + prefill/decode consistency.

The consistency test is the strongest correctness check we have: teacher-
forced forward logits at position t must match prefill(prefix)+decode chain
logits for every family that serves (attention KV caches, SSM states, hybrid
combinations, cross-attention caches).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.models import api

SMOKE_TRAIN = ShapeConfig("smoke_train", "train", 64, 2)

ARCHS = list(registry.ALL_ARCHS)


def assert_mostly_close(a, b, rtol=5e-2, atol=1e-1, frac=0.995):
    """bf16-robust closeness: >=frac of elements within tolerance."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    ok = np.abs(a - b) <= (atol + rtol * np.abs(b))
    assert ok.mean() >= frac, (
        f"only {ok.mean():.4f} close; worst={np.abs(a - b).max():.4f}")


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = registry.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    batch = api.make_batch(cfg, SMOKE_TRAIN, key)
    batch.pop("labels", None)
    logits, aux = api.forward(cfg, params, batch)
    if cfg.family == "dlrm":
        assert logits.shape == (SMOKE_TRAIN.global_batch,)
    else:
        assert logits.shape[0] == SMOKE_TRAIN.global_batch
        assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    assert bool(jnp.isfinite(aux)), "non-finite aux loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_runs_and_loss_finite(arch):
    from repro.configs.base import OptimizerConfig, ParallelConfig
    from repro.launch import steps as STEPS
    from repro.optim import adam as OPT
    from repro.parallel.context import LOCAL

    cfg = registry.get_reduced(arch)
    shape = ShapeConfig("t", "train", 32, 2)
    key = jax.random.PRNGKey(1)
    params = api.init_params(cfg, key)
    ocfg = OptimizerConfig(lr=1e-3)
    opt = OPT.init(ocfg, params)
    batch = api.make_batch(cfg, shape, key)
    step = STEPS.make_train_step(cfg, shape, ParallelConfig(remat="none"),
                                 ocfg, LOCAL, accum_steps=1)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "dlrm0"])
def test_prefill_decode_matches_forward(arch):
    cfg = registry.get_reduced(arch)
    key = jax.random.PRNGKey(2)
    params = api.init_params(cfg, key)
    T = 24
    shape = ShapeConfig("c", "prefill", T, 2)
    batch = api.make_batch(cfg, shape, key)

    # teacher-forced forward over the full sequence (MoE: high capacity so
    # dropping can't differ between the full-sequence and decode paths)
    kw = {"moe_cf": 16.0} if cfg.family == "moe" else {}
    logits_full, _ = api.forward(cfg, params, batch, **kw)

    # prefill on the first T-4 tokens, then decode the remaining 4
    cut = T - 4
    if cfg.family == "audio":
        from repro.models.whisper import split_seq
        enc, dec = split_seq(cfg, T)
        cut = dec - 4
        pre = dict(batch)
        pre["tokens"] = batch["tokens"][:, :cut]
    elif cfg.family == "vlm":
        pre = dict(batch)
        pre["tokens"] = batch["tokens"][:, :cut - cfg.vision_prefix] \
            if cut > cfg.vision_prefix else dict(batch)["tokens"][:, :2]
        cut = pre["tokens"].shape[1] + cfg.vision_prefix
        logits_full_t = logits_full
    else:
        pre = {k: (v[:, :cut] if k == "tokens" else v)
               for k, v in batch.items()}

    max_len = T + 8
    logits_pre, cache = api.prefill(cfg, params, pre, max_len=max_len, **kw)

    # the prefill's last-position logits must match forward at that position
    assert_mostly_close(logits_pre, logits_full[:, cut - 1])

    # decode the next tokens one by one and compare against forward
    toks = batch["tokens"]
    n_dec = 3
    for i in range(n_dec):
        if cfg.family == "audio":
            nxt = toks[:, cut + i]
        elif cfg.family == "vlm":
            nxt = toks[:, cut - cfg.vision_prefix + i]
        else:
            nxt = toks[:, cut + i]
        logits_dec, cache = api.decode_step(cfg, params, cache, nxt, **kw)
        want = logits_full[:, cut + i]
        assert_mostly_close(logits_dec, want)


def test_gemma2_window_schedule():
    from repro.models.transformer import GLOBAL_WINDOW, window_schedule
    cfg = registry.get_config("gemma2-9b")
    ws = window_schedule(cfg)
    assert len(ws) == 42
    assert ws[0] == 4096 and ws[1] == GLOBAL_WINDOW
    assert (ws[::2] == 4096).all() and (ws[1::2] == GLOBAL_WINDOW).all()


def test_blocked_attention_matches_reference():
    from repro.models.layers import blocked_attention, reference_attention
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, T, H, KH, D = 2, 48, 4, 2, 16
    q = jax.random.normal(ks[0], (B, T, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, KH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KH, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    for kw in [dict(), dict(window=8), dict(softcap=20.0),
               dict(causal=False)]:
        got = blocked_attention(q, k, v, pos, pos, kv_chunk=16, **kw)
        want = reference_attention(q, k, v, pos, pos, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)


def test_param_counts_match_published():
    expect = {
        "gemma2-9b": (9.0e9, 9.5e9),
        "olmo-1b": (1.1e9, 1.3e9),
        "qwen2-7b": (7.4e9, 7.8e9),
        "mistral-nemo-12b": (11.9e9, 12.5e9),
        "hymba-1.5b": (1.4e9, 1.8e9),
        "mamba2-130m": (0.12e9, 0.14e9),
        "whisper-small": (0.22e9, 0.26e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.1e12),
        "qwen3-moe-30b-a3b": (29e9, 32e9),
        "internvl2-2b": (1.7e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    # MoE active params
    assert 30e9 <= registry.get_config("kimi-k2-1t-a32b").active_param_count() <= 40e9
    assert 3.0e9 <= registry.get_config("qwen3-moe-30b-a3b").active_param_count() <= 3.7e9
