"""MoE: routing invariants, dropping behaviour, local dispatch vs dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import moe as MOE


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_reduced("qwen3-moe-30b-a3b")
    key = jax.random.PRNGKey(0)
    p = MOE.moe_init(cfg, key)
    return cfg, p


def _dense_oracle(cfg, p, x):
    """Compute every expert for every token, weight by renormalised top-k."""
    gates, eidx, _ = MOE.router_topk(cfg, p, x, jnp.float32)
    m = cfg.moe
    outs = []
    for e in range(m.num_experts):
        pe = {k: v[e] for k, v in p.items()
              if k in ("wg", "wu", "wi", "wo")}
        g = x @ pe["wg"].astype(jnp.float32)
        u = x @ pe["wu"].astype(jnp.float32)
        h = jax.nn.silu(g) * u
        outs.append(h @ pe["wo"].astype(jnp.float32))
    stack = jnp.stack(outs, axis=1)                      # (S, E, D)
    sel = jnp.zeros((x.shape[0], m.num_experts))
    for j in range(m.top_k):
        sel = sel + jax.nn.one_hot(eidx[:, j], m.num_experts) * gates[:, j:j + 1]
    return jnp.einsum("se,sed->sd", sel, stack)


class TestRouter:
    def test_gates_normalised(self, setup):
        cfg, p = setup
        x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
        gates, eidx, aux = MOE.router_topk(cfg, p, x)
        np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-4)
        assert int(eidx.min()) >= 0
        assert int(eidx.max()) < cfg.moe.num_experts
        assert float(aux) > 0


class TestLocalDispatch:
    def test_matches_dense_oracle_at_high_capacity(self, setup):
        cfg, p = setup
        x = jax.random.normal(jax.random.PRNGKey(2), (16, cfg.d_model),
                              jnp.float32) * 0.5
        got, aux, dropped = MOE.moe_local(cfg, p, x.astype(jnp.bfloat16),
                                          capacity_factor=8.0)
        assert float(dropped) == 0.0
        want = _dense_oracle(cfg, p, x)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), rtol=8e-2, atol=8e-2)

    def test_dropping_increases_with_lower_capacity(self, setup):
        cfg, p = setup
        x = jax.random.normal(jax.random.PRNGKey(3), (64, cfg.d_model))
        drops = []
        for cf in (4.0, 1.0, 0.25):
            _, _, d = MOE.moe_local(cfg, p, x, capacity_factor=cf)
            drops.append(float(d))
        assert drops[0] <= drops[1] <= drops[2]
        assert drops[2] > 0

    def test_grads_flow_through_dispatch(self, setup):
        cfg, p = setup
        x = jax.random.normal(jax.random.PRNGKey(4), (8, cfg.d_model))

        def loss(p):
            out, aux, _ = MOE.moe_local(cfg, p, x)
            return jnp.sum(out ** 2) + aux

        g = jax.grad(loss)(p)
        assert float(jnp.abs(g["router"]).sum()) > 0
        assert float(jnp.abs(g["wg"]).sum()) > 0


class TestCapacity:
    def test_capacity_formula(self):
        from repro.configs.base import MoEConfig
        m = MoEConfig(num_experts=8, top_k=2, expert_ffw=4)
        assert MOE.capacity_for(64, m, 1.0) == 16
        assert MOE.capacity_for(64, m, 1.25) == 20
        assert MOE.capacity_for(1, m, 1.0) == 4      # floor
