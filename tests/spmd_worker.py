"""Multi-device SPMD checks, run in a subprocess with 8 fake devices.

(jax locks its device count at first init, so the main pytest process —
which must see exactly 1 device for the smoke tests — cannot host these.)
Exits 0 iff every check passes; prints one line per check.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
# The 8 fake devices only exist on the host platform; pin it so jax never
# probes an ambient TPU runtime (the probe can stall for minutes when the
# caller's env, unlike ci.sh's, doesn't set this).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry, OptimizerConfig, ParallelConfig, ShapeConfig
from repro.configs.base import EmbeddingTableConfig
from repro.embeddings.engine import (EmbeddingCollection, lookup_reference,
                                     materialize_tables)
from repro.launch import steps as STEPS
from repro.models import api
from repro.models import moe as MOE
from repro.optim import adam as OPT
from repro.parallel import sharding as SH
from repro.parallel.context import ParallelContext, shard_map
from repro.parallel.overlap import overlapped_matmul_ag, overlapped_matmul_rs
from repro.parallel.pipeline import pipeline_apply

from repro.launch.mesh import make_mesh, mesh_scope

P = jax.sharding.PartitionSpec


def check(name, ok):
    print(("PASS " if ok else "FAIL ") + name, flush=True)
    if not ok:
        sys.exit(1)


mesh = make_mesh((2, 4), ("data", "model"))
ctx = ParallelContext(mesh=mesh, data_axis="data", model_axis="model")

# ---- 1. embedding engine distributed paths vs oracle -----------------------
specs = [EmbeddingTableConfig("big", 4096, 8, 4.0, 4, "sum"),
         EmbeddingTableConfig("big2", 2048, 8, 2.0, 2, "mean")]
import repro.embeddings.sharding as ESH
ESH_REP, ESH_TAB = ESH.REPLICATE_BYTES, ESH.TABLE_SHARD_BYTES
ESH.REPLICATE_BYTES = 0
ESH.TABLE_SHARD_BYTES = 0
coll = EmbeddingCollection(specs, num_shards=4)
params = coll.init(jax.random.PRNGKey(0))
feats = {"big": jax.random.randint(jax.random.PRNGKey(1), (16, 4), -1, 4096,
                                   jnp.int32),
         "big2": jax.random.randint(jax.random.PRNGKey(2), (16, 2), -1, 2048,
                                    jnp.int32)}
want = lookup_reference(materialize_tables(coll, params), specs, feats)
for method in ("psum", "a2a"):
    with mesh_scope(mesh):
        out = jax.jit(lambda p, f: coll.lookup(p, f, ctx, method=method))(
            params, feats)
    ok = all(np.allclose(np.asarray(out[k]), np.asarray(want[k]),
                         rtol=1e-5, atol=1e-6) for k in out)
    check(f"embedding_{method}_matches_oracle", ok)

with mesh_scope(mesh):
    g = jax.jit(jax.grad(lambda p: sum(
        jnp.sum(v ** 2) for v in coll.lookup(p, feats, ctx,
                                             method="a2a").values())))(params)
gl = jax.grad(lambda p: sum(
    jnp.sum(v ** 2) for v in coll.lookup(p, feats).values()))(params)
ok = all(np.allclose(np.asarray(g[k]), np.asarray(gl[k]), rtol=1e-4,
                     atol=1e-6) for k in g)
check("embedding_a2a_grads_match_local", ok)

# ---- 1b. pipeline v2 parity: pipelined / per-group / psum / cached ---------
from repro.embeddings.cache import HotIdCache

with mesh_scope(mesh):
    out_pipe = jax.jit(lambda p, f: coll.lookup(p, f, ctx, method="a2a",
                                                fused=True))(params, feats)
    out_legacy = jax.jit(lambda p, f: coll.lookup(p, f, ctx, method="a2a",
                                                  fused=False))(params,
                                                                feats)
ok = all(np.array_equal(np.asarray(out_pipe[k]), np.asarray(out_legacy[k]))
         for k in out_pipe)
check("embedding_pipelined_bitwise_matches_pergroup", ok)

with mesh_scope(mesh):
    out_psum = jax.jit(lambda p, f: coll.lookup(p, f, ctx,
                                                method="psum"))(params,
                                                                feats)
ok = all(np.allclose(np.asarray(out_psum[k]), np.asarray(out_pipe[k]),
                     rtol=1e-5, atol=1e-6) for k in out_pipe)
check("embedding_psum_allclose_a2a", ok)

# fresh hot-id cache: cached activations are BITWISE identical to the
# uncached a2a (hits are exact row snapshots; misses take the same path),
# and gradients are bitwise identical too (the custom_vjp backward
# re-differentiates the uncached dataflow)
cache = HotIdCache(capacity=64)
for _dim, _g in sorted(coll.groups.items()):
    for _s in _g.slots:
        _ids = np.asarray(feats[_s.spec.name])
        cache.observe(_g.name, np.where(_ids >= 0, _ids + _s.offset, -1))
cache.refresh_all(coll, params)
with mesh_scope(mesh):
    out_cached = jax.jit(
        lambda p, f, c: coll.lookup(p, f, ctx, method="a2a", cache=c))(
        params, feats, cache.arrays())
    g_cached = jax.jit(jax.grad(
        lambda p: sum(jnp.sum(v ** 2) for v in coll.lookup(
            p, feats, ctx, method="a2a",
            cache=cache.arrays()).values())))(params)
ok = all(np.array_equal(np.asarray(out_cached[k]), np.asarray(out_pipe[k]))
         for k in out_pipe)
check("embedding_cached_bitwise_matches_a2a", ok)
ok = all(np.array_equal(np.asarray(g_cached[k]), np.asarray(g[k]))
         for k in g)
check("embedding_cached_grads_exact", ok)
ESH.REPLICATE_BYTES, ESH.TABLE_SHARD_BYTES = ESH_REP, ESH_TAB

# ---- 2. moe_ep vs moe_local -------------------------------------------------
cfg = registry.get_reduced("qwen3-moe-30b-a3b")
pm = MOE.moe_init(cfg, jax.random.PRNGKey(3))
x = jax.random.normal(jax.random.PRNGKey(4), (8, 16, cfg.d_model),
                      jnp.float32) * 0.3
with mesh_scope(mesh):
    out_ep, aux_ep, _ = jax.jit(
        lambda p, x: MOE.moe_ep(cfg, p, x.astype(jnp.bfloat16), ctx,
                                batch_spec=("data",), seq_spec="model",
                                capacity_factor=8.0))(pm, x)
out_loc, aux_loc, _ = MOE.moe_local(
    cfg, pm, x.reshape(-1, cfg.d_model).astype(jnp.bfloat16),
    capacity_factor=8.0)
a = np.asarray(out_ep, np.float32).reshape(-1, cfg.d_model)
b = np.asarray(out_loc, np.float32)
row_ok = np.isclose(a, b, rtol=6e-2, atol=6e-2).all(axis=1)
# allow the odd token whose near-tied bf16 router scores break differently
check("moe_ep_matches_local", row_ok.mean() >= 0.98)

# ---- 3. sharded-vs-local train step numerics -------------------------------
shape = ShapeConfig("t", "train", 32, 8)
pcfg, ocfg = ParallelConfig(remat="block"), OptimizerConfig(lr=1e-3)
sctx = SH.make_context(mesh, pcfg)
for arch in ("olmo-1b", "hymba-1.5b"):
    rcfg = registry.get_reduced(arch)
    key = jax.random.PRNGKey(7)
    batch = api.make_batch(rcfg, shape, key)
    params = api.init_params(rcfg, key)
    opt = OPT.init(ocfg, params)
    # local (1-device semantics)
    from repro.parallel.context import LOCAL
    step_l = STEPS.make_train_step(rcfg, shape, pcfg, ocfg, LOCAL,
                                   accum_steps=2)
    _, _, m_l = jax.jit(step_l)(params, opt, batch)
    # sharded
    with mesh_scope(mesh):
        args, in_sh, out_sh, step_s = STEPS.shapes_and_shardings(
            rcfg, shape, pcfg, ocfg, sctx)
        step_s = STEPS.make_train_step(rcfg, shape, pcfg, ocfg, sctx,
                                       accum_steps=2)
        to = lambda t: jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s)
            if s is not None else None, t,
            is_leaf=lambda x: isinstance(x, P) or x is None)
        ps = jax.device_put(params, to(in_sh[0]))
        os_ = jax.device_put(opt, to(in_sh[1]))
        bs = jax.device_put(batch, to(in_sh[2]))
        _, _, m_s = jax.jit(step_s, in_shardings=to(in_sh),
                            out_shardings=to(out_sh))(ps, os_, bs)
    ok = np.isclose(float(m_l["loss"]), float(m_s["loss"]), rtol=2e-2)
    check(f"train_step_sharded_matches_local_{arch}", ok)

# ---- 4. sharded decode equals local decode ---------------------------------
rcfg = registry.get_reduced("mistral-nemo-12b")
key = jax.random.PRNGKey(9)
params = api.init_params(rcfg, key)
pre = {"tokens": jax.random.randint(key, (8, 16), 0, rcfg.vocab_size,
                                    jnp.int32)}
logits_l, cache_l = api.prefill(rcfg, params, pre, max_len=24)
tok = jnp.zeros((8,), jnp.int32)
dl, _ = api.decode_step(rcfg, params, cache_l, tok)
with mesh_scope(mesh):
    from repro.parallel.context import activate
    def dstep(p, c, t):
        with activate(sctx):
            return api.decode_step(rcfg, p, c, t, sctx)
    ds, _ = jax.jit(dstep)(params, cache_l, tok)
ok = np.allclose(np.asarray(dl, np.float32), np.asarray(ds, np.float32),
                 rtol=3e-2, atol=3e-2)
check("decode_sharded_matches_local", ok)

# ---- 5. overlap decomposition ------------------------------------------------
w = jax.random.normal(jax.random.PRNGKey(11), (16, 8))
xs = jax.random.normal(jax.random.PRNGKey(12), (8, 16))
with mesh_scope(mesh):
    yag = shard_map(lambda xs_, w_: overlapped_matmul_ag(xs_, w_, "model"),
                    mesh=mesh, in_specs=(P("model", None), P()),
                    out_specs=P(), check_vma=False)(xs, w)
check("overlap_allgather_matmul", np.allclose(np.asarray(yag),
                                              np.asarray(xs @ w), rtol=2e-5,
                                              atol=2e-5))
wrs = jax.random.normal(jax.random.PRNGKey(13), (16, 8))
xrs = jax.random.normal(jax.random.PRNGKey(14), (8, 16))
with mesh_scope(mesh):
    yrs = shard_map(
        lambda x_, w_: overlapped_matmul_rs(x_, w_, "model"),
        mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
        out_specs=P("model", None), check_vma=False)(xrs, wrs)
check("overlap_matmul_reducescatter", np.allclose(
    np.asarray(yrs), np.asarray(xrs @ wrs), rtol=1e-4, atol=1e-4))

# ---- 6. pipeline parallelism ---------------------------------------------------
mesh_p = make_mesh((4, 2), ("stage", "x"))
S = 4
Ws = jax.random.normal(jax.random.PRNGKey(15), (S, 16, 16)) * 0.1
xp = jax.random.normal(jax.random.PRNGKey(16), (8, 16))
with mesh_scope(mesh_p):
    y = pipeline_apply(lambda w, x: jnp.tanh(x @ w), Ws, xp, mesh=mesh_p,
                       stage_axis="stage", microbatches=4)
refp = xp
for i in range(S):
    refp = jnp.tanh(refp @ Ws[i])
check("pipeline_matches_sequential", np.allclose(
    np.asarray(y), np.asarray(refp), rtol=2e-5, atol=2e-5))

# ---- 7. compressed data-parallel gradient exchange --------------------------
from repro.parallel import compression as COMP

mesh_d = make_mesh((8, 1), ("data", "model"))
xs8 = np.asarray(jax.random.normal(jax.random.PRNGKey(21), (8, 256),
                                   jnp.float32))
ref_mean = xs8.mean(axis=0, keepdims=True)

with mesh_scope(mesh_d):
    out8 = shard_map(
        lambda g: COMP.compressed_allreduce(g, "int8", ("data",)),
        mesh=mesh_d, in_specs=P("data", None), out_specs=P(),
        check_vma=False)(jnp.asarray(xs8))
shared_scale = np.abs(xs8).max() / 127.0
check("compressed_allreduce_int8_bounded",
      np.abs(np.asarray(out8) - ref_mean).max() <= 0.51 * shared_scale)

k = int(256 * COMP.TOPK_FRAC)
sp = np.zeros_like(xs8)
for d in range(8):
    idx = np.argsort(-np.abs(xs8[d]), kind="stable")[:k]
    sp[d, idx] = xs8[d, idx]
with mesh_scope(mesh_d):
    outk = shard_map(
        lambda g: COMP.compressed_allreduce(g, "topk", ("data",)),
        mesh=mesh_d, in_specs=P("data", None), out_specs=P(),
        check_vma=False)(jnp.asarray(xs8))
check("compressed_allreduce_topk_exact_k",
      np.allclose(np.asarray(outk), sp.mean(axis=0, keepdims=True),
                  rtol=1e-5, atol=1e-6))

# train step with the compressed exchange active: the shard_map'd int8
# collective runs inside the jitted step, loss matches the local step, and
# the wire-bytes metric shows the ~4x payload cut
rcfg = registry.get_reduced("olmo-1b")
shape_c = ShapeConfig("t", "train", 16, 8)
pcfg_c = ParallelConfig(remat="none", grad_compression="int8")
sctx_d = SH.make_context(mesh_d, pcfg_c)
key = jax.random.PRNGKey(23)
params = api.init_params(rcfg, key)
opt = OPT.init(OptimizerConfig(), params)
batch = api.make_batch(rcfg, shape_c, key)
from repro.parallel.context import LOCAL as _LOCAL
step_l = STEPS.make_train_step(rcfg, shape_c, ParallelConfig(remat="none"),
                               OptimizerConfig(), _LOCAL, accum_steps=1)
_, _, m_l = jax.jit(step_l)(params, opt, batch)
with mesh_scope(mesh_d):
    step_c = STEPS.make_train_step(rcfg, shape_c, pcfg_c, OptimizerConfig(),
                                   sctx_d, accum_steps=1)
    _, _, m_c = jax.jit(step_c)(params, opt, batch)
check("compressed_train_step_loss_matches_local",
      np.isclose(float(m_l["loss"]), float(m_c["loss"]), rtol=2e-2))
check("compressed_train_step_wire_cut",
      float(m_c["wire_bytes_full"]) / float(m_c["wire_bytes"]) >= 3.9)

print("ALL_SPMD_OK", flush=True)
