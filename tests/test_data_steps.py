"""Data pipeline determinism + step builders + autotopo search sanity."""
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.data.synthetic import Dataset


class TestDataset:
    def test_deterministic_and_seekable(self):
        cfg = registry.get_reduced("olmo-1b")
        shape = ShapeConfig("t", "train", 16, 4)
        ds = Dataset(cfg, shape, seed=3)
        b1 = ds.batch(5)
        b2 = ds.batch(5)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])
        b3 = ds.batch(6)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_zipf_skew_enables_dedup(self):
        cfg = registry.get_reduced("dlrm0")
        ds = Dataset(cfg, ShapeConfig("t", "train", 1, 256), seed=0)
        b = ds.batch(0)
        t = cfg.dlrm.tables[0]
        ids = b[f"cat_{t.name}"]
        live = ids[ids >= 0]
        # power-law ids: the most frequent id covers >2% of lookups
        _, counts = np.unique(live, return_counts=True)
        assert counts.max() / live.size > 0.02

    def test_labels_are_shifted_tokens(self):
        cfg = registry.get_reduced("olmo-1b")
        ds = Dataset(cfg, ShapeConfig("t", "train", 16, 2), seed=1)
        b = ds.batch(0)
        assert b["tokens"].shape == b["labels"].shape

    @pytest.mark.parametrize("arch", ["whisper-small", "internvl2-2b",
                                      "dlrm0"])
    def test_family_specific_fields(self, arch):
        cfg = registry.get_reduced(arch)
        shape = (ShapeConfig("t", "train", 32, 2) if arch != "dlrm0"
                 else ShapeConfig("t", "train", 1, 8))
        b = Dataset(cfg, shape, seed=0).batch(0)
        if arch == "whisper-small":
            assert "frames" in b
        if arch == "internvl2-2b":
            assert "patches" in b
        if arch == "dlrm0":
            assert "dense" in b and any(k.startswith("cat_") for k in b)


class TestAccumPolicy:
    def test_accum_bounds_logits(self):
        from repro.configs.base import TRAIN_4K
        from repro.launch.steps import pick_accum_steps
        from repro.parallel.context import LOCAL
        cfg = registry.get_config("gemma2-9b")
        accum = pick_accum_steps(cfg, TRAIN_4K, LOCAL)
        assert TRAIN_4K.global_batch % accum == 0
        per = (TRAIN_4K.global_batch // accum) * TRAIN_4K.seq_len \
            * cfg.vocab_size * 4
        assert per <= 256 << 20 or accum == TRAIN_4K.global_batch


class TestAutotopo:
    def test_search_orders_and_maps(self):
        from repro.core.autotopo import ModelProfile, search
        prof = ModelProfile("toy", params=10e9, layers=32, d_model=4096,
                            seq_len=2048, global_batch=64)
        top = search(prof, 256, top_k=5)
        assert len(top) == 5
        times = [e.step_time for e in top]
        assert times == sorted(times)
        for e in top:
            assert e.spec.total == 256
            a, b, c = e.geometry
            assert a * b * c == 256

    def test_search_beats_naive_for_comm_bound_profile(self):
        """Table 3's message: the search finds materially better configs
        than naive picks for communication-bound jobs."""
        from repro.core.autotopo import (ModelProfile, ParallelSpec,
                                         estimate_step_time, search)
        prof = ModelProfile("llm", params=100e9, layers=80, d_model=12288,
                            seq_len=2048, global_batch=32)
        naive = estimate_step_time(
            prof, (4, 8, 16), ParallelSpec(1, 1, 16, 32, "1d", "1d"))
        best = search(prof, 512, top_k=1)[0]
        assert naive is not None
        assert naive.step_time / best.step_time >= 1.2
