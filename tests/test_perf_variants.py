"""§Perf optimization variants must match the baseline numerics:
qchunked attention, chunked cross-entropy, bf16 wire paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, ShapeConfig
from repro.launch import steps as STEPS
from repro.models import api
from repro.models.layers import (blocked_attention,
                                 blocked_attention_qchunked,
                                 reference_attention)
from repro.parallel.context import LOCAL


def mostly_close(a, b, rtol=3e-2, atol=5e-2, frac=0.99):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    ok = np.abs(a - b) <= (atol + rtol * np.abs(b))
    assert ok.mean() >= frac, (float(ok.mean()), float(np.abs(a - b).max()))


class TestQChunkedAttention:
    @pytest.mark.parametrize("window", [None, 16, 32])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_reference(self, window, causal):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        B, T, H, KH, D = 2, 64, 4, 2, 16
        q = jax.random.normal(ks[0], (B, T, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, T, KH, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, T, KH, D), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(T), (B, T))
        got = blocked_attention_qchunked(
            q, k, v, pos, pos, causal=causal, window=window,
            q_chunk=16, kv_chunk=16)
        want = reference_attention(q, k, v, pos, pos, causal=causal,
                                   window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_pair_pruning_counts(self):
        """Causal prunes ~half the pairs; windows prune to the band."""
        key = jax.random.PRNGKey(1)
        B, T, H, D = 1, 64, 2, 8
        q = jax.random.normal(key, (B, T, H, D))
        kv = jax.random.normal(key, (B, T, H, D))
        pos = jnp.broadcast_to(jnp.arange(T), (B, T))
        # verify numerics at several chunk configs (pair lists differ)
        outs = [blocked_attention_qchunked(q, kv, kv, pos, pos,
                                           q_chunk=cq, kv_chunk=ck)
                for cq, ck in [(8, 8), (16, 8), (8, 16), (64, 64)]]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       rtol=2e-2, atol=2e-2)

    @pytest.mark.parametrize("arch", ["gemma2-9b", "olmo-1b",
                                      "hymba-1.5b"])
    def test_model_forward_equivalence(self, arch):
        cfg = registry.get_reduced(arch)
        key = jax.random.PRNGKey(0)
        params = api.init_params(cfg, key)
        batch = api.make_batch(cfg, ShapeConfig("t", "prefill", 64, 2), key)
        l1, _ = api.forward(cfg, params, batch, attn_impl="blocked")
        l2, _ = api.forward(cfg, params, batch, attn_impl="qchunked")
        mostly_close(l1, l2)


class TestChunkedXent:
    def test_loss_and_grads_match(self):
        cfg = registry.get_reduced("olmo-1b")
        shape = ShapeConfig("t", "train", 32, 4)
        key = jax.random.PRNGKey(0)
        params = api.init_params(cfg, key)
        batch = api.make_batch(cfg, shape, key)
        l1, _ = STEPS.loss_fn(cfg, params, batch, LOCAL)
        l2, _ = STEPS.loss_fn(cfg, params, batch, LOCAL, xent_chunk=8)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
        g1 = jax.grad(lambda p: STEPS.loss_fn(cfg, p, batch, LOCAL)[0])(
            params)
        g2 = jax.grad(lambda p: STEPS.loss_fn(
            cfg, p, batch, LOCAL, xent_chunk=8)[0])(params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-2, atol=1e-4)

    def test_accum_drops_with_chunking(self):
        from repro.configs.base import TRAIN_4K
        from repro.launch.steps import pick_accum_steps

        class FakeCtx:
            mesh = type("M", (), {"devices": np.zeros((16, 16))})()

        cfg = registry.get_config("kimi-k2-1t-a32b")
        full = pick_accum_steps(cfg, TRAIN_4K, FakeCtx())
        chunked = pick_accum_steps(cfg, TRAIN_4K, FakeCtx(), xent_chunk=256)
        assert chunked < full
        assert chunked == 1


class TestEmbeddingWireBf16:
    def test_values_close_to_fp32(self):
        # bf16-on-the-wire changes only low-order bits of combined vectors
        from repro.configs.base import EmbeddingTableConfig
        from repro.embeddings.engine import EmbeddingCollection
        specs = [EmbeddingTableConfig("t", 256, 16, 4.0, 4, "sum")]
        coll = EmbeddingCollection(specs, num_shards=1)
        params = coll.init(jax.random.PRNGKey(0))
        feats = {"t": jax.random.randint(jax.random.PRNGKey(1), (8, 4), -1,
                                         256, jnp.int32)}
        out = coll.lookup(params, feats)
        # local path ignores wire flags; this asserts the API stays stable
        assert out["t"].shape == (8, 16)
