"""Runs the multi-device SPMD checks in a subprocess (8 fake devices)."""
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.timeout(1200)
def test_spmd_suite():
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root"}
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "spmd_worker.py")],
        capture_output=True, text=True, env=env, timeout=1100)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "SPMD subprocess failed"
    assert "ALL_SPMD_OK" in proc.stdout
