"""Straggler detection, spare-swap economics, and the OCS reconfig cost.

Three layers, bottom up:

  * `SliceScheduler.swap_straggler` / `_best_spare` — spare selection
    prefers fast blocks, refuses sideways swaps (no spare faster than the
    straggler) and degrades to a logged no-op with no spare at all;
  * `StragglerDetector` — hysteresis (one noisy step never fires; a
    persistent straggler fires after exactly `patience` steps), cooldown,
    and the payback decision against the ACOS-style reconfiguration cost;
  * live sessions — a fired swap emits a ``"straggler"`` `SliceEvent` that
    propagates into every attached session and charges the blackout to its
    stall clock, and the end-to-end serve/train drills recover step time.
"""
import jax
import numpy as np
import pytest

from repro.cluster import (SliceSpec, StragglerConfig, StragglerDetector,
                           Supercomputer)
from repro.cluster.slices import SliceSession
from repro.configs import (OptimizerConfig, ParallelConfig, RunConfig,
                           ShapeConfig, registry)
from repro.core import ocs
from repro.core.costmodel import CollectiveCostModel
from repro.fleet import FleetService, TrafficSpec, generate_trace
from repro.models import api

CHUNK_S = 0.01
SPEC = SliceSpec(slots=2, max_len=48, prompt_len=8, chunk=4)
CFG = StragglerConfig(threshold=1.25, ema_alpha=0.5, patience=3,
                      cooldown_steps=4)


@pytest.fixture(scope="module")
def small_model():
    cfg = registry.get_reduced("olmo-1b")
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


class TestReconfigCost:
    def test_zero_moves_is_free(self):
        assert ocs.reconfig_time(0) == 0.0
        assert CollectiveCostModel().reconfig_time(0) == 0.0

    def test_acos_shape(self):
        """Base MEMS switch time + per-switch-array programming rounds."""
        one_array = ocs.reconfig_time(ocs.NUM_OCS)
        assert one_array == pytest.approx(
            ocs.SWITCH_TIME_S + ocs.OCS_PROGRAM_S_PER_CIRCUIT)
        # a second full array adds exactly one more programming round
        assert ocs.reconfig_time(2 * ocs.NUM_OCS) == pytest.approx(
            one_array + ocs.OCS_PROGRAM_S_PER_CIRCUIT)
        assert ocs.reconfig_time(1) == ocs.reconfig_time(ocs.NUM_OCS)

    def test_costmodel_delegates(self):
        assert CollectiveCostModel().reconfig_time(64) == pytest.approx(
            ocs.reconfig_time(64))

    def test_retwist_charges_reconfig_time(self):
        sc = Supercomputer(num_blocks=8)
        sl = sc.allocate((4, 4, 8))
        moved = sl.retwist(True)
        assert moved > 0
        ev = [e for e in sl.events if e.kind == "retwist"][-1]
        assert ev.downtime_s == pytest.approx(ocs.reconfig_time(moved))


class TestDetectorHysteresis:
    def test_single_noisy_step_never_fires(self):
        det = StragglerDetector(CFG)
        for i in range(12):
            times = {b: 0.01 for b in range(4)}
            if i == 5:
                times[2] = 0.08        # one wild outlier
            assert det.observe(times) is None, i

    def test_persistent_straggler_fires_after_patience(self):
        det = StragglerDetector(CFG)
        hits = []
        for i in range(CFG.patience + 2):
            blk = det.observe({0: 0.01, 1: 0.01, 2: 0.02, 3: 0.01})
            if blk is not None:
                hits.append((i, blk))
        assert hits and hits[0] == (CFG.patience - 1, 2)
        assert det.slowdown_estimate(2) > CFG.threshold

    def test_flapping_load_never_fires(self):
        """Alternating slow/normal steps reset the streak every time."""
        det = StragglerDetector(CFG)
        for i in range(20):
            t2 = 0.02 if i % 2 == 0 else 0.01
            assert det.observe({0: 0.01, 1: 0.01, 2: t2, 3: 0.01}) is None

    def test_cooldown_silences_next_candidate(self):
        det = StragglerDetector(CFG)
        while det.observe({0: 0.01, 1: 0.02, 2: 0.01, 3: 0.01}) is None:
            pass
        det.fired(1)
        for i in range(CFG.cooldown_steps):
            assert det.observe({0: 0.01, 2: 0.02, 3: 0.01,
                                9: 0.01}) is None, i

    def test_single_block_slice_abstains(self):
        assert StragglerDetector(CFG).observe({0: 0.05}) is None

    def test_payback(self):
        det = StragglerDetector(CFG)
        for _ in range(CFG.patience):
            det.observe({0: 0.01, 1: 0.02, 2: 0.01, 3: 0.01})
        # 2x straggler at 10ms steps recovers ~10ms/step: a 12ms blackout
        # pays back over 200 steps but never over 1
        assert det.worth_swapping(1, 0.01, blackout_s=0.012)
        assert not det.worth_swapping(1, 0.01, blackout_s=0.012,
                                      remaining_steps=1)


class TestSchedulerSwap:
    def test_best_spare_prefers_fast_block(self):
        sc = Supercomputer(num_blocks=8)
        sl = sc.allocate((8, 4, 4))              # blocks [0, 1]
        sc.set_block_slowdown(2, 1.5)            # next-in-line spare is slow
        res = sc.scheduler.swap_straggler(sl.job_id, sl._job.blocks[0])
        assert res is not None
        assert 3 in sl._job.blocks and 2 not in sl._job.blocks

    def test_refuses_without_faster_spare(self):
        sc = Supercomputer(num_blocks=3)
        sl = sc.allocate((8, 4, 4))              # blocks [0, 1]; spare: 2
        sc.set_block_slowdown(1, 1.5)
        sc.set_block_slowdown(2, 2.0)            # spare even slower
        assert sc.scheduler.swap_straggler(sl.job_id, 1) is None
        assert 1 in sl._job.blocks
        assert any("no faster spare" in e for e in sc.scheduler.events)

    def test_no_spare_fallback(self):
        sc = Supercomputer(num_blocks=2)
        sl = sc.allocate((8, 4, 4))              # whole machine
        sc.set_block_slowdown(1, 2.0)
        assert sl.swap_straggler(1) is None
        assert sl._job.blocks == [0, 1]
        assert sl.status == "active"
        assert any("no spare" in e for e in sc.scheduler.events)

    def test_swap_frees_straggler_and_takes_spare(self):
        sc = Supercomputer(num_blocks=4)
        sl = sc.allocate((8, 4, 4))
        sc.set_block_slowdown(1, 2.0)
        ev = sl.swap_straggler(1)
        assert ev is not None and ev.kind == "straggler"
        assert ev.circuits_moved > 0
        assert ev.downtime_s == pytest.approx(
            ocs.reconfig_time(ev.circuits_moved))
        assert 1 not in sl._job.blocks
        assert 1 in sc.scheduler.free          # evicted straggler is a spare
        assert sl.slowdown_factor() == 1.0


class TestSliceTelemetry:
    def test_slowdown_factor_and_block_times(self):
        sc = Supercomputer(num_blocks=4)
        sl = sc.allocate((8, 4, 4))
        assert sl.slowdown_factor() == 1.0
        sc.set_block_slowdown(sl._job.blocks[1], 1.7)
        assert sl.slowdown_factor() == pytest.approx(1.7)
        bt = sl.block_times(0.01)
        assert bt[sl._job.blocks[0]] == pytest.approx(0.01)
        assert bt[sl._job.blocks[1]] == pytest.approx(0.017)

    def test_swap_cost_positive_and_uniform(self):
        sc = Supercomputer(num_blocks=4)
        sl = sc.allocate((8, 4, 4))
        costs = {sl.swap_cost_s(b) for b in sl._job.blocks}
        assert len(costs) == 1 and costs.pop() > 0

    def test_event_propagates_into_live_session(self):
        sc = Supercomputer(num_blocks=4)
        sl = sc.allocate((8, 4, 4))
        session = SliceSession(sl)
        seen = []
        session.add_listener(lambda s, ev: seen.append(ev.kind))
        sc.set_block_slowdown(sl._job.blocks[0], 2.0)
        ev = sl.swap_straggler(sl._job.blocks[0])
        assert ev is not None
        assert seen == ["straggler"]
        assert session.stall_s == pytest.approx(ev.downtime_s)
        assert not session.closed and not session.lost


class TestEndToEnd:
    def test_serve_detects_and_recovers(self, small_model):
        cfg, params = small_model
        sc = Supercomputer(num_blocks=8)
        svc = FleetService(sc, cfg, params, SPEC, geometry=(8, 4, 4),
                           initial_replicas=1, timing=CHUNK_S,
                           straggler=CFG)
        rep = svc.replicas[0]
        slow = rep.slice._job.blocks[1]
        sc.set_block_slowdown(slow, 2.0)
        report = svc.run(generate_trace(
            TrafficSpec(duration_s=3.0, rate_rps=8.0,
                        vocab_size=cfg.vocab_size), seed=7))
        assert report.straggler_swaps >= 1
        assert slow not in rep.slice._job.blocks
        assert rep.slice.slowdown_factor() == 1.0
        assert any(e.kind == "straggler" for e in rep.session.interruptions)
        assert report.completed + report.dropped == report.offered

    def test_serve_without_detector_stays_slow(self, small_model):
        cfg, params = small_model
        sc = Supercomputer(num_blocks=8)
        svc = FleetService(sc, cfg, params, SPEC, geometry=(8, 4, 4),
                           initial_replicas=1, timing=CHUNK_S)
        slow = svc.replicas[0].slice._job.blocks[1]
        sc.set_block_slowdown(slow, 2.0)
        report = svc.run(generate_trace(
            TrafficSpec(duration_s=1.5, rate_rps=8.0,
                        vocab_size=cfg.vocab_size), seed=7))
        assert report.straggler_swaps == 0
        assert slow in svc.replicas[0].slice._job.blocks
        assert svc.replicas[0].slice.slowdown_factor() == 2.0

    def test_train_detects_and_swaps(self, small_model):
        cfg, _ = small_model
        sc = Supercomputer(num_blocks=8)
        sl = sc.allocate((8, 4, 4))
        slow = sl._job.blocks[1]
        sc.set_block_slowdown(slow, 2.0)
        run = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 16, 2),
                        parallel=ParallelConfig(remat="none"),
                        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2))
        sess = sl.train(run)
        det = StragglerDetector(StragglerConfig(
            threshold=1.25, ema_alpha=0.5, patience=2, cooldown_steps=2))
        # enough remaining steps that the recovered time amortizes the
        # reconfiguration blackout (the payback check is remaining-aware)
        sess.run(30, straggler=det, log_every=100)
        assert det.fired_log and det.fired_log[0][1] == slow
        assert slow not in sl._job.blocks
        assert any(e.kind == "straggler" for e in sess.interruptions)
        assert sess.stall_s > 0
